"""Unified search-engine gates (repro.search; acceptance for the
estimator/engine refactor, DESIGN.md §10).

Three verdicts on one trained tile model:

  1. parity     — engine `anneal` at population=1 must replay the classic
     sequential annealing loop exactly: identical visit sequence, <1e-6
     objective delta (both sides scored through the same service-backed
     objective, so this isolates the engine's control flow).
  2. throughput — population-batched annealing (`population=POP`) must
     reach >=2x the sequential baseline's model-scoring throughput
     (configs scored per second of search wall-clock) on the same
     proposal budget. The win is batching: one coalesced service flush
     per temperature step instead of one per candidate.
  3. cascade    — analytical-prune -> learned-refine tile search must
     match learned-only top-k chosen-tile regret while issuing <=0.5x the
     learned-model queries.

Margins (see BENCH_SCALE semantics in benchmarks/common.py): scaling
only ever multiplies candidate/step counts, never kernel sizes, so both
gates stay *binding* at BENCH_SCALE=0.5 — but the throughput margin
shrinks with the timing window (measured 2.8-3.1x at scale 1.0 vs
2.3-2.6x at 0.5 on a noisy shared CPU; best-of-3 interleaved trials per
path). CI therefore runs this benchmark unscaled, like bench_serving.
The cascade query ratio is pinned at 0.5 by construction (keep=0.5) and
scale-independent.

  PYTHONPATH=src python benchmarks/bench_autotune.py
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.autotuner import autotune_program_tiles, \
    simulated_annealing_fusion
from repro.autotuner.fusion_autotuner import _propose_flips
from repro.core.evaluate import make_predict_fn
from repro.core.model import CostModelConfig
from repro.data.fusion import apply_fusion, default_fusion, fusable_edges
from repro.search import AnalyticalEstimator, CascadeEstimator, \
    LearnedEstimator, anneal

from common import SCALE, build_world, train_cost_model

MODEL_STEPS = max(int(320 * SCALE), 160)   # anneal steps (sequential)
POP = 24                                   # population of the batched run
TILE_TOP_K = 8
TILE_MAX_CONFIGS = 16
CASCADE_KEEP = 0.5
NODE_BUDGET = 1024                         # one flush per population step
#                                            without outsized pack buckets


def _model(world):
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=48, opcode_embed_dim=16, dropout=0.0,
                          max_nodes=48, adjacency="sparse")
    params = train_cost_model(world, cfg, task="tile",
                              n_steps=max(int(600 * SCALE), 300))
    # ONE jitted apply shared by every service below — fresh caches per
    # run must not mean fresh bucket compiles (see bench_serving)
    return cfg, params, make_predict_fn(cfg)


def _estimator(world, cfg, params, predict_fn):
    return LearnedEstimator.from_params(
        params, cfg, world.normalizers["random"],
        max_nodes=48, node_budget=NODE_BUDGET, predict_fn=predict_fn)


def _fusion_cost_many(est, prog):
    def cost_many(decs):
        return est.program_costs(
            [apply_fusion(prog, d, 48) for d in decs])
    return cost_many


def _sequential_reference(prog, start, cost_many, *, steps, rng,
                          t0=0.1, t1=1e-3):
    """The pre-refactor sequential annealer, scored through the same
    batched objective (one state per call)."""
    n_edges = len(fusable_edges(prog))
    cur, cur_cost = start, float(cost_many([start])[0])
    visited = {cur.fuse: cur_cost}
    best = [(cur_cost, cur)]
    for i in range(steps):
        if n_edges == 0:
            break
        temp = t0 * (t1 / t0) ** (i / max(steps - 1, 1))
        flips = 1 + int(rng.random() < 0.3)
        cand = cur
        for _ in range(flips):
            cand = cand.flip(int(rng.integers(n_edges)))
        if cand.fuse in visited:
            cand_cost = visited[cand.fuse]
        else:
            cand_cost = float(cost_many([cand])[0])
            visited[cand.fuse] = cand_cost
            best.append((cand_cost, cand))
        if cand_cost < cur_cost or rng.random() < np.exp(
                -(cand_cost - cur_cost) / max(temp * cur_cost, 1e-30)):
            cur, cur_cost = cand, cand_cost
    best.sort(key=lambda x: x[0])
    return best


def bench_parity(world, cfg, params, predict_fn, prog) -> tuple[bool, float]:
    """Returns (visit sequences identical, max objective delta) — BOTH are
    gated: identical sequences with drifted objectives and matching
    objectives via different visit orders are separate regressions."""
    est = _estimator(world, cfg, params, predict_fn)
    cost_many = _fusion_cost_many(est, prog)
    start = default_fusion(prog)
    n_edges = len(fusable_edges(prog))
    ref = _sequential_reference(prog, start, cost_many,
                                steps=MODEL_STEPS,
                                rng=np.random.default_rng(11))
    res = anneal(start, propose=_propose_flips(n_edges),
                 cost_many=cost_many,
                 steps=MODEL_STEPS if n_edges else 0,
                 rng=np.random.default_rng(11), key=lambda d: d.fuse)
    same_seq = [d.fuse for _, d in res.visited] == \
        [d.fuse for _, d in ref]
    delta = max((abs(a - b) for (a, _), (b, _) in zip(res.visited, ref)),
                default=float("inf")) if same_seq else float("inf")
    print(f"  parity: visit sequences {'identical' if same_seq else 'DIVERGED'}"
          f" ({len(res.visited)} states), objective delta {delta:.2e}")
    return same_seq, delta


class _TimedEstimator:
    """Pass-through that clocks `program_costs` — the model-scoring part
    of each annealing step (proposal generation / `apply_fusion` graph
    surgery is identical in both paths and excluded)."""

    def __init__(self, est):
        self._est = est
        self.seconds = 0.0

    def __getattr__(self, name):
        return getattr(self._est, name)

    def program_costs(self, groups):
        t0 = time.perf_counter()
        out = self._est.program_costs(groups)
        self.seconds += time.perf_counter() - t0
        return out


def bench_throughput(world, cfg, params, predict_fn, prog) -> tuple[bool, float]:
    def run(population: int, steps: int):
        est = _TimedEstimator(_estimator(world, cfg, params, predict_fn))
        r = simulated_annealing_fusion(prog, world.sim, estimator=est,
                                       population=population,
                                       model_steps=steps,
                                       hardware_budget_s=0.0, seed=3)
        return r.model_evals, est.seconds

    run(1, MODEL_STEPS)                            # warm jit (both paths
    run(POP, MODEL_STEPS // POP)                   # can hit new buckets)
    seq_tp = pop_tp = 0.0                          # best-of-3, interleaved:
    for _ in range(3):                             # rejects machine noise
        seq_evals, seq_dt = run(1, MODEL_STEPS)
        pop_evals, pop_dt = run(POP, MODEL_STEPS // POP)
        seq_tp = max(seq_tp, seq_evals / seq_dt)
        pop_tp = max(pop_tp, pop_evals / pop_dt)
    speedup = pop_tp / seq_tp
    print(f"  sequential  {seq_tp:7.0f} configs/s "
          f"({seq_evals} evals, {seq_dt:.2f}s scoring)")
    print(f"  population  {pop_tp:7.0f} configs/s "
          f"({pop_evals} evals, {pop_dt:.2f}s scoring, population={POP})")
    print(f"  model-scoring throughput speedup {speedup:.2f}x")
    return speedup >= 2.0, speedup


def bench_cascade(world, cfg, params, predict_fn) -> bool:
    kernels = []
    for prog in world.programs[:max(int(6 * SCALE), 3)]:
        if prog.num_nodes > 400:                  # keep the gate fast
            continue
        kernels.extend(apply_fusion(prog, default_fusion(prog)))
    kernels = [k for k in kernels if k.num_nodes <= 48][:24]

    learned_only = _estimator(world, cfg, params, predict_fn)
    res_learned = autotune_program_tiles(
        kernels, world.sim, scorer=None, estimator=learned_only,
        top_k=TILE_TOP_K, max_configs=TILE_MAX_CONFIGS)

    casc_refine = _estimator(world, cfg, params, predict_fn)  # fresh cache
    cascade = CascadeEstimator([AnalyticalEstimator(), casc_refine],
                               keep=CASCADE_KEEP)
    res_casc = autotune_program_tiles(
        kernels, world.sim, scorer=None, estimator=cascade,
        top_k=TILE_TOP_K, max_configs=TILE_MAX_CONFIGS)

    regret_l = res_learned.total_runtime / res_learned.best_runtime - 1
    regret_c = res_casc.total_runtime / res_casc.best_runtime - 1
    ratio = casc_refine.queries / max(learned_only.queries, 1)
    # keep=0.5 rounds up per kernel (ceil), so an odd candidate count
    # contributes half a query over 0.5x — allow exactly that
    ratio_limit = 0.5 + len(kernels) / (2 * max(learned_only.queries, 1))
    print(f"  learned-only: regret {100*regret_l:.3f}% "
          f"({learned_only.queries} learned queries, "
          f"{res_learned.hardware_evals} hw evals)")
    print(f"  cascade:      regret {100*regret_c:.3f}% "
          f"({casc_refine.queries} learned queries — {ratio:.2f}x, "
          f"limit {ratio_limit:.2f}x)")
    ok = regret_c <= regret_l + 1e-6 and ratio <= ratio_limit
    return ok, {"regret_learned": regret_l, "regret_cascade": regret_c,
                "query_ratio": ratio, "query_ratio_limit": ratio_limit}


def main() -> int:
    t_start = time.perf_counter()
    world = build_world()
    cfg, params, predict_fn = _model(world)
    # a big program (an imported arch if available): hundreds of fusable
    # edges means fresh configs per step — real scoring work to batch
    prog = max((p for p in world.programs if p.num_nodes <= 400),
               key=lambda p: len(fusable_edges(p)))
    print(f"bench_autotune: anneal program {prog.name} "
          f"({len(fusable_edges(prog))} fusable edges), "
          f"{MODEL_STEPS} sequential steps, population {POP}")

    same_seq, parity_delta = bench_parity(world, cfg, params, predict_fn,
                                          prog)
    ok_parity = same_seq and parity_delta < 1e-6
    ok_tp, tp_speedup = bench_throughput(world, cfg, params, predict_fn,
                                         prog)
    ok_casc, casc = bench_cascade(world, cfg, params, predict_fn)

    from common import Gate, emit_json
    ok = emit_json(
        "autotune",
        [Gate("population1_visit_sequences_identical", same_seq, True, "=="),
         Gate("population1_parity_delta", parity_delta, 1e-6, "<"),
         Gate("batched_scoring_speedup", tp_speedup, 2.0),
         Gate("cascade_regret_no_worse",
              casc["regret_cascade"], casc["regret_learned"] + 1e-6, "<="),
         Gate("cascade_query_ratio",
              casc["query_ratio"], casc["query_ratio_limit"], "<=")],
        wall_s=time.perf_counter() - t_start, extra=casc)
    print(f"bench_autotune: {'PASS' if ok else 'FAIL'} "
          f"(need population=1 parity <1e-6, >=2x batched scoring "
          f"throughput, cascade regret match at <=0.5x learned queries)"
          f"{'' if ok else f'  [parity={ok_parity} tp={ok_tp} casc={ok_casc}]'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
