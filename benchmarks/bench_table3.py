"""Table 3: graph-feature & loss ablations (each row = one change to the
'vanilla' configuration; GraphSAGE + per-node reduction like §6.1).

Rows: vanilla / undirected / +static-perf-as-node-features /
+static-perf-in-kernel-embedding / tile-size-moved-to-kernel-embedding /
MSE-instead-of-rank (tile only).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import (
    MAX_NODES,
    build_world,
    csv_row,
    steps,
    train_cost_model,
)
from repro.core.evaluate import (
    eval_fusion_task,
    eval_tile_task,
    learned_runtime_predictor,
    learned_tile_scorer,
)
from repro.core.model import CostModelConfig

N_STEPS = 700


def _vanilla() -> CostModelConfig:
    # vanilla = directed, NO static perf features, tile as node feature
    return CostModelConfig(gnn="graphsage", reduction="per_node",
                           hidden_dim=64, opcode_embed_dim=16,
                           max_nodes=MAX_NODES, dropout=0.1,
                           include_static_perf=False,
                           kernel_feat_mode="node")


VARIANTS = {
    "vanilla": {},
    "undirected": {"directed": False},
    "static_perf_node": {"include_static_perf": True},
    "static_perf_kernel_emb": {"include_static_perf": True,
                               "kernel_feat_mode": "kernel"},
    "tile_in_kernel_emb": {"kernel_feat_mode": "kernel"},
}


def run() -> list[str]:
    world = build_world()
    rows = []
    n = steps(N_STEPS)
    for name, delta in VARIANTS.items():
        mc = dataclasses.replace(_vanilla(), **delta)
        # tile task
        params = train_cost_model(world, mc, task="tile", method="random",
                                  n_steps=n, tag=f"t3.{name}")
        res = eval_tile_task(
            world.tile_subset("random", "test"),
            learned_tile_scorer(params, mc, world.normalizers["random"],
                                max_nodes=MAX_NODES, chunk=64))
        # fusion task
        params_f = train_cost_model(world, mc, task="fusion",
                                    method="random", n_steps=n,
                                    tag=f"t3f.{name}")
        pred = learned_runtime_predictor(params_f, mc,
                                         world.normalizers["random"],
                                         max_nodes=MAX_NODES, chunk=64)
        resf = eval_fusion_task(world.fusion_subset("random", "test"), pred,
                                min_runtime=5e-6)
        rows.append(csv_row(f"table3.{name}",
                            tile_median_ape=res["median_ape"],
                            tile_mean_ape=res["mean_ape"],
                            fusion_median_mape=resf["median_mape"],
                            fusion_mean_mape=resf["mean_mape"]))

    # 'MSE loss (not rank)' row — tile task trained on absolute log-runtime
    mc = _vanilla()
    params = train_cost_model(world, mc, task="tile_mse", method="random",
                              n_steps=n, tag="t3.mse")
    res = eval_tile_task(
        world.tile_subset("random", "test"),
        learned_tile_scorer(params, mc, world.normalizers["random"],
                            max_nodes=MAX_NODES, chunk=64))
    rows.append(csv_row("table3.mse_loss_not_rank",
                        tile_median_ape=res["median_ape"],
                        tile_mean_ape=res["mean_ape"],
                        fusion_median_mape=float("nan"),
                        fusion_mean_mape=float("nan")))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
