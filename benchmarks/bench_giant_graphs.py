"""Whole-program graphs: segmented batching + scan-over-layers GNN
(DESIGN.md §12).

Measures the two scaling mechanisms this repo uses to reach 10k+-node
program graphs:

  * scan-over-layers — the GNN layer body is traced ONCE per bucket shape
    regardless of depth (``lax.scan`` over stacked layer params), vs once
    per layer for the unrolled layout. Gates: a hard compile-count
    ceiling (scan layer traces == #buckets at depth 6) and a >=3x
    trace-count reduction vs unrolled.
  * segmented batching — a 10k-node whole-model graph partitioned into
    bounded sub-bucket segments, embedded through the existing sparse
    batcher, and reassembled before readout. Gates: segmented
    predictions on sub-bucket graphs are BIT-IDENTICAL to the plain
    sparse path (the identity fast path), a 10k-node training+serving
    throughput floor (nodes/sec), and an end-to-end boolean — 10k-node
    programs stream from an on-disk corpus through the trainer and then
    serve through CostModelService.

  PYTHONPATH=src python benchmarks/bench_giant_graphs.py
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import features as F
from repro.core import gnn as G
from repro.core.model import CostModelConfig, cost_model_apply, \
    cost_model_init
from repro.data import batching
from repro.data.sampler import BalancedSampler
from repro.data.store import StreamingCorpus, write_corpus
from repro.data.synthetic import random_kernel, whole_model_records
from repro.serving.service import CostModelService
from repro.training.trainer import CostModelTrainer, TrainerConfig

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
DEPTH = 6                                  # gate depth for the scan compare
TARGET_NODES = 10_000                      # whole-model graph size
NODE_BUDGET = 512                          # segment budget (sub-bucket)
NUM_PROGRAMS = max(int(3 * SCALE), 2)      # whole-model corpus size
TRAIN_STEPS = max(int(6 * SCALE), 3)
# 10k-node throughput floor, nodes/sec through the jitted train step after
# warmup. CPU measures ~15-19k nodes/s at hidden_dim=32/depth 6; the floor
# holds a ~5x margin for machine noise (see BENCH_SCALE notes in common.py;
# the trace-count and parity gates are scale-independent by construction).
THROUGHPUT_FLOOR = 3_000.0


def _cfg(**kw) -> CostModelConfig:
    base = dict(hidden_dim=32, opcode_embed_dim=8, gnn="graphsage",
                reduction="column_wise", dropout=0.0, max_nodes=NODE_BUDGET)
    base.update(kw)
    return CostModelConfig(**base)


# ----------------------------------------------------------------------------
# 1) scan-over-layers: layer-body trace counts under jit
# ----------------------------------------------------------------------------
def bench_scan_traces():
    """Trace the layer body across several bucket shapes at depth 6,
    unrolled vs stacked; the counters in repro.core.gnn bump only at trace
    time, so they count exactly the compile blowup scan removes."""
    graphs = [random_kernel(n, seed=n) for n in (12, 40, 90, 200)]
    norm = F.fit_normalizer(graphs)
    cfg = _cfg(gnn_layers=DEPTH, adjacency="sparse")
    params = cost_model_init(jax.random.key(0), cfg)
    stacked = dict(params, gnn=G.stack_params(params["gnn"]))
    # one bucket per graph: pack each alone so shapes differ
    encs = [batching.encode_packed([g], norm) for g in graphs]
    buckets = {(e.num_nodes, e.num_edges, e.batch_size) for e in encs}

    @jax.jit
    def fwd(p, b):
        return cost_model_apply(p, cfg, b, deterministic=True)

    G.reset_layer_trace_counts()
    for e in encs:
        np.asarray(fwd(params, e))
    unrolled = G.layer_trace_counts()["sparse"]
    G.reset_layer_trace_counts()
    for e in encs:
        np.asarray(fwd(stacked, e))
    scanned = G.layer_trace_counts()["sparse"]
    ratio = unrolled / max(scanned, 1)
    print(f"  layer traces at depth {DEPTH} over {len(buckets)} buckets: "
          f"unrolled={unrolled}, scan={scanned} ({ratio:.1f}x fewer)")
    return unrolled, scanned, len(buckets), ratio


# ----------------------------------------------------------------------------
# 2) segmented parity on sub-bucket graphs (identity fast path)
# ----------------------------------------------------------------------------
def bench_parity():
    graphs = [random_kernel(n, seed=100 + n) for n in (20, 9, 33, 15)]
    norm = F.fit_normalizer(graphs)
    cfg = _cfg(gnn_layers=3, adjacency="segmented")
    params = cost_model_init(jax.random.key(1), cfg)
    sb = batching.encode_segmented(graphs, NODE_BUDGET, norm)
    pb = batching.encode_packed(graphs, norm)
    ys = np.asarray(cost_model_apply(params, cfg, sb))[:len(graphs)]
    yp = np.asarray(cost_model_apply(params, cfg, pb))[:len(graphs)]
    delta = float(np.max(np.abs(ys - yp)))
    print(f"  segmented-vs-sparse prediction max |Δ| on sub-bucket "
          f"graphs = {delta:.2e}")
    return delta


# ----------------------------------------------------------------------------
# 3) 10k-node end-to-end: corpus -> trainer -> service, with throughput
# ----------------------------------------------------------------------------
def bench_giant_end_to_end(tmp: str):
    print(f"  generating {NUM_PROGRAMS} whole-model programs of "
          f"~{TARGET_NODES} nodes ...")
    recs = whole_model_records(NUM_PROGRAMS, TARGET_NODES, seed=0)
    sizes = [r.kernel.num_nodes for r in recs]
    print(f"  sizes: {sizes}")
    store_dir = os.path.join(tmp, "giant_corpus")
    write_corpus(store_dir, "fusion", recs)
    corpus = StreamingCorpus.open(store_dir)   # records stream from disk
    norm = F.fit_normalizer([r.kernel for r in corpus])

    mcfg = _cfg(gnn_layers=DEPTH, adjacency="segmented", scan_layers=True)
    sampler = BalancedSampler(corpus, norm, batch_size=1,
                              max_nodes=NODE_BUDGET, seed=0,
                              adjacency="segmented")
    tcfg = TrainerConfig(task="fusion", steps=TRAIN_STEPS, ckpt_every=0,
                         log_every=max(TRAIN_STEPS, 1))
    tr = CostModelTrainer(mcfg, tcfg, sampler)
    # warm the jit executable on step 0's bucket before timing
    tr.run(steps=1, resume=False)
    t0 = time.perf_counter()
    out = tr.run(resume=False)
    dt = time.perf_counter() - t0
    steps_timed = out["step"] - 1
    nodes_per_s = steps_timed * float(np.mean(sizes)) / dt
    trained = bool(np.isfinite(out["loss"]))
    print(f"  trained {steps_timed} steps over ~{TARGET_NODES}-node graphs "
          f"in {dt:.2f}s -> {nodes_per_s:,.0f} nodes/s "
          f"(loss={out['loss']:.4f})")

    svc = CostModelService(tr.params, mcfg, norm, node_budget=NODE_BUDGET)
    giant_preds = svc.predict_many([r.kernel for r in corpus])
    small_preds = svc.predict_many([random_kernel(12, seed=5)])
    served = bool(np.all(np.isfinite(giant_preds))
                  and np.all(np.isfinite(small_preds)))
    print(f"  served {len(giant_preds)} giant + 1 small graph "
          f"({'finite' if served else 'NON-FINITE'})")
    return nodes_per_s, trained and served


def main() -> int:
    t_start = time.perf_counter()
    print(f"bench_giant_graphs (BENCH_SCALE={SCALE})")
    unrolled, scanned, n_buckets, ratio = bench_scan_traces()
    delta = bench_parity()
    with tempfile.TemporaryDirectory() as tmp:
        nodes_per_s, e2e_ok = bench_giant_end_to_end(tmp)

    from common import Gate, emit_json
    ok = emit_json(
        "giant_graphs",
        [Gate("scan_traces_leq_buckets", scanned, n_buckets, "<="),
         Gate("trace_ratio_depth6", ratio, 3.0),
         Gate("parity_sub_bucket", delta, 0.0, "<="),
         Gate("giant_nodes_per_sec", nodes_per_s, THROUGHPUT_FLOOR),
         Gate("end_to_end_10k", e2e_ok, True, "==")],
        wall_s=time.perf_counter() - t_start,
        extra={"unrolled_traces": unrolled, "scan_traces": scanned,
               "buckets": n_buckets, "depth": DEPTH,
               "target_nodes": TARGET_NODES, "node_budget": NODE_BUDGET})
    print(f"bench_giant_graphs: {'PASS' if ok else 'FAIL'} "
          f"(scan traces <= buckets, >={3.0}x fewer traces at depth "
          f"{DEPTH}, bit-exact sub-bucket parity, "
          f">={THROUGHPUT_FLOOR:,.0f} nodes/s, 10k e2e)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
