"""CI gate enforcement over machine-readable benchmark results.

Reads the ``BENCH_<name>.json`` files written by `common.emit_json`
(uploaded as artifacts by ci.yml), prints a gate table, and exits
non-zero on any failure. The verdict distinguishes the three ways a run
can go wrong, because they point at different CI steps:

* ``missing report`` — an --expect'ed benchmark never emitted its JSON
  (it crashed before its gates; look at that benchmark step's log, not
  at this one);
* ``malformed report`` — the JSON exists but does not parse (truncated
  write / disk issue);
* ``gate regression`` — the benchmark ran and a measured gate failed.

With --baseline BASELINES.json each numeric gate's *margin* (distance
from its threshold, signed so bigger is better) is also compared
against the committed baseline:

* a gate whose margin flips negative still fails as a regression (the
  gate itself catches that);
* a still-passing gate whose margin eroded by more than 25% prints a
  WARN line — the early signal that a contract is about to start
  flapping — but does not fail the job;
* comparisons are skipped (INFO) when the report and baseline were
  measured at different BENCH_SCALE, since margins are scale-dependent
  (common.py §BENCH_SCALE).

Boolean and ``==`` gates carry no margin and are excluded from baseline
comparison. Refresh the baseline intentionally with --write-baseline
after a deliberate contract change:

  python benchmarks/check_gates.py --expect batching serving ...
  python benchmarks/check_gates.py --baseline benchmarks/BASELINES.json
  python benchmarks/check_gates.py --write-baseline benchmarks/BASELINES.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

EROSION = 0.25        # warn when margin < (1 - EROSION) * baseline margin


def gate_margin(gate: dict) -> float | None:
    """Signed distance from the threshold (bigger = safer), or None for
    boolean/equality gates which have no meaningful margin."""
    op = gate.get("op", ">=")
    if op == "==" or isinstance(gate.get("value"), bool):
        return None
    v, t = float(gate["value"]), float(gate["threshold"])
    return v - t if op in (">=", ">") else t - v


def load_reports(dir_: str) -> dict:
    reports = {}
    for path in sorted(glob.glob(os.path.join(dir_, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                reports[name] = json.load(f)
        except ValueError as e:
            print(f"MALFORMED  {path}: {e}")
            reports[name] = None
    return reports


def baseline_entries(reports: dict) -> dict:
    """The committed-baseline form of the current reports: one entry per
    numeric gate, keyed ``bench.gate``, recording the margin and the
    scale it was measured at."""
    out = {}
    for name, doc in sorted(reports.items()):
        if not doc:
            continue
        for g in doc.get("gates", []):
            m = gate_margin(g)
            if m is None:
                continue
            out[f"{name}.{g['name']}"] = {
                "value": g["value"], "threshold": g["threshold"],
                "op": g.get("op", ">="), "margin": round(m, 6),
                "bench_scale": doc.get("bench_scale")}
    return out


def compare_baseline(reports: dict, baseline: dict) -> list[str]:
    """Margin-erosion warnings (returned, already printed)."""
    warns = []
    for key, base in sorted(baseline.items()):
        name, gname = key.split(".", 1)
        doc = reports.get(name)
        if not doc:
            continue                     # missing/malformed handled already
        gate = next((g for g in doc.get("gates", [])
                     if g["name"] == gname), None)
        if gate is None:
            print(f"INFO       {key}: in baseline but not in report "
                  "(gate renamed/removed? refresh with --write-baseline)")
            continue
        if doc.get("bench_scale") != base.get("bench_scale"):
            print(f"INFO       {key}: baseline at scale "
                  f"{base.get('bench_scale')} vs report "
                  f"{doc.get('bench_scale')} — margin comparison skipped")
            continue
        m = gate_margin(gate)
        bm = base.get("margin")
        if m is None or bm is None or bm <= 0:
            continue
        if gate["passed"] and m < (1.0 - EROSION) * bm:
            msg = (f"{key}: margin {m:.6g} is "
                   f"{(1 - m / bm) * 100:.0f}% below baseline {bm:.6g}")
            print(f"WARN       {msg}")
            warns.append(msg)
    return warns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.environ.get("BENCH_JSON_DIR", "."),
                    help="where BENCH_*.json live (default: $BENCH_JSON_DIR "
                         "or CWD)")
    ap.add_argument("--expect", nargs="*", default=[],
                    help="bench names that MUST have emitted a report")
    ap.add_argument("--baseline", default="",
                    help="committed BASELINES.json to compare gate margins "
                         "against (warn on >25%% erosion; regressions "
                         "already fail via the gates themselves)")
    ap.add_argument("--write-baseline", default="", metavar="PATH",
                    help="merge the current reports' gate margins into "
                         "PATH and exit (the deliberate refresh helper)")
    args = ap.parse_args(argv)

    reports = load_reports(args.dir)

    if args.write_baseline:
        merged = {}
        if os.path.exists(args.write_baseline):
            with open(args.write_baseline) as f:
                merged = json.load(f).get("gates", {})
        fresh = baseline_entries(reports)
        merged.update(fresh)
        doc = {"comment": "committed gate-margin baseline; refresh with "
                          "check_gates.py --write-baseline after a "
                          "deliberate contract change",
               "gates": merged}
        with open(args.write_baseline, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(fresh)} gate baseline(s) "
              f"({len(merged)} total) -> {args.write_baseline}")
        return 0

    missing, malformed, regressions = [], [], []
    for name in args.expect:
        if name not in reports:
            print(f"MISSING    BENCH_{name}.json — benchmark did not emit "
                  "a report (crashed before its gates?)")
            missing.append(name)

    for name, doc in sorted(reports.items()):
        if doc is None:
            malformed.append(name)
            continue
        wall = doc.get("wall_s")
        head = (f"{name} (scale={doc.get('bench_scale')}, "
                f"wall={wall if wall is not None else '?'}s)")
        gates = doc.get("gates", [])
        if not gates:
            print(f"INFO       {head}: no gates (archival only)")
            continue
        for g in gates:
            status = "PASS" if g["passed"] else "FAIL"
            print(f"{status:10s} {name}.{g['name']}: "
                  f"{g['value']} {g['op']} {g['threshold']}")
            if not g["passed"]:
                regressions.append(f"{name}.{g['name']}: "
                                   f"{g['value']} !{g['op']} "
                                   f"{g['threshold']}")

    warns = []
    if args.baseline:
        try:
            with open(args.baseline) as f:
                base = json.load(f).get("gates", {})
        except FileNotFoundError:
            print(f"INFO       baseline {args.baseline} not found — "
                  "margin comparison skipped")
            base = {}
        warns = compare_baseline(reports, base)

    if missing or malformed or regressions:
        print("\nverdict: FAIL")
        if missing:
            print(f"  {len(missing)} missing report(s) — the benchmark "
                  "crashed before emitting; check its own step log:")
            for n in missing:
                print(f"    - BENCH_{n}.json")
        if malformed:
            print(f"  {len(malformed)} malformed report(s) — JSON did "
                  "not parse (truncated write?):")
            for n in malformed:
                print(f"    - BENCH_{n}.json")
        if regressions:
            print(f"  {len(regressions)} gate regression(s):")
            for r in regressions:
                print(f"    - {r}")
        return 1

    n_gates = sum(len(d.get("gates", [])) for d in reports.values() if d)
    tail = f", {len(warns)} margin warning(s)" if warns else ""
    print(f"\nverdict: PASS — all gates passed ({len(reports)} reports, "
          f"{n_gates} gates{tail})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
