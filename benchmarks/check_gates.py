"""CI gate enforcement over machine-readable benchmark results.

Reads the ``BENCH_<name>.json`` files written by `common.emit_json`
(uploaded as artifacts by ci.yml), prints a gate table, and exits
non-zero if any gate failed OR any --expect'ed report is missing (a
benchmark that crashed before emitting must fail the job, not slip
through). Run after the benchmark steps with ``if: always()`` so every
report is archived even when one regresses.

  python benchmarks/check_gates.py --expect batching input_pipeline \\
      serving autotune corpus
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.environ.get("BENCH_JSON_DIR", "."),
                    help="where BENCH_*.json live (default: $BENCH_JSON_DIR "
                         "or CWD)")
    ap.add_argument("--expect", nargs="*", default=[],
                    help="bench names that MUST have emitted a report")
    args = ap.parse_args(argv)

    reports = {}
    for path in sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                reports[name] = json.load(f)
        except ValueError as e:
            print(f"MALFORMED  {path}: {e}")
            reports[name] = None

    failures = []
    for name in args.expect:
        if name not in reports:
            print(f"MISSING    BENCH_{name}.json — benchmark did not emit "
                  "a report (crashed before its gates?)")
            failures.append(f"{name}: missing report")

    for name, doc in sorted(reports.items()):
        if doc is None:
            failures.append(f"{name}: malformed report")
            continue
        wall = doc.get("wall_s")
        head = (f"{name} (scale={doc.get('bench_scale')}, "
                f"wall={wall if wall is not None else '?'}s)")
        gates = doc.get("gates", [])
        if not gates:
            print(f"INFO       {head}: no gates (archival only)")
            continue
        for g in gates:
            status = "PASS" if g["passed"] else "FAIL"
            line = (f"{status:10s} {name}.{g['name']}: "
                    f"{g['value']} {g['op']} {g['threshold']}")
            print(line)
            if not g["passed"]:
                failures.append(f"{name}.{g['name']}: "
                                f"{g['value']} !{g['op']} {g['threshold']}")

    if failures:
        print(f"\n{len(failures)} gate failure(s):")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    n_gates = sum(len(d.get('gates', [])) for d in reports.values() if d)
    print(f"\nall gates passed ({len(reports)} reports, {n_gates} gates)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
