"""Shared benchmark world: corpus, datasets, splits, cached model training,
and machine-readable gate emission.

All benchmarks operate on the same corpus (synthetic families + programs
imported from the assigned architectures) with the paper's two split
methods. Trained cost models are cached under experiments/bench_cache keyed
by a config hash so re-runs (and the §Perf loop) are incremental.

The corpus itself is cached the same way: `build_world` writes the tile +
fusion datasets to a sharded on-disk store (repro.data.store) under
experiments/bench_cache/corpus/<spec_hash> on first build and reloads the
records from it afterwards — byte-identical records (dedup off, float64
labels bit-exact), so every downstream cache key and gate number is
unchanged; only the regeneration+measurement cost disappears. Set
REPRO_BENCH_CORPUS_CACHE=0 to force in-memory rebuilds.

## Machine-readable results (CI gates)

Every gated benchmark calls `emit_json(name, gates, wall_s=...)` which
writes ``BENCH_<name>.json`` (gate names, measured values, thresholds,
BENCH_SCALE, wall time) into $BENCH_JSON_DIR (default: CWD). CI uploads
these as artifacts — the perf trajectory is archived per run — and
`benchmarks/check_gates.py` fails the job on any gate regression or any
missing expected report.

## BENCH_SCALE semantics

`BENCH_SCALE` (env, default 1.0) scales how much *work* a benchmark does —
program/kernel counts, training steps, replay rounds — never the *size* of
individual kernels or models, so per-item costs and compiled shapes stay
representative at any scale. Guidelines:

* Scaling changes gate *margins*: fewer items means less amortization of
  cold caches and fixed overheads. A gate that must stay binding in CI
  should either be run at full scale or hold its margin at the CI scale.
  Concretely: `bench_serving.py`'s >=2x service-vs-direct gate has only a
  ~2.07x margin at BENCH_SCALE=0.5 (and the PR-3 encode cache also speeds
  up the *direct* baseline, full-scale margin ~2.6x), so CI runs it
  unscaled; `bench_autotune.py`'s >=2x batched-annealing gate likewise
  runs unscaled in CI (~2.8-3.1x at 1.0 vs ~2.3-2.6x at 0.5 — shorter
  timing windows, more machine-noise sensitivity; its cascade gate is
  scale-independent by construction); `bench_batching.py` and
  `bench_input_pipeline.py` keep wide margins at 0.5 and run scaled down.
* Benchmarks measuring steady-state throughput must warm jit executables
  (and any caches whose steady state is warm) *inside* the benchmark
  before timing — e.g. the serving bench replays the whole query stream
  once per path first, otherwise one path gets charged every bucket
  compile and the comparison is meaningless.
* Anything below ~0.3 is smoke-test territory: numbers still print but
  gates are not meaningful.
"""
from __future__ import annotations

import hashlib
import json
import os
import sys
import time
from dataclasses import dataclass


sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.analytical import AnalyticalModel, fit_type_coefficients
from repro.core.hlo_import import import_arch_program
from repro.core.model import CostModelConfig
from repro.core.simulator import TPUSimulator
from repro.data.corpus import filter_by_programs, split_programs
from repro.data.fusion_dataset import FusionDataset, build_fusion_dataset
from repro.data.sampler import BalancedSampler, TileBatchSampler
from repro.data.synthetic import generate_corpus
from repro.data.tile_dataset import TileDataset, build_tile_dataset
from repro.training.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.training.optim import AdamWConfig
from repro.training.trainer import CostModelTrainer, TrainerConfig

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))   # see module docstring
MAX_NODES = 48
CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench_cache")

IMPORT_ARCHS = ["yi-9b", "mamba2-2.7b", "granite-moe-3b-a800m",
                "recurrentgemma-9b", "musicgen-large"]


def steps(n: int) -> int:
    return max(int(n * SCALE), 50)


@dataclass
class World:
    sim: TPUSimulator
    programs: list
    tile: TileDataset
    fusion: FusionDataset
    splits: dict                     # method -> {train/val/test: [names]}
    normalizers: dict                # method -> FeatureNormalizer (train-fit)

    def tile_records(self, method: str, part: str):
        return filter_by_programs(self.tile.records,
                                  self.splits[method][part])

    def fusion_records(self, method: str, part: str):
        return filter_by_programs(self.fusion.records,
                                  self.splits[method][part])

    def tile_subset(self, method: str, part: str) -> TileDataset:
        return TileDataset(self.tile_records(method, part))

    def fusion_subset(self, method: str, part: str) -> FusionDataset:
        return FusionDataset(self.fusion_records(method, part))


_WORLD = None


def _load_or_build_datasets(programs, sim, seed: int):
    """Build-once-reuse-forever corpus datasets, keyed by spec hash.

    The store write keeps dedup OFF: `build_tile_dataset` /
    `build_fusion_dataset` outputs are preserved record-for-record
    (including cross-program structural duplicates), so the reloaded
    world is byte-identical to an in-memory build — same sampler
    streams, same trained-model cache keys, same gate numbers.
    """
    from repro.data.store import StreamingCorpus, load_manifest, \
        spec_hash, write_corpus
    fusion_configs = max(int(12 * SCALE), 6)
    spec = {"world": 1, "seed": seed, "scale": SCALE,
            "programs": sorted(p.program for p in programs),
            "tile_configs": 24, "fusion_configs": fusion_configs}
    cdir = os.path.join(CACHE_DIR, "corpus", spec_hash(spec))
    use_cache = os.environ.get("REPRO_BENCH_CORPUS_CACHE", "1") != "0"
    tdir, fdir = os.path.join(cdir, "tile"), os.path.join(cdir, "fusion")
    tm, fm = load_manifest(tdir), load_manifest(fdir)
    if (use_cache and tm is not None and fm is not None
            and tm["spec_hash"] == fm["spec_hash"] == spec_hash(spec)):
        tds = TileDataset(list(StreamingCorpus.open(tdir)))
        fds = FusionDataset(list(StreamingCorpus.open(fdir)))
        print(f"[bench] corpus reloaded from store {cdir} "
              f"(tile {tm['manifest_hash'][:12]}…, "
              f"fusion {fm['manifest_hash'][:12]}…)", file=sys.stderr)
        return tds, fds
    tds = build_tile_dataset(programs, sim, max_configs_per_kernel=24)
    fds = build_fusion_dataset(programs, sim,
                               configs_per_program=fusion_configs)
    if use_cache:
        write_corpus(tdir, "tile", tds.records, spec=spec, dedup=False)
        write_corpus(fdir, "fusion", fds.records, spec=spec, dedup=False)
        print(f"[bench] corpus written to store {cdir}", file=sys.stderr)
    return tds, fds


def build_world(num_programs: int | None = None, seed: int = 0) -> World:
    global _WORLD
    if _WORLD is not None:
        return _WORLD
    n = num_programs or max(int(48 * SCALE), 16)
    sim = TPUSimulator()
    programs = generate_corpus(n, seed=seed)
    for arch in IMPORT_ARCHS:
        try:
            programs.append(import_arch_program(arch))
        except Exception as e:                        # noqa: BLE001
            print(f"[warn] arch import {arch} failed: {e}", file=sys.stderr)
    tds, fds = _load_or_build_datasets(programs, sim, seed)
    names = sorted({p.program for p in programs})
    splits = {m: split_programs(names, method=m, seed=seed)
              for m in ("random", "manual")}
    # normalizers are fit on the TRAIN split only (paper footnote 1)
    normalizers = {}
    for m in ("random", "manual"):
        from repro.data.tile_dataset import fit_tile_normalizer
        normalizers[m] = fit_tile_normalizer(
            filter_by_programs(tds.records, splits[m]["train"]))
    _WORLD = World(sim, programs, tds, fds, splits, normalizers)
    return _WORLD


# ----------------------------------------------------------------------------
# Cached training
# ----------------------------------------------------------------------------
def _cfg_hash(model_cfg: CostModelConfig, task: str, method: str,
              n_steps: int, extra: str = "") -> str:
    blob = json.dumps([model_cfg.to_dict(), task, method, n_steps, extra,
                       SCALE], sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def train_cost_model(world: World, model_cfg: CostModelConfig, *,
                     task: str, method: str = "random",
                     n_steps: int = 1000, lr: float = 2e-3,
                     rank_phi: str = "hinge", tag: str = "") -> dict:
    """Train (or load cached) params for a task/split. Returns params."""
    from repro.core.model import cost_model_init
    import jax

    h = _cfg_hash(model_cfg, task + rank_phi, method, n_steps, tag)
    ckpt_dir = os.path.join(CACHE_DIR, h)
    template = {"params": cost_model_init(jax.random.key(0), model_cfg)}
    if latest_step(ckpt_dir) is not None:
        state, _, _ = restore_checkpoint(ckpt_dir, template)
        return state["params"]

    norm = world.normalizers[method]
    if task.startswith("tile"):
        sampler = TileBatchSampler(
            world.tile_records(method, "train"), norm,
            kernels_per_batch=3, configs_per_kernel=8, max_nodes=MAX_NODES)
    else:
        sampler = BalancedSampler(
            world.fusion_records(method, "train"), norm,
            batch_size=24, max_nodes=MAX_NODES)
    tc = TrainerConfig(task=task, rank_phi=rank_phi, steps=n_steps,
                       ckpt_every=0, log_every=200,
                       optim=AdamWConfig(lr=lr, schedule="exponential",
                                         lr_decay=0.9,
                                         decay_every=max(n_steps // 4, 1)))
    tr = CostModelTrainer(model_cfg, tc, sampler)
    t0 = time.time()
    tr.run(n_steps, resume=False)
    print(f"    trained {task}/{method} {n_steps} steps in "
          f"{time.time()-t0:.0f}s", file=sys.stderr)
    save_checkpoint(ckpt_dir, n_steps, {"params": tr.params})
    return tr.params


def analytical_fusion_predictor(world: World, method: str):
    """Analytical model with per-type coefficients fit like §5.2 (on the
    test programs' default-fusion kernels)."""
    am = AnalyticalModel()
    recs = world.fusion_records(method, "test")
    coeffs = fit_type_coefficients(am, [r.kernel for r in recs],
                                   [r.runtime for r in recs])
    from repro.core.evaluate import analytical_runtime_predictor
    return analytical_runtime_predictor(am, coeffs)


def paper_tile_model(hidden=64) -> CostModelConfig:
    """The paper's chosen tile model: GraphSAGE + LSTM reduction."""
    return CostModelConfig(gnn="graphsage", reduction="lstm",
                           hidden_dim=hidden, opcode_embed_dim=16,
                           max_nodes=MAX_NODES, dropout=0.1)


def paper_fusion_model(hidden=64) -> CostModelConfig:
    """The paper's chosen fusion model: GraphSAGE + Transformer, static
    perf features as node features."""
    return CostModelConfig(gnn="graphsage", reduction="transformer",
                           hidden_dim=hidden, opcode_embed_dim=16,
                           max_nodes=MAX_NODES, dropout=0.1)


def csv_row(name: str, **kv) -> str:
    parts = [name] + [f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in kv.items()]
    return ",".join(parts)


# ----------------------------------------------------------------------------
# Machine-readable benchmark results (CI artifacts + gate enforcement)
# ----------------------------------------------------------------------------
@dataclass
class Gate:
    """One pass/fail criterion of a benchmark.

    `op` compares `value` against `threshold`: ">=" / "<=" / ">" / "<"
    for measured margins, "==" for exactness/boolean gates (pass
    value=bool(x), threshold=True).
    """
    name: str
    value: float | bool
    threshold: float | bool
    op: str = ">="

    _OPS = {">=": lambda v, t: v >= t, "<=": lambda v, t: v <= t,
            ">": lambda v, t: v > t, "<": lambda v, t: v < t,
            "==": lambda v, t: v == t}

    @property
    def passed(self) -> bool:
        return bool(self._OPS[self.op](self.value, self.threshold))

    def to_dict(self) -> dict:
        return {"name": self.name, "value": self.value,
                "threshold": self.threshold, "op": self.op,
                "passed": self.passed}


def emit_json(name: str, gates: list, *, wall_s: float | None = None,
              extra: dict | None = None) -> bool:
    """Write ``BENCH_<name>.json`` (the machine-readable result CI archives
    and `benchmarks/check_gates.py` enforces) into $BENCH_JSON_DIR
    (default: CWD). `gates` may mix `Gate` objects and pre-built dicts.
    Returns True iff every gate passed.
    """
    gate_dicts = [g.to_dict() if isinstance(g, Gate) else dict(g)
                  for g in gates]
    passed = all(g["passed"] for g in gate_dicts)
    doc = {"bench": name, "bench_scale": SCALE,
           "wall_s": None if wall_s is None else round(float(wall_s), 3),
           "passed": passed, "gates": gate_dicts, "extra": extra or {}}
    out_dir = os.environ.get("BENCH_JSON_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[bench] wrote {path} ({'PASS' if passed else 'FAIL'})",
          file=sys.stderr)
    return passed
