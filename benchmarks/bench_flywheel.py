"""Data-flywheel acceptance gates (DESIGN.md §15): the closed
measure→append→fine-tune→search loop must beat a static model at equal
hardware budget, the delta-chained corpus view must be byte-identical to
a from-scratch rebuild, and warm-start fine-tuning must reach from-
scratch quality in a fraction of the steps.

Scenario: a static tile model is trained on a base corpus store (written
dedup=True — the flywheel's append path dedups against it), then both
strategies tune a *hard set* of held-out kernels — the pool kernels the
static model ranks worst, exactly the kernels a flywheel exists for —
under one shared `BudgetMeter`:

* static baseline: `static_plan` — round-robin top-k by static score
  (pure exploitation), deploy-and-observe regret via `deploy_regret`;
* flywheel: `run_flywheel` — per round, MC-dropout uncertainty routes
  the budget slice (`AcquisitionEstimator.acquire`), measurements land
  in the store as chain-verified delta shards, and the model is
  warm-start fine-tuned on the base+delta view before re-scoring.

Gates:

* ``regret_margin`` — static regret minus flywheel final regret, gated
  strictly > 0 at equal total evals (the whole point of the loop).
* ``delta_stream_parity`` — `StreamingCorpus.with_deltas()` record
  stream byte-identical (`pack_record` transit form) to
  `write_corpus(base_records + replayed round measurements, dedup=True)`
  — the from-scratch rebuild the delta chain promises to equal.
* ``warm_start_steps_ratio`` — fine-tuning from the static checkpoint
  (params + AdamW moments, LR re-warmed) on the chained corpus must
  reach the from-scratch run's final val loss (`tile_val_loss` over a
  fixed set of base-corpus batches) within 0.5x its steps (the
  TLP-style claim that makes per-round retraining affordable).

  PYTHONPATH=src python benchmarks/bench_flywheel.py
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.model import cost_model_init
from repro.core.simulator import TPUSimulator
from repro.data.fusion import apply_fusion, default_fusion
from repro.data.sampler import TileBatchSampler
from repro.data.store import StreamingCorpus, pack_record, spec_hash, \
    write_corpus
from repro.data.synthetic import generate_corpus, random_kernel
from repro.data.tile_dataset import build_tile_records, enumerate_tiles, \
    fit_tile_normalizer
from repro.flywheel import FlywheelConfig, MeasurementLog, run_flywheel
from repro.flywheel.loop import deploy_regret, static_plan
from repro.flywheel.retrain import fine_tune
from repro.search import LearnedEstimator
from repro.training.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.trainer import CostModelTrainer, TrainerConfig

from common import CACHE_DIR, SCALE, Gate, emit_json, paper_tile_model, steps

N_PROGRAMS = max(int(20 * SCALE), 10)
CORPUS_CONFIGS = 16            # measured tiles per base-corpus kernel
POOL = 20                      # candidate target kernels to pick from
N_TARGETS = 6                  # hard-set size (scale-independent: gates)
TARGET_NODES = 16
N_CANDIDATES = 32              # enumerated tiles per target kernel
ROUNDS = 3
PER_KERNEL = 3                 # hardware evals per target kernel, total
MIN_HARD_REGRET = 0.005        # a target must cost the static model this
# Deliberately NOT scaled: the regret gate needs an *unsaturated* static
# model (a converged one already ranks this simulator's tile sweeps
# near-perfectly, leaving the loop no headroom to demonstrate anything —
# and no reason to exist); 120 steps is the mid-training regime a
# flywheel is deployed in, at any BENCH_SCALE.
STATIC_STEPS = 120
# Also deliberately NOT scaled (the `steps()` scaling is for workloads,
# not for the loop regime under test): more fine-tune steps past ~120
# just converge scratch and warm-start alike onto the corpus noise
# floor, where the warm-start speedup ratio — and the re-ranking edge
# the regret gate measures — both wash out. BENCH_SCALE scales the
# *world* (programs, corpus size); the loop constants are the system.
FT_STEPS = 120                 # per-round fine-tune inside the loop
WARM_STEPS = 150               # warm-start-vs-scratch gate runs


def train_static(base_records, norm, mc, n_steps: int):
    """Train (or load cached) the static round-0 model on the base
    corpus. Unlike `common.train_cost_model` this saves params AND the
    AdamW state — the warm-start gate restores the moments too."""
    key = spec_hash({"flywheel_static": 1, "model": mc.to_dict(),
                     "steps": n_steps, "scale": SCALE,
                     "records": len(base_records)})
    ckpt_dir = os.path.join(CACHE_DIR, "flywheel", key)
    template = {"params": cost_model_init(jax.random.key(0), mc)}
    template["opt"] = adamw_init(template["params"])
    if latest_step(ckpt_dir) is not None:
        state, _, _ = restore_checkpoint(ckpt_dir, template)
        return state["params"], ckpt_dir
    sampler = TileBatchSampler(base_records, norm, kernels_per_batch=4,
                               configs_per_kernel=8,
                               max_nodes=mc.max_nodes)
    tc = TrainerConfig(task="tile", steps=n_steps, ckpt_every=0,
                       log_every=max(n_steps // 4, 1),
                       optim=AdamWConfig(lr=2e-3, schedule="exponential",
                                         lr_decay=0.9,
                                         decay_every=max(n_steps // 4, 1)))
    tr = CostModelTrainer(mc, tc, sampler)
    t0 = time.time()
    tr.run(resume=False)
    print(f"    trained static model {n_steps} steps in "
          f"{time.time() - t0:.0f}s", file=sys.stderr)
    save_checkpoint(ckpt_dir, n_steps,
                    {"params": tr.params, "opt": tr.opt_state})
    return tr.params, ckpt_dir


def pick_hard_targets(scores, truth, per_kernel: int):
    """Indices of the pool kernels where the static model's top-k
    exploitation does worst — descending deploy regret at `per_kernel`
    measured picks (the kernels a flywheel is for). Kernels the static
    model already solves (regret < MIN_HARD_REGRET) are dead weight for
    the comparison — the loop can at best tie there — so they only fill
    the set when the pool has too few genuinely hard ones."""
    regrets = []
    for s, t in zip(scores, truth):
        picks = np.argsort(np.asarray(s), kind="stable")[:per_kernel]
        regrets.append(float(np.min(t[picks]) / np.min(t) - 1.0))
    order = sorted(range(len(scores)), key=lambda i: (-regrets[i], i))
    hard = [i for i in order if regrets[i] >= MIN_HARD_REGRET]
    return (hard[:N_TARGETS] or order[:N_TARGETS]), regrets


def record_blob(rec) -> str:
    """Canonical transit form of one record (dedup key, payload JSON,
    float64 runtimes) — the byte-identity the parity gate compares."""
    return json.dumps(pack_record("tile", rec), sort_keys=True)


def replay_delta_records(rounds, groups):
    """Rebuild each round's raw delta records from the acquisition
    stream, in round order: ONE log fed round by round, taking the
    pending cumulative sweeps after each — exactly what the loop's
    per-round `MeasurementLog.flush_to` appended."""
    out = []
    ml = MeasurementLog("tile")
    for r in rounds:
        for gi, ci, rt in (r.acquired or []):
            ml.record(groups[gi][ci], rt)
        out.extend(ml.take_pending(min_configs=1))
    return out


def first_step_reaching(history, target: float):
    """First (step, val) entry at or below `target`; None if never."""
    for step, val in history:
        if val <= target:
            return step
    return None


def main() -> int:
    t_start = time.perf_counter()
    sim = TPUSimulator()
    mc = paper_tile_model()

    # --- base corpus store (dedup=True: the chain the deltas extend) ---
    programs = generate_corpus(N_PROGRAMS, seed=0)
    kernels = [k for p in programs
               for k in apply_fusion(p, default_fusion(p))]
    base_records = build_tile_records(
        kernels, sim, max_configs_per_kernel=CORPUS_CONFIGS, seed=0)
    work = tempfile.mkdtemp(prefix="bench_flywheel_")
    store_dir = os.path.join(work, "store")
    write_corpus(store_dir, "tile", base_records, dedup=True)
    base = StreamingCorpus.open(store_dir)
    base_list = list(base)
    norm = fit_tile_normalizer(base_list)
    print(f"bench_flywheel: base store {len(base_list)} records "
          f"({len(kernels)} kernels, {N_PROGRAMS} programs)")

    params0, static_ckpt = train_static(base_list, norm, mc,
                                        STATIC_STEPS)

    # --- hard target set: where the static model's ranking is worst ---
    pool = [random_kernel(TARGET_NODES, seed=7000 + i,
                          program=f"fw_target_{i}")
            for i in range(POOL)]
    pool_tiles = [enumerate_tiles(k, max_configs=N_CANDIDATES)
                  for k in pool]
    pool_groups = [[k.with_tile(t) for t in ts]
                   for k, ts in zip(pool, pool_tiles)]
    static_est = LearnedEstimator.from_params(
        params0, mc, norm, max_nodes=mc.max_nodes, cache_capacity=0)
    pool_scores = static_est.estimate_groups(pool_groups)
    pool_truth = [np.array([sim.measure(g) for g in grp], np.float64)
                  for grp in pool_groups]
    hard, pool_regrets = pick_hard_targets(pool_scores, pool_truth,
                                           PER_KERNEL)
    targets = [pool[i] for i in hard]
    tiles = [pool_tiles[i] for i in hard]
    groups = [pool_groups[i] for i in hard]
    budget = PER_KERNEL * len(targets)
    print(f"  hard set: {[f'fw_target_{i}' for i in hard]} "
          f"(static top-{PER_KERNEL} regrets "
          f"{[round(pool_regrets[i], 3) for i in hard]})")

    # --- the flywheel vs the static plan, equal total budget ---
    fc = FlywheelConfig(rounds=ROUNDS, budget_evals=budget,
                        finetune_steps=FT_STEPS, warmup_steps=20,
                        mc_samples=8, spread="kernel", seed=0,
                        max_configs=N_CANDIDATES)
    res = run_flywheel(sim, store_dir, targets, params0, mc, norm, fc,
                       ckpt_dir=os.path.join(work, "rounds"),
                       tiles=tiles)
    scores0 = [pool_scores[i] for i in hard]
    static_regret = deploy_regret(res.truth, scores0,
                                  static_plan(scores0, budget))
    fly_regret = res.final_regret
    print(f"  static plan @ {budget} evals: regret {static_regret:.4f}")
    for r in res.rounds:
        print(f"  round {r.round}: +{r.measured} evals "
              f"(+{r.delta_records} delta records) -> "
              f"regret {r.regret:.4f}")
    print(f"  flywheel charged {res.evals_charged}/{budget} evals; "
          f"model-pick-only (no measurements) regret {res.regret0:.4f}")
    if os.environ.get("BENCH_FW_DEBUG"):
        final_est = LearnedEstimator.from_params(
            res.params, mc, norm, max_nodes=mc.max_nodes,
            cache_capacity=0)
        final_scores = final_est.estimate_groups(groups)
        splan = static_plan(scores0, budget)
        for gi, t in enumerate(res.truth):
            best = float(np.min(t))
            def reg(ci):
                return float(t[int(ci)]) / best - 1.0
            s_meas = sorted(reg(ci) for ci in splan[gi])
            f_meas = sorted(reg(ci) for ci in res.measured[gi])
            pick = int(np.argmin(final_scores[gi]))
            print(f"    [dbg] g{gi} true-best@{int(np.argmin(t))} "
                  f"static-meas {s_meas} | fly-meas {f_meas} "
                  f"fly-pick@{pick} regret {reg(pick):.4f}")

    # --- delta-chain parity: chained view == from-scratch rebuild ---
    chained = StreamingCorpus.open(store_dir).with_deltas()
    rebuild_dir = os.path.join(work, "rebuild")
    write_corpus(rebuild_dir, "tile",
                 base_records + replay_delta_records(res.rounds, groups),
                 dedup=True)
    rebuilt = list(StreamingCorpus.open(rebuild_dir))
    parity = (len(chained) == len(rebuilt)
              and all(record_blob(a) == record_blob(b)
                      for a, b in zip(chained, rebuilt)))
    print(f"  delta parity: chained {len(chained)} records "
          f"({chained.num_deltas} deltas) vs rebuild {len(rebuilt)} "
          f"-> {'identical' if parity else 'MISMATCH'}")

    # --- warm-start vs from-scratch on the chained corpus. The val
    # yardstick is a fixed set of base-corpus batches (tile_val_loss's
    # batch-purity trick): "reaches the static model's quality" is a
    # base-domain statement, and it is exactly where restoring params +
    # AdamW moments should land the run near-converged at step 0 ---
    val_sampler = TileBatchSampler(base_list, norm, kernels_per_batch=4,
                                   configs_per_kernel=8,
                                   max_nodes=mc.max_nodes, seed=123)
    eval_every = max(WARM_STEPS // 10, 1)
    init_dir = os.path.join(work, "init")
    p_init = cost_model_init(jax.random.key(1), mc)
    save_checkpoint(init_dir, 0, {"params": p_init,
                                  "opt": adamw_init(p_init)})
    scratch = fine_tune(chained, norm, mc, warm_start_dir=init_dir,
                        steps=WARM_STEPS, lr=1e-3, warmup_steps=20,
                        seed=5, val_sampler=val_sampler,
                        eval_every=eval_every)
    scratch_val = scratch.val_history[-1][1]
    warm = fine_tune(chained, norm, mc, warm_start_dir=static_ckpt,
                     steps=WARM_STEPS, lr=1e-3, warmup_steps=20,
                     seed=5, val_sampler=val_sampler,
                     eval_every=eval_every)
    match = first_step_reaching(warm.val_history, scratch_val)
    ratio = (match / WARM_STEPS) if match is not None else 2.0
    print(f"  scratch {WARM_STEPS} steps -> val {scratch_val:.4f}; "
          f"warm-start reaches it at step "
          f"{match if match is not None else 'NEVER'} "
          f"(ratio {ratio:.2f})")

    ok = emit_json(
        "flywheel",
        [Gate("regret_margin",
              round(static_regret - fly_regret, 6), 0.0, ">"),
         Gate("delta_stream_parity", bool(parity), True, "=="),
         Gate("warm_start_steps_ratio", round(ratio, 4), 0.5, "<=")],
        wall_s=time.perf_counter() - t_start,
        extra={"static_regret": round(static_regret, 5),
               "flywheel_regret": round(fly_regret, 5),
               "regret_no_measure": round(res.regret0, 5),
               "round_regrets": [round(r.regret, 5) for r in res.rounds],
               "budget_evals": budget,
               "evals_charged": res.evals_charged,
               "delta_records": [r.delta_records for r in res.rounds],
               "chained_records": len(chained),
               "scratch_final_val": round(scratch_val, 5),
               "warm_val_history": [[s, round(v, 5)]
                                    for s, v in warm.val_history],
               "hard_targets": [f"fw_target_{i}" for i in hard],
               "scale": SCALE})
    print(f"bench_flywheel: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
