"""Data-parallel mesh training: scaling efficiency, dp=1 bit-parity,
sharded-stream identity, cross-layout checkpoint restore (DESIGN.md §13;
acceptance gates for ISSUE 8).

Four properties of the mesh train step, each a machine-readable gate in
BENCH_scaling.json:

  1. dp=1 bit-parity      — `TrainerConfig(dp=1)` (mesh step: shard_map,
                            psum, GlobalBatchSampler) reproduces the legacy
                            jit path EXACTLY: same final loss float and
                            byte-identical params after N steps.
  2. scaling efficiency   — train-step throughput at dp=2 over dp=1, both
                            on forced host CPU devices
                            (XLA_FLAGS=--xla_force_host_platform_device_
                            count). dp=2 consumes two per-device batches
                            per step, so perfect scaling is 2.0x and the
                            ISSUE-8 gate is 1.7x (>= 85% per-device
                            efficiency).
  3. stream identity      — the union of `StreamingCorpus.shard(i, W)`
                            worker views, position-interleaved, is
                            byte-identical to the unsharded stream (same
                            record keys, same runtime arrays, disjoint,
                            exhaustive) for several W.
  4. checkpoint elasticity — a checkpoint written while training under
                            dp=2 restores under dp=1 with bit-exact params
                            at the saved step.

Like bench_corpus, the scaling threshold is calibrated, not assumed:
`cpu_count` lies on quota'd containers, so the bench first measures the
host's parallel capacity with fork-pool spin workers (before jax loads)
and gates at min(1.7, max(1.0, 0.85 * capacity)) — multi-core CI runners
(capacity ~3-4) get the full 1.7x gate; a 1-core dev box degrades to
"two devices must not be slower than their work serialized". Step times
are interleaved best-of-2 trials. The measured capacity and threshold are
recorded in BENCH_scaling.json.

Every training/restore measurement runs in a subprocess (this file
re-invokes itself with --worker) because the forced device count is fixed
at jax import; the parent stays jax-free until the corpus pools and spin
workers are done.

`BENCH_SCALE` scales the number of timed steps (model and batch shapes
are fixed — scaling efficiency at a smaller model would measure dispatch
overhead, not the data path).

  PYTHONPATH=src python benchmarks/bench_scaling.py
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
WARM_STEPS = 3
TIMED_STEPS = max(int(16 * SCALE), 6)
PARITY_STEPS = 6
STREAM_PROGRAMS = max(int(12 * SCALE), 8)
STREAM_WORKER_COUNTS = (2, 3, 5)
EFF_CAP = 1.7             # ISSUE-8 number: >= 85% of perfect 2.0x
KERNEL_NODES = (24, 30, 28, 22, 26, 32, 20, 34)


# ---------------------------------------------------------------- capacity
def _spin(seconds: float) -> int:
    t0 = time.perf_counter()
    n = 0
    while time.perf_counter() - t0 < seconds:
        n += 1
    return n


def parallel_capacity(workers: int, window: float = 0.5) -> float:
    """Measured speedup ceiling of this host (see bench_corpus): total
    spin throughput of `workers` fork-pool processes over one process's."""
    import multiprocessing
    one = _spin(window)
    with multiprocessing.get_context("fork").Pool(workers) as pool:
        many = sum(pool.map(_spin, [window] * workers))
    return many / max(one, 1)


# ---------------------------------------------------------------- worker
def _build_sampler():
    """Deterministic training set shared by every worker invocation."""
    from repro.core.simulator import TPUSimulator
    from repro.data.synthetic import random_kernel
    from repro.data.tile_dataset import build_tile_records, \
        fit_tile_normalizer
    from repro.data.sampler import TileBatchSampler

    sim = TPUSimulator()
    kernels = [random_kernel(n, seed=i)
               for i, n in enumerate(KERNEL_NODES)]
    recs = build_tile_records(kernels, sim, max_configs_per_kernel=16)
    norm = fit_tile_normalizer(recs)
    return TileBatchSampler(recs, norm, seed=3, adjacency="sparse",
                            kernels_per_batch=4, configs_per_kernel=8)


def _params_sha(params) -> str:
    import jax
    import numpy as np
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _worker_train(args) -> dict:
    from repro.core.model import CostModelConfig
    from repro.training.trainer import CostModelTrainer, TrainerConfig

    mcfg = CostModelConfig(hidden_dim=96, gnn_layers=3, adjacency="sparse")
    cfg = TrainerConfig(task="tile", steps=args.warm, log_every=10 ** 6,
                        ckpt_every=args.steps if args.ckpt_dir else 0,
                        ckpt_dir=args.ckpt_dir, seed=0, dp=args.dp,
                        prefetch=2)
    trainer = CostModelTrainer(mcfg, cfg, _build_sampler())
    trainer.run(resume=False)                    # warmup incl. compile
    t0 = time.perf_counter()
    trainer.cfg.steps = args.steps
    res = trainer.run(resume=False)
    dt = time.perf_counter() - t0
    return {"step": res["step"], "loss": res["loss"],
            "step_s": dt / max(args.steps - args.warm, 1),
            "params_sha": _params_sha(trainer.params)}


def _worker_restore(args) -> dict:
    from repro.core.model import CostModelConfig
    from repro.training.trainer import CostModelTrainer, TrainerConfig

    mcfg = CostModelConfig(hidden_dim=96, gnn_layers=3, adjacency="sparse")
    cfg = TrainerConfig(task="tile", steps=args.steps, log_every=10 ** 6,
                        ckpt_dir=args.ckpt_dir, seed=0, dp=args.dp)
    trainer = CostModelTrainer(mcfg, cfg, _build_sampler())
    resumed = trainer.maybe_resume()
    return {"resumed": resumed, "step": trainer.step,
            "params_sha": _params_sha(trainer.params)}


def _run_worker(mode: str, *, dp: int, devices: int, steps: int,
                warm: int = WARM_STEPS, ckpt_dir: str = "") -> dict:
    """Re-invoke this file with a forced device count; last stdout line is
    the worker's JSON result."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               JAX_PLATFORMS="cpu")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker", mode,
           "--dp", str(dp), "--steps", str(steps), "--warm", str(warm),
           "--ckpt-dir", ckpt_dir]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          cwd=os.path.dirname(os.path.abspath(__file__)))
    if proc.returncode != 0:
        raise RuntimeError(f"worker {mode} dp={dp} failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------- streams
def stream_identity(root: str) -> bool:
    """Shard views are disjoint, exhaustive, and their position interleave
    is byte-identical to the unsharded stream."""
    import numpy as np
    from repro.data.store import StreamingCorpus, record_key

    corpus = StreamingCorpus.open(os.path.join(root, "tile"))
    full = list(corpus)
    ok = len(full) == len(corpus)
    for w in STREAM_WORKER_COUNTS:
        shards = [corpus.shard(i, w) for i in range(w)]
        ok &= sum(len(s) for s in shards) == len(full)
        keys = [record_key(r) for sh in shards for r in sh]
        ok &= len(set(keys)) == len(keys)                    # disjoint
        for k, rec in enumerate(full):                       # interleave
            got = shards[k % w][k // w]
            ok &= (record_key(got) == record_key(rec)
                   and np.array_equal(got.runtimes, rec.runtimes)
                   and got.program == rec.program)
    # shard(0, 1) is the identity view over the same parent cache
    s01 = corpus.shard(0, 1)
    ok &= len(s01) == len(corpus) and all(
        record_key(a) == record_key(b) for a, b in zip(s01, corpus))
    return ok


def main() -> int:
    t_start = time.perf_counter()
    assert "jax" not in sys.modules, \
        "bench_scaling must measure capacity and fork corpus pools " \
        "before jax loads"
    capacity = parallel_capacity(2)
    eff_gate = min(EFF_CAP, max(1.0, 0.85 * capacity))
    print(f"bench_scaling: timed_steps={TIMED_STEPS}, "
          f"{os.cpu_count()} cpus, measured parallel capacity "
          f"{capacity:.2f}x -> efficiency gate >= {eff_gate:.2f}x")

    root = tempfile.mkdtemp(prefix="bench_scaling_")
    try:
        # --- 3. sharded-stream identity (store pools fork: jax-free) ------
        from repro.launch.build_corpus import DEFAULT_TILE, build_corpus
        build_corpus(os.path.join(root, "corpus"), kinds=("tile",),
                     programs=STREAM_PROGRAMS, seed=0, workers=2,
                     tile_opts=dict(DEFAULT_TILE, max_configs_per_kernel=8),
                     quiet=True)
        stream_ok = stream_identity(os.path.join(root, "corpus"))
        print(f"  shard union byte-identical to unsharded stream "
              f"(W={STREAM_WORKER_COUNTS}): {stream_ok}")

        # --- 1. dp=1 mesh step is bit-identical to the legacy jit path ----
        legacy = _run_worker("train", dp=0, devices=1, steps=PARITY_STEPS,
                             warm=0)
        mesh1p = _run_worker("train", dp=1, devices=1, steps=PARITY_STEPS,
                             warm=0)
        parity = (legacy["params_sha"] == mesh1p["params_sha"]
                  and legacy["loss"] == mesh1p["loss"])
        print(f"  dp=1 bit-parity with legacy path: {parity} "
              f"(loss {legacy['loss']:.6f} vs {mesh1p['loss']:.6f})")

        # --- 2. throughput scaling dp=1 -> dp=2 (interleaved best-of-2) ---
        ckpt_dir = os.path.join(root, "ckpt_dp2")
        t1 = t2 = float("inf")
        for trial in range(2):
            r2 = _run_worker("train", dp=2, devices=2, steps=TIMED_STEPS,
                             ckpt_dir=ckpt_dir if trial == 0 else "")
            r1 = _run_worker("train", dp=1, devices=1, steps=TIMED_STEPS)
            t1, t2 = min(t1, r1["step_s"]), min(t2, r2["step_s"])
            if trial == 0:
                dp2_sha, dp2_step = r2["params_sha"], r2["step"]
        efficiency = 2.0 * t1 / t2       # dp=2 consumes 2 batches/step
        print(f"  step time dp=1 {t1 * 1e3:.0f}ms, dp=2 {t2 * 1e3:.0f}ms "
              f"-> {efficiency:.2f}x throughput (best of 2, perfect = 2.0)")

        # --- 4. dp=2 checkpoint restores under dp=1, params bit-exact -----
        rr = _run_worker("restore", dp=1, devices=1, steps=TIMED_STEPS,
                         ckpt_dir=ckpt_dir)
        ckpt_ok = (rr["resumed"] and rr["step"] == dp2_step
                   and rr["params_sha"] == dp2_sha)
        print(f"  dp=2 checkpoint -> dp=1 restore bit-exact at step "
              f"{rr['step']}: {ckpt_ok}")

        from common import Gate, emit_json
        ok = emit_json(
            "scaling",
            [Gate("dp1_bit_parity", parity, True, "=="),
             Gate("scaling_efficiency_dp2", efficiency, eff_gate),
             Gate("shard_union_identity", stream_ok, True, "=="),
             Gate("ckpt_dp2_to_dp1", ckpt_ok, True, "==")],
            wall_s=time.perf_counter() - t_start,
            extra={"parallel_capacity": round(capacity, 2),
                   "efficiency_gate": round(eff_gate, 2),
                   "step_s_dp1": round(t1, 4),
                   "step_s_dp2": round(t2, 4),
                   "timed_steps": TIMED_STEPS,
                   "legacy_loss": legacy["loss"],
                   "mesh_dp1_loss": mesh1p["loss"],
                   "stream_worker_counts": list(STREAM_WORKER_COUNTS)})
        print(f"bench_scaling: {'PASS' if ok else 'FAIL'} "
              f"(need bit-parity, >={eff_gate:.2f}x, stream identity, "
              f"elastic ckpt; got {parity} / {efficiency:.2f}x / "
              f"{stream_ok} / {ckpt_ok})")
        return 0 if ok else 1
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", choices=("train", "restore"), default="")
    ap.add_argument("--dp", type=int, default=0)
    ap.add_argument("--steps", type=int, default=TIMED_STEPS)
    ap.add_argument("--warm", type=int, default=WARM_STEPS)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.worker == "train":
        print(json.dumps(_worker_train(args)))
    elif args.worker == "restore":
        print(json.dumps(_worker_restore(args)))
    else:
        raise SystemExit(main())
