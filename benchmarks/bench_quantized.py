"""Int8 quantized serving vs f32 (DESIGN.md §14; acceptance gates for the
quantized inference path).

Trains the paper's tile + fusion models (cached via common.train_cost_model),
quantizes them per-channel (`repro.quant.quantize_params`, calibrated on a
test-split sample), and replays the serving hot path — every (kernel, tile)
candidate of the test tile records scored through the sparse packed forward
(`core.evaluate.predict_kernels`) — under both precisions on warm jit
executables.

Gates:

* ``throughput_ratio`` — int8 vs f32 scoring throughput, gated at a
  machine-calibrated threshold (the bench_corpus / bench_scaling idiom):
  ``min(1.5, max(0.85, 0.7 * int8_capacity))`` where ``int8_capacity`` is
  this host's *measured* int8-vs-f32 matmul throughput ratio
  (`int8_capacity_ratio`). On int8-capable hardware (TPU MXU, VNNI-class
  CPUs) capacity is >=2 and the full 1.5x contract binds. This CI
  container's CPU backend executes int8 ``dot_general`` ~5-6x *slower*
  than f32 (measured capacity ~0.2), so there the int8 model serves as
  int8-in-memory weights decoded inside jit (one fused multiply per leaf)
  into f32 compute — measured ~0.89-0.95x of f32 on the small per-request
  flush packs of this stream, the per-call decode cost. The 0.85x floor
  keeps the gate binding for what can actually regress: accidentally
  routing int8 ``dot_general`` onto this backend would measure ~0.2x and
  fail loudly.
* ``weight_bytes_ratio`` — quantized parameter bytes / f32 bytes <= 0.35
  (machine-independent: the ~4x memory/bandwidth win is the point).
* ``prediction_delta_rel`` — max |int8 - f32| prediction over the whole
  stream, relative to the f32 prediction spread (std). Measured ~0.02-0.05
  on trained models; gated at 0.25.
* ``tile_regret_excess`` — tile-selection regret (runtime of the
  argmin-predicted tile / best runtime - 1, averaged over test kernels)
  must be no worse than f32's + 0.01.
* ``tile_kendall_drop`` / ``fusion_kendall_drop`` — rank fidelity
  (Kendall's tau against true runtimes; the quantity search consumes)
  within 0.02 of f32. The fusion side scores through
  `LearnedEstimator.from_params(QuantizedCostModel, ...)`, pinning the
  estimator integration.

  PYTHONPATH=src python benchmarks/bench_quantized.py
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.evaluate import eval_fusion_task, kendall_tau, \
    learned_runtime_predictor, make_predict_fn, predict_kernels
from repro.core.model import CostModelConfig
from repro.quant.quantize import quantize_params, tree_bytes

from common import (
    MAX_NODES,
    SCALE,
    Gate,
    build_world,
    emit_json,
    paper_fusion_model,
    paper_tile_model,
    steps,
    train_cost_model,
)

N_TILE_RECORDS = max(int(24 * SCALE), 8)
TIMING_ROUNDS = 3


def int8_capacity_ratio(n: int = 256, iters: int = 30) -> float:
    """Measured int8-vs-f32 matmul throughput ratio of this host (>1 means
    int8 compute is faster). The `parallel_capacity` idiom from
    bench_corpus: calibrate the gate to what the machine can do instead of
    assuming CI hardware."""
    rng = np.random.default_rng(0)
    a32 = jnp.asarray(rng.normal(0, 1, (n, n)), jnp.float32)
    a8 = jnp.asarray(rng.integers(-127, 128, (n, n)), jnp.int8)
    dims = (((1,), (0,)), ((), ()))
    mm32 = jax.jit(lambda x: jax.lax.dot_general(x, x, dims))
    mm8 = jax.jit(lambda x: jax.lax.dot_general(
        x, x, dims, preferred_element_type=jnp.int32))
    mm32(a32).block_until_ready()
    mm8(a8).block_until_ready()

    def clock(f, x):
        t0 = time.perf_counter()
        for _ in range(iters):
            f(x).block_until_ready()
        return time.perf_counter() - t0

    return clock(mm32, a32) / clock(mm8, a8)


def throughput_threshold(capacity: float) -> float:
    """min(1.5, max(0.85, 0.7 * capacity)): the full-scale 1.5x int8
    serving contract where int8 compute is fast, a >=0.85x no-regression
    floor where it is not (weights still shrink ~4x there; the few percent
    under 1.0 is the per-call weight-decode cost on small flush packs).

    >>> throughput_threshold(3.0)
    1.5
    >>> throughput_threshold(1.6)        # marginal int8 hardware
    1.12
    >>> throughput_threshold(0.2)        # this container's CPU
    0.85
    """
    return round(min(1.5, max(0.85, 0.7 * capacity)), 4)


def _regret(pred: np.ndarray, runtimes: np.ndarray) -> float:
    """Tile-selection regret: chosen-vs-best true runtime excess."""
    chosen = int(np.argmin(pred))
    best = float(np.min(runtimes))
    return float(runtimes[chosen]) / max(best, 1e-12) - 1.0


def main() -> int:
    t_start = time.perf_counter()
    capacity = int8_capacity_ratio()
    thr = throughput_threshold(capacity)
    print(f"bench_quantized: int8 matmul capacity {capacity:.2f}x f32 -> "
          f"throughput gate >={thr:.2f}x")

    world = build_world()
    norm = world.normalizers["random"]
    mc_tile = paper_tile_model()
    params = train_cost_model(world, mc_tile, task="tile",
                              n_steps=steps(1500))
    recs = world.tile_records("random", "test")[:N_TILE_RECORDS]
    requests = [[r.kernel.with_tile(t) for t in r.tiles] for r in recs]
    n_queries = sum(len(r) for r in requests)
    calib = [g for req in requests[:4] for g in req]

    cfg32 = CostModelConfig.from_dict(
        dict(mc_tile.to_dict(), adjacency="sparse", dropout=0.0))
    qm = quantize_params(params, cfg32, calib_graphs=calib, normalizer=norm)
    cfg8 = qm.serving_config()
    bytes32, bytes8 = tree_bytes(params), qm.quantized_bytes()
    wratio = bytes8 / bytes32
    print(f"  weights: {bytes32} B f32 -> {bytes8} B int8 "
          f"({wratio:.2f}x, {qm.num_quantized} leaves quantized)")

    fn32, fn8 = make_predict_fn(cfg32), make_predict_fn(cfg8)

    def direct(ps, cfg, fn):
        def score(graphs):
            return predict_kernels(ps, cfg, graphs, norm,
                                   max_nodes=MAX_NODES, predict_fn=fn)
        return score

    d32 = direct(params, cfg32, fn32)
    d8 = direct(qm.params, cfg8, fn8)

    def replay(score, reps=1):
        t0 = time.perf_counter()
        for _ in range(reps):
            preds = [np.asarray(score(req)) for req in requests]
        return preds, (time.perf_counter() - t0) / reps

    # steady-state serving comparison: warm every packed bucket shape for
    # BOTH paths before timing (BENCH_SCALE notes in common.py — an
    # unwarmed path gets charged its bucket compiles and the ratio is
    # meaningless); then size the timed window to >=0.5s of work so a
    # single scheduler hiccup cannot flip a ~0.9x ratio gate
    preds32, t_once = replay(d32)
    preds8, _ = replay(d8)
    reps = max(1, int(np.ceil(0.5 / max(t_once, 1e-3))))
    t32 = min(replay(d32, reps)[1] for _ in range(TIMING_ROUNDS))
    t8 = min(replay(d8, reps)[1] for _ in range(TIMING_ROUNDS))
    ratio = t32 / t8
    print(f"  f32  {n_queries / t32:8.0f} queries/s ({t32:.3f}s)")
    print(f"  int8 {n_queries / t8:8.0f} queries/s ({t8:.3f}s)  "
          f"-> {ratio:.2f}x")

    flat32 = np.concatenate(preds32)
    flat8 = np.concatenate(preds8)
    delta_rel = float(np.max(np.abs(flat32 - flat8))
                      / max(float(np.std(flat32)), 1e-9))
    reg32 = float(np.mean([_regret(p, np.asarray(r.runtimes))
                           for p, r in zip(preds32, recs)]))
    reg8 = float(np.mean([_regret(p, np.asarray(r.runtimes))
                          for p, r in zip(preds8, recs)]))
    k32 = float(np.mean([kendall_tau(p, np.asarray(r.runtimes))
                         for p, r in zip(preds32, recs)]))
    k8 = float(np.mean([kendall_tau(p, np.asarray(r.runtimes))
                        for p, r in zip(preds8, recs)]))
    print(f"  prediction delta {delta_rel:.3f} (rel std); tile regret "
          f"f32={reg32:.4f} int8={reg8:.4f}; kendall f32={k32:.3f} "
          f"int8={k8:.3f}")

    # fusion: rank fidelity through the estimator path (QuantizedCostModel
    # straight into LearnedEstimator.from_params)
    mc_f = paper_fusion_model()
    params_f = train_cost_model(world, mc_f, task="fusion",
                                n_steps=steps(1500))
    cfg_f = CostModelConfig.from_dict(
        dict(mc_f.to_dict(), adjacency="sparse", dropout=0.0))
    qm_f = quantize_params(params_f, cfg_f)
    fds = world.fusion_subset("random", "test")
    ev32 = eval_fusion_task(fds, learned_runtime_predictor(
        params_f, cfg_f, norm, max_nodes=MAX_NODES))
    ev8 = eval_fusion_task(fds, learned_runtime_predictor(
        qm_f, cfg_f, norm, max_nodes=MAX_NODES))
    fk32, fk8 = ev32["mean_kendall"], ev8["mean_kendall"]
    print(f"  fusion kendall f32={fk32:.3f} int8={fk8:.3f}")

    ok = emit_json(
        "quantized",
        [Gate("throughput_ratio", round(ratio, 4), thr),
         Gate("weight_bytes_ratio", round(wratio, 4), 0.35, "<="),
         Gate("prediction_delta_rel", round(delta_rel, 4), 0.25, "<="),
         Gate("tile_regret_excess", round(reg8 - reg32, 4), 0.01, "<="),
         Gate("tile_kendall_drop", round(k32 - k8, 4), 0.02, "<="),
         Gate("fusion_kendall_drop", round(fk32 - fk8, 4), 0.02, "<=")],
        wall_s=time.perf_counter() - t_start,
        extra={"int8_capacity": round(capacity, 3),
               "throughput_threshold": thr,
               "f32_qps": round(n_queries / t32, 1),
               "int8_qps": round(n_queries / t8, 1),
               "weight_bytes_f32": bytes32, "weight_bytes_int8": bytes8,
               "num_quantized_leaves": qm.num_quantized,
               "tile_regret_f32": round(reg32, 5),
               "tile_regret_int8": round(reg8, 5),
               "scale": SCALE})
    print(f"bench_quantized: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
