"""Cached + coalescing serving vs uncached per-request scoring
(docs/SERVING.md; acceptance gate for the prediction service).

Replays a deterministic tile-search query stream — several search rounds
per kernel over overlapping candidate subsets, the revisit pattern of
top-k re-ranking and annealing (`repro.serving.replay`) — two ways:

  * direct  — `core.evaluate.predict_kernels` per request (encode + score
    every query every time; the pre-serving behavior of every call site),
  * service — `CostModelService` (content-addressed cache + coalescer +
    bucketed sparse flushes).

Both run on warm jit executables: the benchmark itself replays the full
query stream once per path before timing (each path can produce different
BucketSpecs, so each warms its own) — without this the service run gets
charged every bucket compile and can look slower than direct. PASS
requires the service to reach >=2x the direct throughput with max
prediction delta <1e-4 (features go through a fitted FeatureNormalizer —
unnormalized f32 features lose the tolerance to summation-order effects).

Margins (see BENCH_SCALE semantics in benchmarks/common.py): ~2.07x at
BENCH_SCALE=0.5 — scaled runs gate against the calibrated
`service_speedup_threshold(scale)` instead of the full-scale 2x, so the
gate stays *binding* at every scale (previously a sub-1.0 scale only
printed a warning and still gated at 2x). Since PR 3 the
shared structural EncodeCache also accelerates the *direct* baseline
(tile sweeps no longer re-encode per config), which narrows the
full-scale margin from ~3.4x to ~2.6x — the gate measures caching of
*predictions* + coalescing on top of cached *encodes*.

  PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.evaluate import make_predict_fn, predict_kernels
from repro.core.model import CostModelConfig, cost_model_init
from repro.serving import CostModelService
from repro.serving.replay import build_tile_replay, run_replay

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
NUM_PROGRAMS = max(int(6 * SCALE), 3)
MAX_CONFIGS = 16
ROUNDS = 4
SUBSET = 0.75


def service_speedup_threshold(scale: float) -> float:
    """Calibrated gate threshold for `service_speedup` at a given
    BENCH_SCALE (same idea as bench_corpus's capacity-aware gate: a scaled
    run keeps a *binding* gate instead of a warning nobody reads).

    At scale>=1.0 the stream is large enough to amortize per-request
    overhead and the full 2x contract applies. Smaller scales shrink the
    revisit stream (fewer programs -> fewer duplicate queries -> lower hit
    rate), so the achievable speedup degrades roughly with the scale
    deficit; measured: ~2.6x at 1.0, ~2.07x at 0.5. The floor of 1.25x
    keeps the gate meaningful at any scale: the service must always beat
    direct scoring, warm-cache or not.

    >>> service_speedup_threshold(1.0)
    2.0
    >>> service_speedup_threshold(2.0)
    2.0
    >>> service_speedup_threshold(0.5)
    1.5
    >>> service_speedup_threshold(0.0)
    1.25
    """
    if scale >= 1.0:
        return 2.0
    return max(1.25, 2.0 - (1.0 - scale))


def main() -> int:
    import time
    t_start = time.perf_counter()
    threshold = service_speedup_threshold(SCALE)
    if SCALE < 1.0:
        print(f"[info] BENCH_SCALE={SCALE}: gating service_speedup at the "
              f"calibrated {threshold:.2f}x instead of the full-scale 2x "
              "(see service_speedup_threshold)", file=sys.stderr)
    replay = build_tile_replay(NUM_PROGRAMS, max_configs=MAX_CONFIGS,
                               rounds=ROUNDS, subset=SUBSET, seed=0)
    max_nodes = max(g.num_nodes for r in replay.requests for g in r)
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=48, opcode_embed_dim=16, dropout=0.0,
                          max_nodes=max_nodes, adjacency="sparse")
    params = cost_model_init(jax.random.key(0), cfg)
    predict_fn = make_predict_fn(cfg)
    print(f"bench_serving: {replay.num_kernels} kernels, "
          f"{len(replay.requests)} requests, {replay.num_queries} queries "
          f"({replay.num_unique} unique graphs)")

    def make_service() -> CostModelService:
        return CostModelService(params, cfg, replay.normalizer,
                                predict_fn=predict_fn)

    def direct(graphs):
        return predict_kernels(params, cfg, graphs, replay.normalizer,
                               max_nodes=max_nodes, predict_fn=predict_fn)

    # warmup: compile every bucket shape either path can produce — the
    # service's miss-set packs and the direct path's full-request packs
    # can land in different BucketSpecs, so each path warms its own
    run_replay(make_service().predict_many, replay.requests)
    run_replay(direct, replay.requests)

    service = make_service()
    svc_preds, svc_dt = run_replay(service.predict_many, replay.requests)
    dir_preds, dir_dt = run_replay(direct, replay.requests)

    stats = service.stats()
    err = max(float(np.max(np.abs(a - b)))
              for a, b in zip(svc_preds, dir_preds))
    speedup = dir_dt / svc_dt
    print(f"  direct   {replay.num_queries / dir_dt:8.0f} queries/s "
          f"({dir_dt:.2f}s)")
    print(f"  service  {replay.num_queries / svc_dt:8.0f} queries/s "
          f"({svc_dt:.2f}s)  hit_rate={stats.hit_rate:.1%} "
          f"flushes={stats.flushes} p50={stats.latency_p50_ms:.2f}ms "
          f"p99={stats.latency_p99_ms:.2f}ms")
    print(f"  speedup {speedup:.2f}x, max prediction delta {err:.2e}")
    from common import Gate, emit_json
    ok = emit_json(
        "serving",
        [Gate("service_speedup", speedup, threshold),
         Gate("prediction_delta", err, 1e-4, "<")],
        wall_s=time.perf_counter() - t_start,
        extra={"hit_rate": stats.hit_rate, "flushes": stats.flushes,
               "latency_p50_ms": stats.latency_p50_ms,
               "latency_p99_ms": stats.latency_p99_ms,
               "scale": SCALE})
    print(f"bench_serving: {'PASS' if ok else 'FAIL'} "
          f"(need >={threshold:.2f}x speedup and <1e-4 prediction delta)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
