"""Sharded corpus store: parallel build, no-op rebuild, reload, streaming
parity (DESIGN.md §11, docs/DATA.md; acceptance gates for the data layer).

Builds the same tile+fusion corpus four ways and checks that the store
behaves like a cache of the in-memory path, not a different path:

  1. parallel build     — `build_corpus` at workers=4 vs workers=1,
                          identical manifest hashes (partitioning cannot
                          change the corpus) and >= BUILD_SPEEDUP_GATE
                          faster wall-clock,
  2. no-op rebuild      — re-invoking with an unchanged spec returns the
                          existing manifests without building (and in a
                          small fraction of the build time),
  3. reload             — `StreamingCorpus` open+verify+full decode of
                          both kinds >= 5x faster than regenerating the
                          records in-process (the pre-store behavior of
                          every trainer/bench run; generation + oracle
                          measurement, no store write),
  4. streaming parity   — `TileBatchSampler` and `Prefetcher` batch
                          streams over the store are byte-identical to
                          the same samplers over the in-memory records
                          (targets, group ids, masks, every encoded
                          array leaf).

The build-speedup threshold is calibrated, not assumed: `cpu_count` lies
on quota'd/shared containers (this repo's dev box reports 2 CPUs but two
busy processes achieve only ~1.35x one process's throughput), so the
bench first measures the host's actual parallel capacity with spin
workers and gates at min(2.0, max(1.0, 0.7 * capacity)) — on the >=4-vCPU
CI runners capacity is ~3-4 so the gate binds at the full 2.0x
(the ISSUE-5 acceptance number); on a throttled host it degrades to
"parallel build must still beat serial" instead of demanding throughput
the machine cannot physically deliver. Builds run as interleaved
best-of-2 trials — single-trial wall clock on shared CPUs is noisy. The
computed threshold and measured capacity are recorded in
BENCH_corpus.json.

`BENCH_SCALE` scales the program *count* only (kernel sizes and per-
kernel config counts are fixed — see benchmarks/common.py). The build-
speedup gate narrows at small scales (less measurement work to amortize
pool startup + record pickling over: ~2.0x at scale 1.0 on 2 cores but
only ~0.9x at 0.5), so CI runs this benchmark UNSCALED like
bench_serving / bench_autotune.

jax must not load before the build phases: the builder forks workers
(`--mp-context auto` picks fork only while jax is absent), so everything
jax-backed (samplers, encoding, emit_json's common import) loads after
the pools are done.

  PYTHONPATH=src python benchmarks/bench_corpus.py
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data.store import StreamingCorpus, record_key   # noqa: E402
from repro.launch.build_corpus import DEFAULT_FUSION, DEFAULT_TILE, \
    build_corpus  # noqa: E402

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
PROGRAMS = max(int(48 * SCALE), 16)
TILE_OPTS = dict(DEFAULT_TILE, max_configs_per_kernel=48)
FUSION_OPTS = dict(DEFAULT_FUSION, configs_per_program=12)
KINDS = ("tile", "fusion")
PAR_WORKERS = 4
RELOAD_GATE = 5.0
PARITY_STEPS = 6


def _spin(seconds: float) -> int:
    t0 = time.perf_counter()
    n = 0
    while time.perf_counter() - t0 < seconds:
        n += 1
    return n


def parallel_capacity(workers: int, window: float = 0.5) -> float:
    """Measured speedup ceiling of this host: total busy-loop throughput
    of `workers` concurrent processes over one process's. ~= the real
    core count, except on quota'd containers where cpu_count overstates
    what the scheduler will actually deliver."""
    import multiprocessing
    one = _spin(window)
    with multiprocessing.get_context("fork").Pool(workers) as pool:
        many = sum(pool.map(_spin, [window] * workers))
    return many / max(one, 1)


def build(out: str, workers: int, force: bool = False) -> tuple[dict, float]:
    t0 = time.perf_counter()
    manifests = build_corpus(
        out, kinds=KINDS, programs=PROGRAMS, seed=0, workers=workers,
        tile_opts=TILE_OPTS, fusion_opts=FUSION_OPTS, force=force,
        quiet=True)
    return manifests, time.perf_counter() - t0


def build_in_memory() -> tuple[list, list]:
    """The records the store holds, built the pre-store way: in-process,
    program by program in task order, deduped by content key first-wins —
    the ground truth the streaming path must match byte-for-byte."""
    from repro.core.simulator import TPUSimulator
    from repro.data.fusion import apply_fusion, default_fusion
    from repro.data.fusion_dataset import build_fusion_records
    from repro.data.synthetic import corpus_plan, generate_program
    from repro.data.tile_dataset import build_tile_records

    sim = TPUSimulator()
    tile, fusion = [], []
    for fam, idx in corpus_plan(PROGRAMS):
        prog = generate_program(fam, idx, 0)
        kernels = apply_fusion(prog, default_fusion(prog))
        tile.extend(build_tile_records(kernels, sim, seed=0, **TILE_OPTS))
        fusion.extend(build_fusion_records(prog, sim, seed=0,
                                           **FUSION_OPTS))
    out = []
    for recs in (tile, fusion):
        seen: set[str] = set()
        kept = [r for r in recs
                if not (record_key(r) in seen or seen.add(record_key(r)))]
        out.append(kept)
    return out[0], out[1]


def main() -> int:
    t_start = time.perf_counter()
    assert "jax" not in sys.modules, \
        "bench_corpus must fork its build pools before jax loads"
    root = tempfile.mkdtemp(prefix="bench_corpus_")
    out1, out4 = os.path.join(root, "w1"), os.path.join(root, "w4")
    capacity = parallel_capacity(PAR_WORKERS)
    build_gate = min(2.0, max(1.0, 0.7 * capacity))
    print(f"bench_corpus: {PROGRAMS} programs, tile configs "
          f"{TILE_OPTS['max_configs_per_kernel']}, fusion configs "
          f"{FUSION_OPTS['configs_per_program']}; {os.cpu_count()} cpus, "
          f"measured parallel capacity {capacity:.2f}x "
          f"-> build gate >= {build_gate:.2f}x")
    try:
        # --- 1. parallel build vs serial build ----------------------------
        # interleaved best-of-2: single-trial wall clock on a shared CPU
        # is too noisy for a binding ratio gate (benchmarks/common.py)
        t_par = t_ser = float("inf")
        for trial in range(2):
            m4, dt4 = build(out4, workers=PAR_WORKERS, force=trial > 0)
            m1, dt1 = build(out1, workers=1, force=trial > 0)
            t_par, t_ser = min(t_par, dt4), min(t_ser, dt1)
        build_speedup = t_ser / t_par
        deterministic = all(
            m1[k]["manifest_hash"] == m4[k]["manifest_hash"] for k in KINDS)
        print(f"  build: workers=1 {t_ser:.1f}s, workers={PAR_WORKERS} "
              f"{t_par:.1f}s -> {build_speedup:.2f}x (best of 2); "
              f"manifests {'identical' if deterministic else 'DIVERGED'}")

        # --- 2. unchanged spec rebuild is a manifest-hash no-op -----------
        t0 = time.perf_counter()
        m1b, _ = build(out1, workers=1)
        t_noop = time.perf_counter() - t0
        noop = (all(m1b[k]["manifest_hash"] == m1[k]["manifest_hash"]
                    for k in KINDS) and t_noop < max(0.25 * t_ser, 1.0))
        print(f"  rebuild same spec: {t_noop:.2f}s "
              f"({'no-op' if noop else 'REBUILT'})")

        # --- 3. reload from store vs regeneration -------------------------
        t0 = time.perf_counter()
        stores = {k: StreamingCorpus.open(os.path.join(out1, k),
                                          verify=True) for k in KINDS}
        store_recs = {k: list(stores[k]) for k in KINDS}
        t_reload = time.perf_counter() - t0
        t0 = time.perf_counter()
        mem_tile, mem_fusion = build_in_memory()   # the pre-store behavior
        t_regen = time.perf_counter() - t0
        reload_speedup = t_regen / t_reload
        print(f"  reload: {t_reload:.2f}s for "
              f"{sum(len(r) for r in store_recs.values())} records "
              f"-> {reload_speedup:.2f}x vs in-process regeneration "
              f"({t_regen:.1f}s)")

        # --- 4. streaming parity vs the in-memory path --------------------
        content_ok = (
            len(mem_tile) == len(store_recs["tile"])
            and len(mem_fusion) == len(store_recs["fusion"])
            and all(record_key(a) == record_key(b) and
                    np.array_equal(a.runtimes, b.runtimes)
                    for a, b in zip(mem_tile, store_recs["tile"]))
            and all(record_key(a) == record_key(b) and a.runtime == b.runtime
                    for a, b in zip(mem_fusion, store_recs["fusion"])))
        print(f"  record content identical: {content_ok} "
              f"({len(mem_tile)} tile / {len(mem_fusion)} fusion records)")

        # jax-backed encoding from here on (pools are done)
        import jax
        from repro.data.prefetch import Prefetcher
        from repro.data.sampler import BalancedSampler, TileBatchSampler
        from repro.data.tile_dataset import fit_tile_normalizer

        def batches_equal(a, b) -> bool:
            fields = [(a.targets, b.targets), (a.valid, b.valid)]
            if hasattr(a, "group_ids"):
                fields.append((a.group_ids, b.group_ids))
            fields += list(zip(jax.tree_util.tree_leaves(a.graphs),
                               jax.tree_util.tree_leaves(b.graphs)))
            return all(np.array_equal(np.asarray(x), np.asarray(y))
                       for x, y in fields)

        norm = fit_tile_normalizer(mem_tile)
        # streaming corpus view: small LRU — draws hop shards mid-batch
        tile_stream = StreamingCorpus.open(os.path.join(out1, "tile"),
                                           max_cached_shards=2)
        s_mem = TileBatchSampler(mem_tile, norm, max_nodes=48, seed=0)
        s_store = TileBatchSampler(tile_stream, norm, max_nodes=48, seed=0)
        parity = all(batches_equal(s_mem.batch(s), s_store.batch(s))
                     for s in range(PARITY_STEPS))
        with Prefetcher(TileBatchSampler(tile_stream, norm, max_nodes=48,
                                         seed=0), depth=2) as pre:
            parity &= all(batches_equal(s_mem.batch(s), pre.batch(s))
                          for s in range(PARITY_STEPS))
        fus_stream = StreamingCorpus.open(os.path.join(out1, "fusion"),
                                          max_cached_shards=2)
        f_mem = BalancedSampler(mem_fusion, norm, batch_size=32,
                                max_nodes=48, seed=0)
        f_store = BalancedSampler(fus_stream, norm, batch_size=32,
                                  max_nodes=48, seed=0)
        parity &= all(batches_equal(f_mem.batch(s), f_store.batch(s))
                      for s in range(PARITY_STEPS))
        parity &= content_ok
        print(f"  sampler + prefetcher streams byte-identical: {parity}")

        from common import Gate, emit_json
        ok = emit_json(
            "corpus",
            [Gate("build_speedup_workers4", build_speedup, build_gate),
             Gate("manifest_deterministic", deterministic, True, "=="),
             Gate("rebuild_noop", noop, True, "=="),
             Gate("reload_speedup", reload_speedup, RELOAD_GATE),
             Gate("streaming_parity", parity, True, "==")],
            wall_s=time.perf_counter() - t_start,
            extra={"programs": PROGRAMS,
                   "parallel_capacity": round(capacity, 2),
                   "build_s_workers1": round(t_ser, 2),
                   "build_s_workers4": round(t_par, 2),
                   "regen_s": round(t_regen, 2),
                   "reload_s": round(t_reload, 3),
                   "tile_records": len(store_recs["tile"]),
                   "fusion_records": len(store_recs["fusion"]),
                   "tile_manifest": m1["tile"]["manifest_hash"],
                   "fusion_manifest": m1["fusion"]["manifest_hash"]})
        print(f"bench_corpus: {'PASS' if ok else 'FAIL'} "
              f"(need >={build_gate:.2f}x build, deterministic "
              f"manifests, no-op rebuild, >={RELOAD_GATE:.0f}x reload, "
              f"byte-identical streams; got {build_speedup:.2f}x / "
              f"{deterministic} / {noop} / {reload_speedup:.2f}x / "
              f"{parity})")
        return 0 if ok else 1
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
