"""Fig 4: tile-size autotuner integration.

Per benchmark program, speedup over the compiler default (= the analytical
model's argmin tile per kernel) for:
  * Exhaustive          — measure every tile on hardware,
  * Learned model 1     — learned model replaces the analytical model in the
                          compiler (top-1, no hardware),
  * Learned model 10    — learned model proposes top-10, hardware picks,
  * Analytical 10       — analytical model proposes top-10, hardware picks.
"""
from __future__ import annotations


from benchmarks.common import (
    MAX_NODES,
    build_world,
    csv_row,
    paper_tile_model,
    steps,
    train_cost_model,
)
from repro.autotuner import autotune_program_tiles
from repro.core.analytical import AnalyticalModel
from repro.core.evaluate import analytical_tile_scorer, learned_tile_scorer
from repro.data.fusion import apply_fusion, default_fusion

MAX_CONFIGS = 24


def run() -> list[str]:
    world = build_world()
    mc = paper_tile_model()
    params = train_cost_model(world, mc, task="tile", method="random",
                              n_steps=steps(1500))
    learned = learned_tile_scorer(params, mc, world.normalizers["random"],
                                  max_nodes=MAX_NODES, chunk=64)
    analytical = analytical_tile_scorer(AnalyticalModel())

    rows = []
    test_programs = world.splits["random"]["test"][:6]
    by_name = {p.program: p for p in world.programs}
    for prog_name in test_programs:
        prog = by_name[prog_name]
        kernels = apply_fusion(prog, default_fusion(prog))
        kernels = [k for k in kernels if k.num_nodes <= MAX_NODES]
        if not kernels:
            continue
        default = autotune_program_tiles(kernels, world.sim,
                                         scorer=analytical, top_k=1,
                                         max_configs=MAX_CONFIGS)
        ex = autotune_program_tiles(kernels, world.sim, scorer=None,
                                    max_configs=MAX_CONFIGS)
        l1 = autotune_program_tiles(kernels, world.sim, scorer=learned,
                                    top_k=1, max_configs=MAX_CONFIGS)
        l10 = autotune_program_tiles(kernels, world.sim, scorer=learned,
                                     top_k=10, max_configs=MAX_CONFIGS)
        a10 = autotune_program_tiles(kernels, world.sim, scorer=analytical,
                                     top_k=10, max_configs=MAX_CONFIGS)
        d = default.total_runtime
        rows.append(csv_row(
            f"fig4.{prog_name}",
            exhaustive=d / ex.total_runtime,
            learned1=d / l1.total_runtime,
            learned10=d / l10.total_runtime,
            analytical10=d / a10.total_runtime,
            hw_evals_exhaustive=ex.hardware_evals,
            hw_evals_learned10=l10.hardware_evals))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
