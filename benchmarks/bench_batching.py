"""Dense-padded vs sparse-packed batching throughput (DESIGN.md §4).

Mixed-size synthetic corpus (8–256 node kernels, log-uniform sizes — the
TpuGraphs-style regime where a few big graphs force huge padding on many
small ones). Measures:

  * train-step throughput (graphs/sec, fusion-task log-MSE objective),
  * inference throughput (graphs/sec, deterministic forward),
  * numerical agreement of per-graph predictions between the two paths.

Dense pads every kernel to [N_max, N_max] adjacency slots; sparse packs
kernels into flat node/edge buffers of ~NODE_BUDGET total nodes with
pow2-bucketed capacities (one compiled executable per bucket).

  PYTHONPATH=src python benchmarks/bench_batching.py
"""
from __future__ import annotations

import os
import sys
import time
from functools import partial

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import features as F
from repro.core.losses import log_mse_loss
from repro.core.model import CostModelConfig, cost_model_apply, \
    cost_model_init
from repro.data.batching import iter_packed_batches
from repro.data.synthetic import random_kernel
from repro.training.optim import AdamWConfig, adamw_init, adamw_update

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
NUM_GRAPHS = max(int(96 * SCALE), 32)
MIN_NODES, MAX_NODES = 8, 256
DENSE_BATCH = 16
NODE_BUDGET = 1024          # sparse pack size (total real nodes per batch)
EPOCHS = max(int(3 * SCALE), 2)


def build_corpus(seed: int = 0):
    rng = np.random.default_rng(seed)
    sizes = np.unique(np.round(np.exp(rng.uniform(
        np.log(MIN_NODES), np.log(MAX_NODES), NUM_GRAPHS))).astype(int))
    sizes = np.concatenate([sizes, rng.choice(
        sizes, NUM_GRAPHS - len(sizes))])          # re-use sizes to fill up
    graphs = [random_kernel(int(n), seed=i) for i, n in enumerate(sizes)]
    # deterministic runtime proxy so the regression target is meaningful
    targets = np.array([g.total_flops() / 8e13 + g.bytes_written() / 8e11
                        + 1e-6 for g in graphs], np.float32)
    return graphs, targets


def model_cfg() -> CostModelConfig:
    return CostModelConfig(gnn="graphsage", reduction="column_wise",
                           hidden_dim=64, opcode_embed_dim=16,
                           max_nodes=MAX_NODES, dropout=0.0)


def make_train_step(cfg: CostModelConfig, opt_cfg: AdamWConfig):
    def loss_fn(params, batch, targets, valid):
        preds = cost_model_apply(params, cfg, batch)
        return log_mse_loss(preds, targets, valid)

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch, targets, valid):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, targets,
                                                  valid)
        params, opt_state, _ = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss
    return step


def dense_batches(graphs, targets, normalizer):
    out = []
    for i in range(0, len(graphs), DENSE_BATCH):
        part = graphs[i:i + DENSE_BATCH]
        pad = DENSE_BATCH - len(part)
        enc = F.encode_batch(part + [part[-1]] * pad, MAX_NODES, normalizer)
        t = np.concatenate([targets[i:i + DENSE_BATCH],
                            np.full((pad,), 1.0, np.float32)])
        v = np.concatenate([np.ones((len(part),), np.float32),
                            np.zeros((pad,), np.float32)])
        out.append((enc, jnp.asarray(t), jnp.asarray(v), len(part)))
    return out


def sparse_batches(graphs, targets, normalizer):
    out = []
    for enc, idx in iter_packed_batches(graphs, NODE_BUDGET, normalizer):
        G = enc.batch_size
        t = np.full((G,), 1.0, np.float32)
        t[:len(idx)] = targets[idx]
        v = np.asarray(enc.graph_mask, np.float32)
        out.append((enc, jnp.asarray(t), jnp.asarray(v), len(idx)))
    return out


def time_train(batches, cfg, label):
    params = cost_model_init(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params)
    step = make_train_step(cfg, opt_cfg)
    # warmup epoch: compiles every bucket shape
    for enc, t, v, _ in batches:
        params, opt_state, loss = step(params, opt_state, enc, t, v)
    jax.block_until_ready(loss)
    n_graphs = sum(b[3] for b in batches)
    t0 = time.perf_counter()
    for _ in range(EPOCHS):
        for enc, t, v, _ in batches:
            params, opt_state, loss = step(params, opt_state, enc, t, v)
    jax.block_until_ready(loss)
    dt = time.perf_counter() - t0
    tput = EPOCHS * n_graphs / dt
    print(f"  train    {label:14s} {tput:8.1f} graphs/s  "
          f"({len(batches)} batches/epoch, {dt:.2f}s)")
    return tput


def time_infer(batches, cfg, params, label):
    @jax.jit
    def fwd(params, batch):
        return cost_model_apply(params, cfg, batch)

    for enc, *_ in batches:
        preds = fwd(params, enc)
    jax.block_until_ready(preds)
    n_graphs = sum(b[3] for b in batches)
    reps = EPOCHS * 4
    t0 = time.perf_counter()
    for _ in range(reps):
        for enc, *_ in batches:
            preds = fwd(params, enc)
    jax.block_until_ready(preds)
    dt = time.perf_counter() - t0
    tput = reps * n_graphs / dt
    print(f"  infer    {label:14s} {tput:8.1f} graphs/s")
    return tput


def main():
    t_start = time.perf_counter()
    graphs, targets = build_corpus()
    normalizer = F.fit_normalizer(graphs)
    cfg = model_cfg()
    print(f"bench_batching: {len(graphs)} kernels, "
          f"{MIN_NODES}-{MAX_NODES} nodes, dense B={DENSE_BATCH} "
          f"N={MAX_NODES}, sparse node_budget={NODE_BUDGET}")

    db = dense_batches(graphs, targets, normalizer)
    sb = sparse_batches(graphs, targets, normalizer)
    total_dense_nodes = len(db) * DENSE_BATCH * MAX_NODES
    total_sparse_nodes = sum(b[0].num_nodes for b in sb)
    print(f"  padded node footprint: dense {total_dense_nodes}, "
          f"sparse {total_sparse_nodes} "
          f"({total_dense_nodes / total_sparse_nodes:.1f}x smaller)")

    # --- numerical agreement (shared params, deterministic forward)
    params = cost_model_init(jax.random.key(0), cfg)
    pred_dense = np.concatenate(
        [np.asarray(cost_model_apply(params, cfg, enc))[:n]
         for enc, _, _, n in db])
    pred_sparse = np.zeros_like(pred_dense)
    off = 0
    for enc, idx in iter_packed_batches(graphs, NODE_BUDGET, normalizer):
        p = np.asarray(cost_model_apply(params, cfg, enc))
        pred_sparse[idx] = p[:len(idx)]
    err = float(np.max(np.abs(pred_dense - pred_sparse)))
    agree = err < 1e-4
    print(f"  dense-vs-sparse prediction max |Δ| = {err:.2e} "
          f"({'OK' if agree else 'MISMATCH'})")

    t_dense = time_train(db, cfg, "dense-padded")
    t_sparse = time_train(sb, cfg, "sparse-packed")
    i_dense = time_infer(db, cfg, params, "dense-padded")
    i_sparse = time_infer(sb, cfg, params, "sparse-packed")

    train_speedup = t_sparse / t_dense
    infer_speedup = i_sparse / i_dense
    print(f"  speedup: train {train_speedup:.2f}x, infer "
          f"{infer_speedup:.2f}x")
    from common import Gate, emit_json
    ok = emit_json(
        "batching",
        [Gate("train_speedup", train_speedup, 2.0),
         Gate("prediction_delta", err, 1e-4, "<")],
        wall_s=time.perf_counter() - t_start,
        extra={"infer_speedup": infer_speedup,
               "dense_nodes": total_dense_nodes,
               "sparse_nodes": total_sparse_nodes})
    print(f"bench_batching: {'PASS' if ok else 'FAIL'} "
          f"(need >=2x train speedup and <1e-4 prediction delta)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
