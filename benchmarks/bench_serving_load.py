"""Multi-process load test for the cost-model socket server
(docs/SERVING.md §server; acceptance gate for serving-at-load).

Three phases against `repro.serving.server.CostModelServer`:

  * load   — N client *processes* (spawn, jax-free: the client module is
    numpy+stdlib) replay disjoint slices of the deterministic tile-search
    stream (`repro.serving.replay`) concurrently. Gates: sustained
    throughput >= 200 queries/s from >= 4 clients, bounded p99 request
    latency, and ZERO divergence from direct in-process
    `predict_kernels` (each request ships its expected scores; float32
    survives the JSON double round trip exactly).
  * shed   — a throttled server (tiny admission queue + a `delay`
    FaultPolicy slowing the scoring worker) is deliberately saturated.
    Gates: requests are shed with explicit `overloaded` errors (never
    silently dropped — client send counts and server counters must both
    add up exactly) and the server serves normally once the throttle
    lifts.
  * warm   — the load-phase server's cache snapshot restarts a *fresh*
    service, which must answer the first replay of the same stream
    >= 90% from disk (it measures 100%: every unique graph was snapshot).

Work counts scale with BENCH_SCALE (replay repeats/programs — never
kernel sizes); the gates are per-second or exactness criteria and stay
binding at any scale.

  PYTHONPATH=src python benchmarks/bench_serving_load.py
"""
from __future__ import annotations

import json
import multiprocessing
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
NUM_CLIENTS = 4
NUM_PROGRAMS = max(int(4 * SCALE), 3)
MAX_CONFIGS = 8
ROUNDS = 3
SUBSET = 0.75
REPEATS = max(int(12 * SCALE), 3)    # passes each client makes over its slice
DEADLINE_MS = 30_000.0


# ---------------------------------------------------------------------------
# Client process (spawn target — stays jax-free; the module re-import in
# the child only pays numpy + repro.serving.client)
# ---------------------------------------------------------------------------
def _client_worker(host: str, port: int, req_path: str,
                   out_path: str) -> None:
    from repro.core.graph import KernelGraph
    from repro.serving.client import ClientError, CostModelClient

    with open(req_path) as f:
        spec = json.load(f)
    requests = [([KernelGraph.from_dict(g) for g in r["graphs"]],
                 np.asarray(r["expect"], np.float32))
                for r in spec["requests"]]
    latencies, errors = [], {}
    sent = ok = queries = 0
    divergence = 0.0
    t0 = time.perf_counter()
    with CostModelClient(host, port, retries=3) as client:
        for ri in range(spec["repeats"]):
            # pass 0 fills the server's cold prediction cache (scoring
            # passes, hundreds of ms); the sustained-QPS/p99 gates measure
            # the steady state, so timing starts at pass 1
            timed = ri > 0
            if ri == 1:
                t0 = time.perf_counter()
            for graphs, expect in requests:
                sent += 1
                t_req = time.perf_counter()
                try:
                    scores = client.predict_many(graphs,
                                                 deadline_ms=DEADLINE_MS)
                except ClientError as e:
                    errors[type(e).__name__] = \
                        errors.get(type(e).__name__, 0) + 1
                    continue
                ok += 1
                if timed:
                    latencies.append((time.perf_counter() - t_req) * 1e3)
                    queries += len(graphs)
                divergence = max(divergence,
                                 float(np.max(np.abs(scores - expect))))
    with open(out_path, "w") as f:
        json.dump({"sent": sent, "ok": ok, "queries": queries,
                   "errors": errors, "latencies_ms": latencies,
                   "max_divergence": divergence,
                   "t0": t0, "t1": time.perf_counter()}, f)


def main() -> int:
    t_start = time.perf_counter()
    import tempfile

    import jax

    from common import Gate, emit_json
    from repro.core.evaluate import make_predict_fn, predict_kernels
    from repro.core.model import CostModelConfig, cost_model_init
    from repro.serving import CostModelService
    from repro.serving.replay import build_tile_replay, run_replay
    from repro.serving.client import CostModelClient, Overloaded
    from repro.serving.server import CostModelServer, FaultPolicy

    replay = build_tile_replay(NUM_PROGRAMS, max_configs=MAX_CONFIGS,
                               rounds=ROUNDS, subset=SUBSET, seed=0)
    max_nodes = max(g.num_nodes for r in replay.requests for g in r)
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=48, opcode_embed_dim=16, dropout=0.0,
                          max_nodes=max_nodes, adjacency="sparse")
    params = cost_model_init(jax.random.key(0), cfg)
    predict_fn = make_predict_fn(cfg)
    print(f"bench_serving_load: {replay.num_kernels} kernels, "
          f"{len(replay.requests)} requests x {REPEATS} repeats x "
          f"{NUM_CLIENTS} clients, {replay.num_queries} queries/pass "
          f"({replay.num_unique} unique graphs)")

    def make_service() -> CostModelService:
        return CostModelService(params, cfg, replay.normalizer,
                                predict_fn=predict_fn)

    def direct(graphs):
        return predict_kernels(params, cfg, graphs, replay.normalizer,
                               max_nodes=max_nodes, predict_fn=predict_fn)

    # ground truth for the divergence gate; also warms every jit bucket
    # either path can hit, so the timed phase measures steady-state serving
    expects, _ = run_replay(direct, replay.requests)
    run_replay(make_service().predict_many, replay.requests)

    tmp = tempfile.mkdtemp(prefix="bench_serving_load_")
    snap = os.path.join(tmp, "warm-cache.npz")

    # ---- phase 1: concurrent load ----------------------------------------
    service = make_service()
    server = CostModelServer(service, max_queue=256,
                             snapshot_path=snap).start()
    host, port = server.address
    ctx = multiprocessing.get_context("spawn")   # children must not fork
    procs, outs = [], []                         # the jax-laden parent
    for ci in range(NUM_CLIENTS):
        slice_reqs = [{"graphs": [g.to_dict() for g in r],
                       "expect": [float(s) for s in e]}
                      for i, (r, e) in enumerate(zip(replay.requests,
                                                     expects))
                      if i % NUM_CLIENTS == ci]
        req_path = os.path.join(tmp, f"reqs_{ci}.json")
        out_path = os.path.join(tmp, f"out_{ci}.json")
        with open(req_path, "w") as f:
            json.dump({"requests": slice_reqs, "repeats": REPEATS}, f)
        outs.append(out_path)
        procs.append(ctx.Process(target=_client_worker,
                                 args=(host, port, req_path, out_path)))
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=600)
    assert all(p.exitcode == 0 for p in procs), \
        [f"client exit {p.exitcode}" for p in procs]
    reports = []
    for path in outs:
        with open(path) as f:
            reports.append(json.load(f))
    load_stats = server.stats
    svc_stats = service.stats()
    server.stop()                                # writes the warm snapshot

    sent = sum(r["sent"] for r in reports)
    ok = sum(r["ok"] for r in reports)
    typed_errors = sum(sum(r["errors"].values()) for r in reports)
    queries = sum(r["queries"] for r in reports)
    window = max(r["t1"] for r in reports) - min(r["t0"] for r in reports)
    qps = queries / window
    lat = np.sort(np.concatenate(
        [np.asarray(r["latencies_ms"]) for r in reports]))
    p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
    divergence = max(r["max_divergence"] for r in reports)
    accounted = (sent == ok + typed_errors
                 and load_stats.requests == load_stats.completed
                 + load_stats.shed_overloaded + load_stats.shed_deadline
                 + load_stats.worker_failures)
    print(f"  load: {qps:8.0f} queries/s over {window:.2f}s "
          f"({NUM_CLIENTS} procs, {ok}/{sent} ok, p50={p50:.2f}ms "
          f"p99={p99:.2f}ms, hit_rate={svc_stats.hit_rate:.1%})")
    print(f"  divergence vs direct: {divergence:.2e}")

    # ---- phase 2: forced saturation sheds explicitly, then recovers ------
    shed_server = CostModelServer(
        service, max_queue=2, coalesce_limit=1,
        fault_policy=FaultPolicy("delay", every=1, delay_s=0.02)).start()
    shost, sport = shed_server.address
    shed_sent = shed_ok = shed_rejected = 0
    import threading

    def hammer():
        nonlocal shed_sent, shed_ok, shed_rejected
        with CostModelClient(shost, sport, retries=0) as c:
            for i in range(12):
                with lock:
                    shed_sent += 1
                try:
                    c.predict_many(replay.requests[i % len(replay.requests)],
                                   deadline_ms=DEADLINE_MS)
                    with lock:
                        shed_ok += 1
                except Overloaded:
                    with lock:
                        shed_rejected += 1

    lock = threading.Lock()
    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    shed_stats = shed_server.stats
    shed_accounted = (shed_sent == shed_ok + shed_rejected
                      and shed_stats.requests == shed_stats.completed
                      + shed_stats.shed_overloaded + shed_stats.shed_deadline
                      + shed_stats.worker_failures)
    # lift the throttle: the same server must serve normally again
    shed_server.fault_policy = None
    with CostModelClient(shost, sport) as c:
        recovered = c.predict_many(replay.requests[0],
                                   deadline_ms=DEADLINE_MS).shape[0] \
            == len(replay.requests[0])
    shed_server.stop()
    print(f"  shed: {shed_rejected}/{shed_sent} rejected `overloaded` "
          f"under saturation, {shed_ok} served, recovered={recovered}")

    # ---- phase 3: warm restart answers the first replay from disk --------
    warm_service = make_service()
    warm_server = CostModelServer(warm_service, snapshot_path=snap).start()
    with CostModelClient(*warm_server.address) as c:
        warm_preds, _ = run_replay(
            lambda gs: c.predict_many(gs, deadline_ms=DEADLINE_MS),
            replay.requests)
    warm_stats = warm_service.stats()
    warm_hit_rate = warm_stats.hit_rate
    warm_exact = max(float(np.max(np.abs(a - b)))
                     for a, b in zip(warm_preds, expects))
    warm_server.stop()
    print(f"  warm: restored {warm_server.stats.restored_entries} entries, "
          f"first-replay hit_rate={warm_hit_rate:.1%}, "
          f"divergence {warm_exact:.2e}")

    gates = [
        Gate("num_clients", NUM_CLIENTS, 4),
        Gate("sustained_qps", qps, 200.0),
        Gate("latency_p99_ms", p99, 250.0, "<="),
        Gate("prediction_divergence", divergence, 0.0, "<="),
        Gate("no_silent_drops", bool(accounted and shed_accounted), True,
             "=="),
        Gate("shed_overloaded", shed_rejected, 1),
        Gate("shed_recovered", bool(recovered), True, "=="),
        Gate("warm_restart_hit_rate", warm_hit_rate, 0.9),
        Gate("warm_restart_divergence", warm_exact, 0.0, "<="),
    ]
    ok_all = emit_json(
        "serving_load", gates, wall_s=time.perf_counter() - t_start,
        extra={"queries": queries, "window_s": round(window, 3),
               "latency_p50_ms": round(p50, 3),
               "hit_rate": svc_stats.hit_rate,
               "reconnect_errors": typed_errors,
               "server": load_stats.to_dict(),
               "shed_server": shed_stats.to_dict(),
               "restored_entries": warm_server.stats.restored_entries,
               "scale": SCALE})
    print(f"bench_serving_load: {'PASS' if ok_all else 'FAIL'} "
          f"(need >=200 q/s from >={NUM_CLIENTS} clients, p99<=250ms, "
          f"0 divergence, explicit shedding, warm hit rate >=90%)")
    return 0 if ok_all else 1


if __name__ == "__main__":
    raise SystemExit(main())
