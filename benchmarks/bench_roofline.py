"""Roofline table (deliverable g): three terms per (arch × shape) from the
dry-run + scan-corrected probe artifacts. Reads experiments/dryrun and
experiments/probes; writes experiments/roofline.md and prints CSV."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import csv_row
from repro.roofline.analysis import ROOFLINE_HW, RooflineRow, \
    analytic_memory_bytes, model_flops, render_markdown

EXP = os.path.join(os.path.dirname(__file__), "..", "experiments")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def corrected_rows(mesh_name: str = "pod16x16") -> list[RooflineRow]:
    from repro.models import SHAPES, registry
    from repro.models.lm import analytic_param_count
    rows = []
    dr_dir = os.path.join(EXP, "dryrun")
    pr_dir = os.path.join(EXP, "probes")
    if not os.path.isdir(dr_dir):
        return rows
    for fname in sorted(os.listdir(dr_dir)):
        if not fname.startswith(mesh_name) or not fname.endswith(".json"):
            continue
        with open(os.path.join(dr_dir, fname)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        cfg = registry.get_config(arch)
        shape = SHAPES[shape_name]
        probe_path = os.path.join(pr_dir, fname)
        corrected = None
        if os.path.exists(probe_path):
            with open(probe_path) as f:
                corrected = json.load(f).get("corrected")
        devices = rec.get("devices", 256)
        if corrected:
            flops_dev = corrected["flops"]
            bytes_dev = corrected["bytes"]
            coll_dev = corrected["collective_total"]
            note = "scan-corrected (probes)"
        else:
            flops_dev = rec["cost"].get("flops", 0.0)
            bytes_dev = rec["cost"].get("bytes accessed", 0.0)
            coll_dev = sum(v for k, v in rec["collectives"].items()
                           if k in _COLL)
            note = "RAW (bodies-once; no probe record)"
        n_params = analytic_param_count(cfg)
        compute_s = flops_dev / ROOFLINE_HW["peak_flops"]
        # HLO bytes = unfused upper bound; fused estimate drives dominance
        mem_fused = analytic_memory_bytes(cfg, shape, n_params)
        memory_s = min(bytes_dev, max(mem_fused, 0.0)) / \
            ROOFLINE_HW["hbm_bw"]
        memory_upper_s = bytes_dev / ROOFLINE_HW["hbm_bw"]
        collective_s = coll_dev / ROOFLINE_HW["ici_bw"]
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops(cfg, shape, n_params)
        hlo_total = flops_dev * devices
        m = rec.get("memory", {})
        peak = max(m.get("peak_memory_in_bytes", 0),
                   m.get("argument_size_in_bytes", 0))
        rows.append(RooflineRow(
            arch=arch, shape=shape_name, mesh=rec["mesh"], devices=devices,
            compute_s=compute_s, memory_s=memory_s,
            collective_s=collective_s, dominant=dominant,
            hlo_flops_total=hlo_total, model_flops=mf,
            useful_ratio=mf / hlo_total if hlo_total else float("nan"),
            peak_mem_gb=peak / 1024**3,
            fits_hbm=peak <= ROOFLINE_HW["hbm_bytes"], note=note,
            memory_upper_s=memory_upper_s))
    return rows


def run() -> list[str]:
    rows = corrected_rows()
    out = []
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        out.append(csv_row(
            f"roofline.{r.arch}.{r.shape}",
            compute_s=r.compute_s, memory_s=r.memory_s,
            collective_s=r.collective_s, bound=r.dominant,
            useful_flops_pct=100 * r.useful_ratio,
            roofline_fraction=r.roofline_fraction,
            peak_mem_gb=r.peak_mem_gb,
            memory_upper_s=r.memory_upper_s, note=r.note))
    if rows:
        md = render_markdown(rows)
        with open(os.path.join(EXP, "roofline.md"), "w") as f:
            f.write(md + "\n")
    else:
        out.append("roofline.SKIPPED,reason=no dryrun records "
                   "(run python -m repro.launch.dryrun first)")
    return out


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
