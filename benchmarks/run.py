"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig4
  BENCH_SCALE=0.3 python -m benchmarks.run           # quick pass

Prints `name,key=value,...` CSV rows; each row maps to one cell of the
corresponding paper artifact. Trained models are cached under
experiments/bench_cache (delete to retrain).
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _benches():
    from benchmarks import (
        bench_fig4,
        bench_fig5,
        bench_roofline,
        bench_table2,
        bench_table3,
        bench_table4,
        bench_table8,
    )
    return {
        "table2": bench_table2.run,
        "table3": bench_table3.run,
        "table4": bench_table4.run,
        "table8": bench_table8.run,
        "fig4": bench_fig4.run,
        "fig5": bench_fig5.run,
        "roofline": bench_roofline.run,
    }


def main() -> None:
    benches = _benches()
    want = [a for a in sys.argv[1:] if not a.startswith("-")] or \
        list(benches)
    failures = 0
    for name in want:
        if name not in benches:
            print(f"{name},ERROR=unknown benchmark")
            failures += 1
            continue
        t0 = time.time()
        try:
            rows = benches[name]()
            for r in rows:
                print(r)
            print(f"{name}.WALL,seconds={time.time()-t0:.1f}")
            # paper-artifact benches have no pass/fail gates; their
            # BENCH_<name>.json archives the CSV rows + wall time so the
            # reproduction trajectory is machine-readable per run too
            from benchmarks.common import emit_json
            emit_json(name, [], wall_s=time.time() - t0,
                      extra={"rows": rows})
        except Exception as e:                        # noqa: BLE001
            failures += 1
            print(f"{name},ERROR={type(e).__name__}:{str(e)[:200]}")
            traceback.print_exc(file=sys.stderr)
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
