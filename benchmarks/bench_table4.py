"""Table 4: model-architecture grid — {no GNN, GraphSAGE, GAT} ×
{per-node, column-wise, LSTM, Transformer} on both tasks.

Settings follow §6.2: direction-aware, static perf (and tile) as node
features; rank loss for tile, log-MSE for fusion.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    MAX_NODES,
    build_world,
    csv_row,
    steps,
    train_cost_model,
)
from repro.core.evaluate import (
    eval_fusion_task,
    eval_tile_task,
    learned_runtime_predictor,
    learned_tile_scorer,
)
from repro.core.model import CostModelConfig

GNNS = ("none", "graphsage", "gat")
REDUCTIONS = ("per_node", "column_wise", "lstm", "transformer")
N_STEPS = 500


def run() -> list[str]:
    world = build_world()
    rows = []
    n = steps(N_STEPS)
    for gnn in GNNS:
        for red in REDUCTIONS:
            mc = CostModelConfig(gnn=gnn, reduction=red, hidden_dim=48,
                                 opcode_embed_dim=16, max_nodes=MAX_NODES,
                                 dropout=0.1, gat_heads=2)
            lr = 5e-4 if gnn == "gat" else 2e-3    # GATs are LR-sensitive
            params = train_cost_model(world, mc, task="tile",
                                      method="random", n_steps=n, lr=lr,
                                      tag="t4")
            res = eval_tile_task(
                world.tile_subset("random", "test"),
                learned_tile_scorer(params, mc,
                                    world.normalizers["random"],
                                    max_nodes=MAX_NODES, chunk=64))
            apes = [m["ape"] for m in res["per_program"].values()]

            params_f = train_cost_model(world, mc, task="fusion",
                                        method="random", n_steps=n, lr=lr,
                                        tag="t4f")
            pred = learned_runtime_predictor(params_f, mc,
                                             world.normalizers["random"],
                                             max_nodes=MAX_NODES, chunk=64)
            resf = eval_fusion_task(world.fusion_subset("random", "test"),
                                    pred, min_runtime=5e-6)
            mapes = [m["mape"] for m in resf["per_program"].values()]
            rows.append(csv_row(
                f"table4.{gnn}.{red}",
                tile_ape=res["mean_ape"],
                tile_ape_std=float(np.std(apes)) if apes else float("nan"),
                fusion_mape=resf["mean_mape"],
                fusion_mape_std=float(np.std(mapes)) if mapes
                else float("nan")))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
