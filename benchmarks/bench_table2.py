"""Table 2: learned vs analytical on the randomly split test set.

Left half — tile-size task: per-program Tile-Size APE + Kendall τ.
Right half — fusion task: per-program MAPE (runtimes ≥ 5 'µs'-equivalent
threshold) + Kendall τ. The threshold is scaled to this corpus's runtime
distribution (the paper uses 5µs on its own; we use the median so the
"large kernels" emphasis carries over).
"""
from __future__ import annotations


from benchmarks.common import (
    analytical_fusion_predictor,
    build_world,
    csv_row,
    paper_fusion_model,
    paper_tile_model,
    steps,
    train_cost_model,
)
from repro.core.analytical import AnalyticalModel
from repro.core.evaluate import (
    analytical_tile_scorer,
    eval_fusion_task,
    eval_tile_task,
    learned_runtime_predictor,
    learned_tile_scorer,
)

MIN_RUNTIME = 5e-6


def run(method: str = "random") -> list[str]:
    world = build_world()
    rows = []

    # ---------------- tile task
    mc_tile = paper_tile_model()
    params = train_cost_model(world, mc_tile, task="tile", method=method,
                              n_steps=steps(3000))
    learned = eval_tile_task(
        world.tile_subset(method, "test"),
        learned_tile_scorer(params, mc_tile, world.normalizers[method],
                            max_nodes=mc_tile.max_nodes, chunk=64))
    ana = eval_tile_task(world.tile_subset(method, "test"),
                         analytical_tile_scorer(AnalyticalModel()))
    for prog in sorted(learned["per_program"]):
        rows.append(csv_row(
            f"table2.tile.{method}.{prog}",
            learned_ape=learned["per_program"][prog]["ape"],
            analytical_ape=ana["per_program"][prog]["ape"],
            learned_tau=learned["per_program"][prog]["kendall"],
            analytical_tau=ana["per_program"][prog]["kendall"]))
    rows.append(csv_row(f"table2.tile.{method}.MEAN",
                        learned_ape=learned["mean_ape"],
                        analytical_ape=ana["mean_ape"],
                        learned_tau=learned["mean_kendall"],
                        analytical_tau=ana["mean_kendall"]))
    rows.append(csv_row(f"table2.tile.{method}.MEDIAN",
                        learned_ape=learned["median_ape"],
                        analytical_ape=ana["median_ape"],
                        learned_tau=learned["median_kendall"],
                        analytical_tau=ana["median_kendall"]))

    # ---------------- fusion task
    mc_f = paper_fusion_model()
    params_f = train_cost_model(world, mc_f, task="fusion", method=method,
                                n_steps=steps(3000))
    pred = learned_runtime_predictor(params_f, mc_f,
                                     world.normalizers[method],
                                     max_nodes=mc_f.max_nodes, chunk=64)
    fl = eval_fusion_task(world.fusion_subset(method, "test"), pred,
                          min_runtime=MIN_RUNTIME)
    fa = eval_fusion_task(world.fusion_subset(method, "test"),
                          analytical_fusion_predictor(world, method),
                          min_runtime=MIN_RUNTIME)
    for prog in sorted(fl["per_program"]):
        if prog not in fa["per_program"]:
            continue
        rows.append(csv_row(
            f"table2.fusion.{method}.{prog}",
            learned_mape=fl["per_program"][prog]["mape"],
            analytical_mape=fa["per_program"][prog]["mape"],
            learned_tau=fl["per_program"][prog]["kendall"],
            analytical_tau=fa["per_program"][prog]["kendall"]))
    rows.append(csv_row(f"table2.fusion.{method}.MEAN",
                        learned_mape=fl["mean_mape"],
                        analytical_mape=fa["mean_mape"],
                        learned_tau=fl["mean_kendall"],
                        analytical_tau=fa["mean_kendall"]))
    rows.append(csv_row(f"table2.fusion.{method}.MEDIAN",
                        learned_mape=fl["median_mape"],
                        analytical_mape=fa["median_mape"],
                        learned_tau=fl["median_kendall"],
                        analytical_tau=fa["median_kendall"]))
    # small-kernel slice (paper reports <5µs separately)
    fl_small = eval_fusion_task(world.fusion_subset(method, "test"), pred)
    fa_small = eval_fusion_task(world.fusion_subset(method, "test"),
                                analytical_fusion_predictor(world, method))
    rows.append(csv_row(f"table2.fusion.{method}.ALL_KERNELS",
                        learned_mape=fl_small["mean_mape"],
                        analytical_mape=fa_small["mean_mape"]))
    return rows


def main():
    for r in run("random"):
        print(r)


if __name__ == "__main__":
    main()
