"""Encode-once feature cache + async prefetch vs the old synchronous
per-config encoder (DESIGN.md §9; acceptance gate for the input pipeline).

The tile task re-scores every kernel under many tile configurations; before
this pipeline the sampler re-ran full feature extraction per config with a
per-node Python loop. This bench replays the same deterministic batch
stream two ways:

  * old — `node_features_reference` (per-node loop) + `EncodeCache(0)`
    (every draw encodes fresh) + synchronous encode in the train loop: the
    pre-cache behavior of every call site.
  * new — vectorized `node_features` + the shared structural `EncodeCache`
    (tile variants rewrite only `TILE_SLICE`) + `TrainerConfig.prefetch`
    encode-ahead.

Gates (all must hold):
  1. sampler encode throughput (dense tile batches)   >= 3.0x
  2. end-to-end `CostModelTrainer` steps/s on CPU     >= 1.5x
  3. cached-path predictions vs old encoder           max delta < 1e-6
  4. prefetched batch stream vs synchronous           byte-identical

  PYTHONPATH=src python benchmarks/bench_input_pipeline.py

`BENCH_SCALE` scales kernel/step *counts*, never kernel *sizes* (see
benchmarks/common.py) — the encode-vs-step cost ratio the gates measure is
scale-independent. Margins are wide (measured ~24x encode / ~2.3x
steps/s at scale 1.0, ~5x steps/s at 0.5), so the scaled-down CI run
keeps headroom.
"""
from __future__ import annotations

import os
import sys
import time
from contextlib import contextmanager

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import features as F
from repro.core.evaluate import make_predict_fn
from repro.core.model import CostModelConfig, cost_model_init
from repro.core.simulator import TPUSimulator
from repro.data.prefetch import Prefetcher
from repro.data.sampler import TileBatchSampler
from repro.data.synthetic import random_kernel
from repro.data.tile_dataset import build_tile_dataset, fit_tile_normalizer
from repro.training.trainer import CostModelTrainer, TrainerConfig

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
MAX_NODES = 48
# BENCH_SCALE scales how MANY kernels/steps run, never how BIG the kernels
# are — the encode-vs-step cost ratio (what the gates measure) must not
# depend on the scale knob (see benchmarks/common.py).
NUM_KERNELS = max(int(24 * SCALE), 12)
KERNEL_NODES = (28, 34, 40, 48)            # cycled; sizes fixed at any scale
ENCODE_STEPS = max(int(40 * SCALE), 15)
TRAIN_WARM = 3
TRAIN_STEPS = max(int(30 * SCALE), 12)

_VECTORIZED_NODE_FEATURES = F.node_features


@contextmanager
def encoder(mode: str):
    """'old' = reference per-node-loop encoder, caching disabled;
    'new' = vectorized encoder + a fresh EncodeCache."""
    F.node_features = (F.node_features_reference if mode == "old"
                       else _VECTORIZED_NODE_FEATURES)
    prev = F.set_encode_cache(F.EncodeCache(0 if mode == "old" else 4096))
    try:
        yield
    finally:
        F.node_features = _VECTORIZED_NODE_FEATURES
        F.set_encode_cache(prev)


def make_sampler(records, norm, adjacency: str) -> TileBatchSampler:
    return TileBatchSampler(records, norm, kernels_per_batch=4,
                            configs_per_kernel=16, max_nodes=MAX_NODES,
                            seed=0, adjacency=adjacency)


def time_stream(sampler, steps: int, warm: int = 5) -> float:
    """Steady-state batch-encode time: `warm` untimed steps first, so the
    cached path is measured with the structural cache populated (the
    training regime — every kernel recurs across thousands of steps) and
    the uncached path amortizes nothing either way."""
    for s in range(warm):
        sampler.batch(s)
    t0 = time.perf_counter()
    for s in range(warm, warm + steps):
        sampler.batch(s)
    return time.perf_counter() - t0


def batches_equal(a, b) -> bool:
    """Byte-identical TileBatch comparison (targets/groups/valid + every
    array leaf of the encoded graphs)."""
    fields = [(a.targets, b.targets), (a.group_ids, b.group_ids),
              (a.valid, b.valid)]
    fields += list(zip(jax.tree_util.tree_leaves(a.graphs),
                       jax.tree_util.tree_leaves(b.graphs)))
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in fields)


def train_steps_per_sec(mc, records, norm, *, prefetch: int) -> float:
    sampler = make_sampler(records, norm, mc.adjacency)
    tc = TrainerConfig(task="tile", steps=TRAIN_WARM + TRAIN_STEPS,
                       ckpt_every=0, log_every=10 ** 9, prefetch=prefetch)
    tr = CostModelTrainer(mc, tc, sampler)
    tr.run(TRAIN_WARM, resume=False)            # compile + warm the caches
    t0 = time.perf_counter()
    tr.run(TRAIN_WARM + TRAIN_STEPS, resume=False)
    jax.block_until_ready(tr.params)
    return TRAIN_STEPS / (time.perf_counter() - t0)


def main() -> int:
    t_start = time.perf_counter()
    sim = TPUSimulator()
    kernels = [random_kernel(KERNEL_NODES[i % len(KERNEL_NODES)], seed=i)
               for i in range(NUM_KERNELS)]
    ds = build_tile_dataset([], sim, extra_kernels=kernels,
                            max_configs_per_kernel=16,
                            max_kernel_nodes=MAX_NODES)
    records = ds.records
    norm = fit_tile_normalizer(records)
    bs = 4 * 16
    print(f"bench_input_pipeline: {len(records)} kernels, "
          f"{ds.num_samples} (kernel, tile) samples, batch={bs}")

    # --- 1. sampler encode throughput -------------------------------------
    enc = {}
    for adjacency in ("dense", "sparse"):
        for mode in ("old", "new"):
            with encoder(mode):
                dt = time_stream(make_sampler(records, norm, adjacency),
                                 ENCODE_STEPS)
            enc[mode, adjacency] = ENCODE_STEPS * bs / dt
            print(f"  encode {adjacency:6s} {mode}: "
                  f"{enc[mode, adjacency]:8.0f} graphs/s")
    enc_speedup = enc["new", "dense"] / enc["old", "dense"]
    sparse_speedup = enc["new", "sparse"] / enc["old", "sparse"]
    print(f"  encode speedup: dense {enc_speedup:.2f}x, "
          f"sparse {sparse_speedup:.2f}x")

    # --- 2. end-to-end trainer steps/s ------------------------------------
    mc = CostModelConfig(gnn="graphsage", reduction="column_wise",
                         hidden_dim=16, opcode_embed_dim=16, gnn_layers=2,
                         dropout=0.1, max_nodes=MAX_NODES, adjacency="dense")
    with encoder("old"):
        sps_old = train_steps_per_sec(mc, records, norm, prefetch=0)
    with encoder("new"):
        sps_new = train_steps_per_sec(mc, records, norm, prefetch=3)
    e2e_speedup = sps_new / sps_old
    print(f"  train old: {sps_old:6.1f} steps/s   "
          f"new(+cache+prefetch): {sps_new:6.1f} steps/s   "
          f"-> {e2e_speedup:.2f}x")

    # --- 3. prediction delta: cached path vs the old encoder --------------
    params = cost_model_init(jax.random.key(0), mc)
    predict = make_predict_fn(mc)
    deltas = []
    for step in range(3):
        with encoder("old"):
            b_old = make_sampler(records, norm, "dense").batch(step)
        with encoder("new"):
            b_new = make_sampler(records, norm, "dense").batch(step)
        p_old = np.asarray(predict(params, b_old.graphs))
        p_new = np.asarray(predict(params, b_new.graphs))
        deltas.append(float(np.max(np.abs(p_old - p_new))))
        if not batches_equal(b_old, b_new):
            deltas.append(float("inf"))       # encoders diverged
    delta = max(deltas)
    print(f"  max prediction delta cached-vs-old-encoder: {delta:.2e}")

    # --- 4. prefetched stream == synchronous stream -----------------------
    sync = make_sampler(records, norm, "dense")
    with Prefetcher(make_sampler(records, norm, "dense"), depth=3) as pre:
        stream_ok = all(batches_equal(sync.batch(s), pre.batch(s))
                        for s in range(6))
        # simulated restart mid-stream: a fresh prefetcher seeked to step 3
        with Prefetcher(make_sampler(records, norm, "dense"), depth=3,
                        start_step=3) as pre2:
            stream_ok &= batches_equal(sync.batch(3), pre2.batch(3))
    print(f"  prefetched stream byte-identical: {stream_ok}")

    from common import Gate, emit_json
    ok = emit_json(
        "input_pipeline",
        [Gate("encode_speedup", enc_speedup, 3.0),
         Gate("train_steps_speedup", e2e_speedup, 1.5),
         Gate("prediction_delta", delta, 1e-6, "<"),
         Gate("prefetch_stream_identical", bool(stream_ok), True, "==")],
        wall_s=time.perf_counter() - t_start,
        extra={"sparse_encode_speedup": sparse_speedup,
               "steps_per_sec_old": sps_old, "steps_per_sec_new": sps_new})
    print(f"bench_input_pipeline: {'PASS' if ok else 'FAIL'} "
          f"(need >=3x encode, >=1.5x steps/s, delta <1e-6, identical "
          f"stream; got {enc_speedup:.2f}x / {e2e_speedup:.2f}x / "
          f"{delta:.1e} / {stream_ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
