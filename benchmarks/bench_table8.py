"""Table 8: same evaluation as Table 2 but on the *manual* split — whole
program families (convdraw, embedding) held out of training. Expectation
per the paper: the learned model degrades on tile ranking (test programs
chosen for dissimilarity) but still beats the analytical model on fusion
MAPE."""
from benchmarks import bench_table2


def run():
    return [r.replace("table2.", "table8.", 1)
            for r in bench_table2.run("manual")]


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
