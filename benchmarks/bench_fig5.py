"""Fig 5: fusion autotuner with a hardware-time budget.

Per program, best speedup over the compiler-default fusion config for:
  * HW 10m          — simulated annealing directly on hardware,
  * CM + HW 1m      — anneal on the learned cost model, validate the top
                      configs within a 10x smaller hardware budget,
  * CM + HW 10m     — same with the full budget.
Hardware minutes are simulated (eval_seconds per config), scaled 1:10 to
keep CPU time sane — the comparison is budget-relative either way. Repeated
3x (different SA seeds); reports median/min/max like the figure's bars.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    MAX_NODES,
    build_world,
    csv_row,
    paper_fusion_model,
    steps,
    train_cost_model,
)
from repro.autotuner import simulated_annealing_fusion
from repro.core.evaluate import make_predict_fn, predict_kernels

EVAL_SECONDS = 2.0
HW_BUDGET_10M = 60.0      # '10 minutes' at 1:10 scale
HW_BUDGET_1M = 6.0
REPEATS = 3


def run() -> list[str]:
    world = build_world()
    mc = paper_fusion_model()
    params = train_cost_model(world, mc, task="fusion", method="random",
                              n_steps=steps(1500))
    predict_fn = make_predict_fn(mc)
    norm = world.normalizers["random"]

    def model_cost(kernels):
        kernels = [k for k in kernels if k.num_nodes <= MAX_NODES]
        if not kernels:
            return 0.0
        scores = predict_kernels(params, mc, kernels, norm,
                                 max_nodes=MAX_NODES, chunk=64,
                                 predict_fn=predict_fn)
        return float(np.sum(np.exp(scores)))

    rows = []
    # programs that gain from fusion autotuning (paper picks such a set)
    candidates = world.splits["random"]["test"] + \
        world.splits["random"]["val"]
    by_name = {p.program: p for p in world.programs}
    for prog_name in candidates[:5]:
        prog = by_name[prog_name]
        res = {"hw10": [], "cm1": [], "cm10": []}
        for rep in range(REPEATS):
            r_hw = simulated_annealing_fusion(
                prog, world.sim, model_cost=None,
                hardware_budget_s=HW_BUDGET_10M,
                eval_seconds=EVAL_SECONDS, seed=rep)
            r_cm1 = simulated_annealing_fusion(
                prog, world.sim, model_cost=model_cost,
                hardware_budget_s=HW_BUDGET_1M, model_steps=250,
                eval_seconds=EVAL_SECONDS, seed=rep)
            r_cm10 = simulated_annealing_fusion(
                prog, world.sim, model_cost=model_cost,
                hardware_budget_s=HW_BUDGET_10M, model_steps=250,
                eval_seconds=EVAL_SECONDS, seed=rep)
            res["hw10"].append(r_hw.speedup)
            res["cm1"].append(r_cm1.speedup)
            res["cm10"].append(r_cm10.speedup)
        rows.append(csv_row(
            f"fig5.{prog_name}",
            hw10_median=float(np.median(res["hw10"])),
            hw10_min=float(np.min(res["hw10"])),
            hw10_max=float(np.max(res["hw10"])),
            cm_hw1_median=float(np.median(res["cm1"])),
            cm_hw10_median=float(np.median(res["cm10"]))))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
