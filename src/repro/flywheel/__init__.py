"""Online data flywheel (DESIGN.md §15): measured runtimes feed the
corpus as delta shards, the cost model warm-start fine-tunes on the
base+delta stream, and the next search round spends its hardware budget
where the refreshed model is least certain.

measure  — `MeasurementLog` taps every charged `HardwareEstimator` eval
store    — `MeasurementLog.flush_to` appends a corpus delta shard
           (`CorpusWriter.append_delta`, chain-verified manifests)
retrain  — `fine_tune` warm-starts from the latest checkpoint on the
           `StreamingCorpus.with_deltas()` stream with a short warmup
search   — `AcquisitionEstimator` (repro.search) routes the remaining
           `BudgetMeter` seconds to the highest-variance candidates
loop     — `run_flywheel` chains k measure→append→fine-tune→search
           rounds (`launch/flywheel.py` is the CLI driver)
"""
from repro.flywheel.log import MeasurementLog
from repro.flywheel.loop import FlywheelConfig, FlywheelResult, run_flywheel
from repro.flywheel.retrain import fine_tune, tile_val_loss

__all__ = [
    "FlywheelConfig",
    "FlywheelResult",
    "MeasurementLog",
    "fine_tune",
    "run_flywheel",
    "tile_val_loss",
]
