"""Incremental retraining: warm-start fine-tune on base+delta streams.

TLP (PAPERS.md) motivates the shape of this: adapting an existing
checkpoint on fresh measurements reaches the from-scratch model's
quality in a fraction of the steps, which is what makes per-round
retraining affordable inside a search loop. `fine_tune` wires the
pieces the trainer already has — `CostModelTrainer.warm_start` (params
+ AdamW moments from the previous round's checkpoint, optimizer step
counter reset so `AdamWConfig.warmup_steps` re-warms the LR) over a
`TileBatchSampler` on any record sequence, typically a
`StreamingCorpus.with_deltas()` chained view.

`tile_val_loss` is the deterministic yardstick both bench gates use:
the pairwise rank loss of deterministic predictions over a fixed set of
sampler batches — no dropout, no step dependence, directly comparable
across models and rounds.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.losses import pairwise_rank_loss
from repro.core.model import CostModelConfig
from repro.data.sampler import TileBatchSampler
from repro.training.optim import AdamWConfig
from repro.training.trainer import CostModelTrainer, TrainerConfig


def tile_val_loss(params, model_cfg: CostModelConfig, sampler, *,
                  batches: int = 8, rank_phi: str = "hinge",
                  predict_fn=None) -> float:
    """Mean deterministic pairwise rank loss over `sampler.batch(0..b)`.

    Batch purity (`batch(step)` is a pure function of step) makes this a
    fixed eval set: every call scores the same batches, so two models'
    losses — or one model's loss across fine-tune rounds — are exactly
    comparable. Pass a cached `predict_fn` (from
    `core.evaluate.make_predict_fn`) when calling repeatedly to reuse
    the compiled executable.
    """
    if predict_fn is None:
        from repro.core.evaluate import make_predict_fn
        predict_fn = make_predict_fn(model_cfg)
    total = 0.0
    for step in range(batches):
        b = sampler.batch(step)
        preds = predict_fn(params, b.graphs)
        gids = getattr(b, "group_ids", np.zeros_like(b.targets, np.int32))
        total += float(pairwise_rank_loss(
            preds, jnp.asarray(b.targets), jnp.asarray(gids),
            jnp.asarray(b.valid), phi=rank_phi))
    return total / max(batches, 1)


@dataclass
class FineTuneResult:
    params: dict
    steps: int
    from_step: int                 # checkpoint step warm-started from
    final_train_loss: float
    val_history: list = field(default_factory=list)   # (step, val_loss)


def fine_tune(records, normalizer, model_cfg: CostModelConfig, *,
              warm_start_dir: str, steps: int, ckpt_dir: str = "",
              lr: float = 1e-3, warmup_steps: int = 20, seed: int = 0,
              kernels_per_batch: int = 4, configs_per_kernel: int = 8,
              reset_opt_step: bool = True, val_sampler=None,
              eval_every: int = 0, val_batches: int = 8,
              rank_phi: str = "hinge") -> FineTuneResult:
    """Warm-start fine-tune the tile cost model on `records`.

    `records` is any record sequence the samplers accept — in the
    flywheel, the `with_deltas()` chained view of the measurement store.
    Restores params + optimizer moments from the latest checkpoint in
    `warm_start_dir`, resets the optimizer step counter (unless
    `reset_opt_step=False`) so the LR re-warms over `warmup_steps`, and
    trains `steps` steps from a fresh step-0 (``resume=False`` — a
    previous round's checkpoint in `ckpt_dir` must not short-circuit the
    run). With `val_sampler` + `eval_every`, records a
    `tile_val_loss` trajectory in ``val_history``.
    """
    sampler = TileBatchSampler(
        records, normalizer, kernels_per_batch=kernels_per_batch,
        configs_per_kernel=configs_per_kernel,
        max_nodes=model_cfg.max_nodes, seed=seed,
        adjacency=("dense" if model_cfg.adjacency == "dense" else "sparse"))
    cfg = TrainerConfig(
        task="tile", rank_phi=rank_phi, steps=steps,
        ckpt_every=steps, log_every=max(steps // 4, 1), seed=seed,
        ckpt_dir=ckpt_dir,
        optim=AdamWConfig(lr=lr, warmup_steps=warmup_steps))
    trainer = CostModelTrainer(model_cfg, cfg, sampler)
    from_step = trainer.warm_start(warm_start_dir,
                                   reset_opt_step=reset_opt_step)
    history: list = []
    eval_fn = None
    if val_sampler is not None and eval_every:
        from repro.core.evaluate import make_predict_fn
        predict = make_predict_fn(model_cfg)

        def eval_fn(params, step):
            v = tile_val_loss(params, model_cfg, val_sampler,
                              batches=val_batches, rank_phi=rank_phi,
                              predict_fn=predict)
            history.append((step, v))
            return {"val_loss": v}

    res = trainer.run(resume=False, eval_fn=eval_fn, eval_every=eval_every)
    return FineTuneResult(params=trainer.params, steps=res["step"],
                          from_step=from_step,
                          final_train_loss=res["loss"],
                          val_history=history)
