"""The flywheel driver: k measure→append→fine-tune→search rounds.

Each round spends an equal slice of one shared `BudgetMeter` on the
candidates the current model is least certain about
(`AcquisitionEstimator.acquire`), appends the paid measurements to the
corpus store as a chain-verified delta shard (`MeasurementLog.flush_to`
→ `CorpusWriter.append_delta`), and warm-start fine-tunes the model on
the base+delta stream (`fine_tune` from the previous round's
checkpoint). Selection quality is reported as deploy-and-observe
regret: per kernel, the best of (everything measured so far, the
current model's top pick run once) against the exhaustive oracle
optimum — the same rule a static model is scored with at equal budget,
which is the `bench_flywheel` gate.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass

import numpy as np

from repro.core.model import CostModelConfig
from repro.core.simulator import TPUSimulator
from repro.data.store import StreamingCorpus
from repro.data.tile_dataset import enumerate_tiles
from repro.flywheel.log import MeasurementLog
from repro.flywheel.retrain import fine_tune
from repro.search import (
    AcquisitionEstimator,
    BudgetMeter,
    HardwareEstimator,
    LearnedEstimator,
)
from repro.training.optim import adamw_init


@dataclass
class FlywheelConfig:
    rounds: int = 3
    budget_evals: int = 48        # TOTAL hardware evals across all rounds
    eval_seconds: float = 2.0     # BudgetMeter cost of one eval
    finetune_steps: int = 120
    warmup_steps: int = 20
    lr: float = 1e-3
    mc_samples: int = 8           # MC-dropout passes per score
    spread: str = "kernel"        # acquisition routing policy
    # LCB exploitation/exploration balance: candidates are acquired by
    # lowest (mean - kappa * std). None = pure highest-variance routing
    # (too risky: it happily burns the whole budget on candidates the
    # mean already calls slow). kappa must be calibrated to the variance
    # head: MC-dropout stds run ~3-5x smaller than the model's actual
    # error margins, so with kappa ~ 1 the kappa*std term never
    # overturns a confident mean and LCB degenerates into the static
    # ranking — the loop then measures exactly the static plan's
    # candidates and can only tie it. 6.0 scales the std up to where
    # the plan explores just past the static top-k frontier (which is
    # precisely where a kernel the static model ranks badly keeps its
    # true best), while staying mean-anchored enough not to waste evals
    # on predicted-slow outliers.
    kappa: float | None = 6.0
    # Oversampling of the measured target sweeps during fine-tune: each
    # multi-config sweep the log has accumulated appears `delta_boost`
    # times in the round's training stream (once via the store's chained
    # view + boost-1 extra copies under alias program names). The alias
    # is the load-bearing part: `TileBatchSampler` balances draws
    # per-PROGRAM, so extra records filed under the same program change
    # nothing — each alias is its own draw slot, multiplying the
    # target's draw probability. Without it, uniform program sampling
    # starves the rank loss of exactly the within-sweep contrast the
    # round just paid for (the target programs are a sliver of the
    # corpus), and the fine-tuned model's top pick never moves off the
    # static model's.
    delta_boost: int = 4
    seed: int = 0
    kernels_per_batch: int = 4
    configs_per_kernel: int = 8
    max_configs: int = 24         # candidate tiles enumerated per kernel


@dataclass
class RoundStats:
    round: int
    measured: int                 # hardware evals charged this round
    delta_records: int            # records in the appended delta (0 = none)
    regret: float                 # deploy-and-observe regret after round
    train_loss: float
    # the raw (group, candidate, runtime) acquisition stream, in charge
    # order — what a from-scratch rebuild of this round's delta replays
    acquired: list = None


@dataclass
class FlywheelResult:
    rounds: list[RoundStats]
    params: dict                  # final fine-tuned params
    truth: list[np.ndarray]       # oracle runtimes per group (eval only)
    measured: list[dict]          # per group: {candidate: runtime}
    evals_charged: int
    regret0: float                # static (round-0) model, model-pick only

    @property
    def final_regret(self) -> float:
        return self.rounds[-1].regret if self.rounds else self.regret0


def deploy_regret(truth, scores, measured) -> float:
    """Mean relative regret under deploy-and-observe selection: per
    group, run the model's top pick once and keep the best runtime seen
    (that pick plus everything already measured)."""
    regs = []
    for t, s, m in zip(truth, scores, measured):
        cand = [float(t[int(np.argmin(s))])]
        cand.extend(float(t[ci]) for ci in m)
        regs.append(min(cand) / float(np.min(t)) - 1.0)
    return float(np.mean(regs))


def static_plan(scores, budget: int) -> list[dict]:
    """The uniform-exploitation baseline plan: round-robin over groups,
    each group measuring its next-best candidate by static model score,
    until `budget` evals are allotted. Returns per-group candidate sets
    (the `measured` shape `deploy_regret` takes)."""
    orders = [list(np.argsort(np.asarray(s), kind="stable"))
              for s in scores]
    picks: list[set] = [set() for _ in scores]
    allotted, depth = 0, 0
    while allotted < budget and any(depth < len(o) for o in orders):
        for gi, o in enumerate(orders):
            if allotted >= budget:
                break
            if depth < len(o):
                picks[gi].add(int(o[depth]))
                allotted += 1
        depth += 1
    return [dict.fromkeys(p) for p in picks]


def run_flywheel(sim: TPUSimulator, store_dir: str, target_kernels,
                 params0, model_cfg: CostModelConfig, normalizer,
                 cfg: FlywheelConfig, *, ckpt_dir: str,
                 tiles=None) -> FlywheelResult:
    """Run `cfg.rounds` flywheel rounds against `store_dir`.

    `target_kernels` are the (untiled) kernels being tuned; candidates
    are their `enumerate_tiles` sweeps (or `tiles`, a parallel list of
    tile lists). `params0` is the static round-0 model; its checkpoint
    chain grows under `ckpt_dir` (``round-00`` holds params0, each round
    r fine-tunes from ``round-<r>`` into ``round-<r+1>``). The exhaustive
    oracle pass used for regret reporting is an *eval harness* — it never
    touches the meter, exactly like the autotuners' `exhaustive_truth`.
    """
    from repro.training import checkpoint as ckpt_lib

    target_kernels = list(target_kernels)
    if tiles is None:
        tiles = [enumerate_tiles(k, max_configs=cfg.max_configs)
                 for k in target_kernels]
    groups = [[k.with_tile(t) for t in ts]
              for k, ts in zip(target_kernels, tiles)]
    truth = [np.array([sim.measure(g) for g in grp], np.float64)
             for grp in groups]                      # oracle: uncharged

    meter = BudgetMeter(budget_s=cfg.budget_evals * cfg.eval_seconds,
                        eval_seconds=cfg.eval_seconds)
    mlog = MeasurementLog("tile")
    hw = HardwareEstimator(sim, meter=meter, log=mlog)

    cur_ckpt = os.path.join(ckpt_dir, "round-00")
    ckpt_lib.save_checkpoint(cur_ckpt, 0,
                             {"params": params0,
                              "opt": adamw_init(params0)},
                             meta={"flywheel_round": 0})
    cur_params = params0

    static = LearnedEstimator.from_params(
        params0, model_cfg, normalizer, max_nodes=model_cfg.max_nodes,
        cache_capacity=0)
    scores0 = static.estimate_groups(groups)
    regret0 = deploy_regret(truth, scores0, [()] * len(groups))

    measured: list[dict] = [{} for _ in groups]
    exclude: set[tuple[int, int]] = set()
    rounds: list[RoundStats] = []
    for r in range(cfg.rounds):
        acq = AcquisitionEstimator(
            cur_params, model_cfg, normalizer, samples=cfg.mc_samples,
            seed=cfg.seed + r, max_nodes=model_cfg.max_nodes)
        share = -(-cfg.budget_evals // cfg.rounds)   # ceil split
        triples = acq.acquire(groups, hw, budget=share,
                              spread=cfg.spread, exclude=exclude,
                              kappa=cfg.kappa)
        for gi, ci, rt in triples:
            measured[gi][ci] = rt
            exclude.add((gi, ci))
        manifest = mlog.flush_to(store_dir, min_configs=1,
                                 note=f"flywheel round {r}")
        n_delta = manifest["stats"]["records"] if manifest else 0
        chained = StreamingCorpus.open(store_dir).with_deltas()
        train_recs = chained
        if cfg.delta_boost > 1:
            sweeps = mlog.records(min_configs=2)
            if sweeps:
                train_recs = list(chained) + [
                    dataclasses.replace(s, program=f"{s.program}~b{j}")
                    for j in range(1, cfg.delta_boost)
                    for s in sweeps]
        next_ckpt = os.path.join(ckpt_dir, f"round-{r + 1:02d}")
        ft = fine_tune(train_recs, normalizer, model_cfg,
                       warm_start_dir=cur_ckpt, steps=cfg.finetune_steps,
                       ckpt_dir=next_ckpt, lr=cfg.lr,
                       warmup_steps=cfg.warmup_steps, seed=cfg.seed + r,
                       kernels_per_batch=cfg.kernels_per_batch,
                       configs_per_kernel=cfg.configs_per_kernel)
        if os.environ.get("REPRO_FLYWHEEL_DEBUG"):
            import jax
            delta = sum(float(np.abs(np.asarray(a) - np.asarray(b)).sum())
                        for a, b in zip(jax.tree.leaves(cur_params),
                                        jax.tree.leaves(ft.params)))
            n_rec = len(train_recs) if train_recs is not chained \
                else len(chained)
            progs = {getattr(r, "program", "?") for r in (
                train_recs if train_recs is not chained else [])}
            print(f"    [fw-dbg] round {r}: sweeps="
                  f"{len(mlog.records(min_configs=2))} train_recs={n_rec} "
                  f"alias_progs={sum('~b' in p for p in progs)} "
                  f"param_delta={delta:.3e} "
                  f"train_loss={ft.final_train_loss:.4f}")
        cur_params, cur_ckpt = ft.params, next_ckpt
        learned = LearnedEstimator.from_params(
            cur_params, model_cfg, normalizer,
            max_nodes=model_cfg.max_nodes, cache_capacity=0)
        scores = learned.estimate_groups(groups)
        if os.environ.get("REPRO_FLYWHEEL_DEBUG"):
            picks = [int(np.argmin(s)) for s in scores]
            picks0 = [int(np.argmin(s)) for s in scores0]
            print(f"    [fw-dbg] round {r}: picks {picks} "
                  f"(static {picks0})")
        rounds.append(RoundStats(
            round=r, measured=len(triples), delta_records=n_delta,
            regret=deploy_regret(truth, scores, measured),
            train_loss=ft.final_train_loss, acquired=list(triples)))
    return FlywheelResult(rounds=rounds, params=cur_params, truth=truth,
                          measured=measured, evals_charged=meter.evals,
                          regret0=regret0)
