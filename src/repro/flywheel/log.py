"""MeasurementLog: the measure→store tap of the data flywheel.

Every measurement a `HardwareEstimator` charges to the `BudgetMeter` is
a labeled training example the run already paid hardware seconds for.
`MeasurementLog` collects those (kernel, runtime) pairs — grouping tile
variants of the same kernel into one `TileKernelRecord` sweep so the
pairwise rank loss has within-kernel contrast — and `flush_to` appends
them to a corpus store as a chain-verified delta shard
(`CorpusWriter.append_delta`).

>>> from repro.core.simulator import TPUSimulator
>>> from repro.data.synthetic import random_kernel
>>> from repro.flywheel import MeasurementLog
>>> from repro.search import HardwareEstimator
>>> log = MeasurementLog("tile")
>>> hw = HardwareEstimator(TPUSimulator(), log=log)
>>> g = random_kernel(8, seed=0)
>>> _ = hw.estimate([g.with_tile((8, 8)), g.with_tile((16, 8))])
>>> _ = hw.estimate([g.with_tile((8, 8))])      # repeat: deduplicated
>>> (len(log), log.duplicates, len(log.records()))
(2, 1, 1)
>>> log.records()[0].tiles
[(8, 8), (16, 8)]
>>> len(log.take_pending())                     # flush 1: the sweep
1
>>> _ = hw.estimate([g.with_tile((4, 4))])      # sweep grows...
>>> [r.tiles for r in log.take_pending()]       # flush 2: re-emitted whole
[[(8, 8), (16, 8), (4, 4)]]
>>> log.take_pending()                          # nothing new -> nothing
[]
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.core.graph import KernelGraph
from repro.data.fusion_dataset import FusionKernelRecord
from repro.data.store import KINDS, CorpusWriter
from repro.data.tile_dataset import TileKernelRecord


class MeasurementLog:
    """Accumulates charged (kernel, runtime) measurements into dataset
    records, deduplicating repeats of the same (kernel, tile).

    Tile kind: measurements are grouped by the kernel's order-sensitive
    `structural_digest` — every tile variant of one kernel lands in the
    same group, so one flushed `TileKernelRecord` carries a multi-config
    sweep (the within-kernel contrast the rank loss trains on). Fusion
    kind: one `FusionKernelRecord` per distinct kernel (first runtime
    wins, matching the store's first-occurrence dedup).

    Flushing does NOT reset the groups: a flush emits the *cumulative*
    sweep of every group that gained measurements since the last flush,
    and later flushes re-emit a group's full sweep once it grows again.
    A search loop that measures one tile per kernel per round therefore
    still produces multi-config records from round 1 on — per-round
    incremental records would be 1-config sweeps the pairwise rank loss
    is blind to, and the fine-tune stage would never actually learn the
    kernels being tuned.
    """

    def __init__(self, kind: str = "tile"):
        if kind not in KINDS:
            raise ValueError(f"unknown corpus kind {kind!r}")
        self.kind = kind
        # digest -> {"kernel": base, "program": str,
        #            "tiles": [...], "runtimes": [...], "seen": set,
        #            "flushed": int}  (tiles already emitted by a flush;
        #            fusion groups use a bool)
        self._groups: OrderedDict = OrderedDict()
        self.total = 0        # record() calls observed
        self.duplicates = 0   # repeats of an already-logged (kernel, tile)

    def record(self, kernel: KernelGraph, runtime: float) -> bool:
        """Log one measured (kernel, runtime); False if already logged."""
        self.total += 1
        if self.kind == "fusion":
            key = kernel.canonical_hash(order_sensitive=True)
            if key in self._groups:
                self.duplicates += 1
                return False
            self._groups[key] = {"kernel": kernel,
                                 "runtime": float(runtime),
                                 "flushed": False}
            return True
        key = kernel.structural_digest(order_sensitive=True)
        tile = tuple(int(x) for x in kernel.tile_size)
        g = self._groups.get(key)
        if g is None:
            base = kernel.with_tile(()) if kernel.tile_size else kernel
            g = self._groups[key] = {"kernel": base,
                                     "program": kernel.program,
                                     "tiles": [], "runtimes": [],
                                     "seen": set(), "flushed": 0}
        if tile in g["seen"]:
            self.duplicates += 1
            return False
        g["seen"].add(tile)
        g["tiles"].append(tile)
        g["runtimes"].append(float(runtime))
        return True

    def __len__(self) -> int:
        """Distinct measurements retained (post-dedup)."""
        if self.kind == "fusion":
            return len(self._groups)
        return sum(len(g["tiles"]) for g in self._groups.values())

    def _materialize(self, groups) -> list:
        if self.kind == "fusion":
            return [FusionKernelRecord(g["kernel"], g["runtime"],
                                       program=g["kernel"].program)
                    for g in groups]
        return [TileKernelRecord(kernel=g["kernel"], tiles=list(g["tiles"]),
                                 runtimes=np.asarray(g["runtimes"],
                                                     np.float64),
                                 program=g["program"])
                for g in groups]

    def records(self, *, min_configs: int = 1) -> list:
        """Materialize ALL grouped measurements as dataset records.
        Tile groups with fewer than `min_configs` measured tiles are
        dropped (a 1-config sweep contributes no rank-loss signal)."""
        if self.kind == "fusion":
            return self._materialize(self._groups.values())
        return self._materialize(g for g in self._groups.values()
                                 if len(g["tiles"]) >= min_configs)

    def take_pending(self, *, min_configs: int = 1) -> list:
        """Records for every group that changed since the last take:
        the group's full *cumulative* sweep (see class docstring), with
        tile groups below `min_configs` held back — unmarked — until
        they grow past it. Marks what it returns as flushed."""
        if self.kind == "fusion":
            pend = [g for g in self._groups.values() if not g["flushed"]]
            for g in pend:
                g["flushed"] = True
            return self._materialize(pend)
        pend = [g for g in self._groups.values()
                if len(g["tiles"]) > g["flushed"]
                and len(g["tiles"]) >= min_configs]
        recs = self._materialize(pend)
        for g in pend:
            g["flushed"] = len(g["tiles"])
        return recs

    def clear(self) -> None:
        self._groups.clear()

    def flush_to(self, store_dir: str, *, min_configs: int = 1,
                 note: str = "") -> dict | None:
        """Append everything new since the last flush to `store_dir` as
        one delta shard (`CorpusWriter.append_delta` of `take_pending`).
        Groups stay live — a kernel measured again later flushes again,
        as a fresh record of its grown sweep. Returns the delta
        manifest, or None if nothing new to append."""
        recs = self.take_pending(min_configs=min_configs)
        return (CorpusWriter.append_delta(store_dir, recs, note=note)
                if recs else None)
