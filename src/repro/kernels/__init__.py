"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three files:
  kernel.py - pl.pallas_call body + explicit BlockSpec VMEM tiling
  ops.py    - the jit'd public wrapper (+ block-shape candidates for the
              tile-size autotuner)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

Kernels target TPU; on this CPU container they are validated with
interpret=True (the dry-run lowers the jnp paths instead; see DESIGN.md).
"""
