"""Fused GNN neighbor aggregation Pallas TPU kernel.

Computes, per graph in the batch:   out = A @ act(X @ W)
  A [N, N] dense directed adjacency (adj[d, s] = 1 iff edge s→d)
  X [N, D] node embeddings, W [D, F] the per-hop message transform (f2^k)

This is the TPU-native formulation of GraphSAGE aggregation (DESIGN.md §3):
for kernel graphs of ≤128 nodes a dense N×N adjacency matmul on the MXU
beats sparse gather/scatter, and fusing the two matmuls keeps the message
tensor act(XW) in VMEM — it never round-trips to HBM.

Grid: (B, num_f_blocks). BlockSpecs:
  A   [1, N, N]        index (b, 0, 0)
  X   [1, N, D]        index (b, 0, 0)
  W   [D, block_f]     index (0, jf)
  out [1, N, block_f]  index (b, 0, jf)
VMEM per step ≈ N·N + N·D + D·bf + 2·N·bf floats — N=64, D=F=512, bf=256
→ ~0.6 MB, far under VMEM; block_f exists for wider hidden dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, w_ref, o_ref, *, act: str, mean: bool):
    a = a_ref[0].astype(jnp.float32)                     # [N, N]
    x = x_ref[0].astype(jnp.float32)                     # [N, D]
    w = w_ref[...].astype(jnp.float32)                   # [D, bf]
    msg = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if act == "relu":
        msg = jnp.maximum(msg, 0.0)
    agg = jax.lax.dot_general(a, msg, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if mean:
        deg = jnp.sum(a, axis=1, keepdims=True)
        agg = agg / jnp.maximum(deg, 1.0)
    o_ref[0] = agg.astype(o_ref.dtype)


def graph_aggregate_bnd(adj: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray, *,
                        act: str = "relu", mean: bool = True,
                        block_f: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """adj: [B,N,N]; x: [B,N,D]; w: [D,F]. Returns [B,N,F] (x.dtype)."""
    B, N, D = x.shape
    F = w.shape[1]
    block_f = min(block_f, F)
    nf = -(-F // block_f)
    pad_f = nf * block_f - F
    if pad_f:
        w = jnp.pad(w, ((0, 0), (0, pad_f)))

    kernel = functools.partial(_kernel, act=act, mean=mean)
    out = pl.pallas_call(
        kernel,
        grid=(B, nf),
        in_specs=[
            pl.BlockSpec((1, N, N), lambda b, jf: (b, 0, 0)),
            pl.BlockSpec((1, N, D), lambda b, jf: (b, 0, 0)),
            pl.BlockSpec((D, block_f), lambda b, jf: (0, jf)),
        ],
        out_specs=pl.BlockSpec((1, N, block_f), lambda b, jf: (b, 0, jf)),
        out_shape=jax.ShapeDtypeStruct((B, N, nf * block_f), x.dtype),
        interpret=interpret,
    )(adj, x, w)
    return out[:, :, :F]
