"""Segment-sum oracle for graph_aggregate — computes the same quantity via
explicit edge-list gather/scatter (the 'GPU-ish' formulation), so the dense
MXU kernel is checked against an independent sparse derivation."""
from __future__ import annotations

import numpy as np


def graph_aggregate_ref(adj: np.ndarray, x: np.ndarray, w: np.ndarray, *,
                        act: str = "relu", mean: bool = True) -> np.ndarray:
    """adj: [B,N,N] (adj[b,d,s]); x: [B,N,D]; w: [D,F] -> [B,N,F]."""
    B, N, D = x.shape
    F = w.shape[1]
    msg = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
    if act == "relu":
        msg = np.maximum(msg, 0.0)
    out = np.zeros((B, N, F), np.float32)
    deg = np.zeros((B, N), np.float32)
    for b in range(B):
        dsts, srcs = np.nonzero(np.asarray(adj[b]) > 0)
        for d, s in zip(dsts, srcs):
            out[b, d] += msg[b, s]
            deg[b, d] += 1.0
    if mean:
        out = out / np.maximum(deg, 1.0)[..., None]
    return out.astype(np.asarray(x).dtype)
