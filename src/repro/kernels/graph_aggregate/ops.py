"""Public wrapper for the fused GNN aggregation kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.graph_aggregate.kernel import graph_aggregate_bnd


@partial(jax.jit, static_argnames=("act", "mean", "block_f", "interpret"))
def graph_aggregate(adj: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray, *,
                    act: str = "relu", mean: bool = True, block_f: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    return graph_aggregate_bnd(adj, x, w, act=act, mean=mean,
                               block_f=block_f, interpret=interpret)


def block_candidates(hidden: int) -> list[int]:
    """block_f candidates for the tile-size autotuner."""
    return [b for b in (64, 128, 256, 512, 1024) if b <= max(hidden, 64)]
