"""Flash attention Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv axis is the
minor (sequential) grid dimension, so the online-softmax state (running max,
normalizer, accumulator) lives in VMEM scratch and is carried across kv
steps; the output block is emitted at the last kv step.

BlockSpecs (all VMEM):
  q   [1, 1, block_q, head_dim]   index (b, h, iq, 0)
  k/v [1, 1, block_k, head_dim]   index (b, h // rep, ik, 0)  — GQA without
                                  materializing repeated KV heads
  out [1, 1, block_q, head_dim]   index (b, h, iq, 0)

Supports causal masking and sliding-window attention; blocks fully outside
the causal window are masked (grid shapes are static — a block-skip via a
sparser grid is a known further optimization, noted in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 block_q: int, block_k: int, seq_q: int, seq_k: int,
                 causal: bool, window: int | None, q_offset: int,
                 num_kv_blocks: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)                    # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    valid = k_pos < seq_k
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window is not None:
        valid = valid & (q_pos - k_pos < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: int | None = None,
                         q_offset: int = 0, block_q: int = 128,
                         block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, Sq, hd]; k, v: [B, KH, Sk, hd]; H % KH == 0."""
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    rep = H // KH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_q=Sq, seq_k=Sk,
        causal=causal, window=window, q_offset=q_offset, num_kv_blocks=nk,
        scale=1.0 / math.sqrt(hd))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
