"""Pure-jnp oracle for flash attention: dense masked softmax attention.

Deliberately independent of repro.models.layers (a separate derivation so a
shared bug can't hide)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: [B,H,Sq,hd]; k,v: [B,KH,Sk,hd]. Returns [B,H,Sq,hd] (q.dtype)."""
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    rep = H // KH
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window is not None:
        valid = valid & (q_pos - k_pos < window)
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)
