"""Public wrapper for the flash-attention kernel + autotuner hooks."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B,S,H,hd]; k,v: [B,S,KH,hd] (model layout). Returns [B,S,H,hd]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def block_candidates(seq_q: int, seq_k: int) -> list[tuple[int, int]]:
    """(block_q, block_k) candidates for the tile-size autotuner."""
    qs = [b for b in (64, 128, 256, 512) if b <= max(seq_q, 64)]
    ks = [b for b in (128, 256, 512, 1024) if b <= max(seq_k, 128)]
    return [(bq, bk) for bq in qs for bk in ks]
