"""Pure-jnp oracle for the SSD inter-chunk recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(S: jnp.ndarray, d: jnp.ndarray):
    """S: [B, nc, H, N, P]; d: [B, nc, H].
    Returns (h_before [B, nc, H, N, P], h_final [B, H, N, P])."""
    def step(h, inp):
        s_c, d_c = inp
        h_new = h * d_c[..., None, None] + s_c
        return h_new, h

    B, nc, H, N, P = S.shape
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    hT, h_before = jax.lax.scan(
        step, h0, (S.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
                   d.transpose(1, 0, 2).astype(jnp.float32)))
    return h_before.transpose(1, 0, 2, 3, 4), hT
