"""Mamba2 SSD inter-chunk state-recurrence Pallas TPU kernel.

Given per-chunk input-state contributions S [B, nc, H, N, P] and per-chunk
decays d [B, nc, H] (exp of summed log-decay within the chunk), computes

    h_0 = h_init;   h_{c+1} = d_c * h_c + S_c

emitting the state *before* each chunk (what Y_inter consumes) plus the
final state (the decode cache). The chunk axis is the minor grid dimension:
the running state lives in VMEM scratch across grid steps — this is the
sequential dependence that XLA cannot parallelize, so keeping it resident
in VMEM (instead of one HBM round-trip per chunk, as the lax.scan HLO does)
is the win.

Grid: (B, H, nc). BlockSpecs:
  S   [1, nc_blk=1, 1, N, P]  index (b, c, h, 0, 0)
  d   [1, 1, 1]               index (b, c, h)
  out [1, 1, 1, N, P]         index (b, c, h, 0, 0)
VMEM per step ≈ 2·N·P floats (N=128, P=64 → 64 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, d_ref, hout_ref, hfin_ref, h_scr, *, num_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    # emit the state BEFORE this chunk
    hout_ref[0, 0, 0] = h_scr[...].astype(hout_ref.dtype)
    d = d_ref[0, 0, 0].astype(jnp.float32)
    h_scr[...] = h_scr[...] * d + s_ref[0, 0, 0].astype(jnp.float32)

    @pl.when(c == num_chunks - 1)
    def _final():
        hfin_ref[0, 0] = h_scr[...].astype(hfin_ref.dtype)


def ssd_scan_bchnp(S: jnp.ndarray, d: jnp.ndarray, *,
                   interpret: bool = False):
    """S: [B, nc, H, N, P]; d: [B, nc, H].
    Returns (h_before [B, nc, H, N, P], h_final [B, H, N, P])."""
    B, nc, H, N, P = S.shape
    kernel = functools.partial(_kernel, num_chunks=nc)
    h_before, h_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(S, d)
    return h_before, h_final
