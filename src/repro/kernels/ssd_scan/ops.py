"""Public wrapper for the SSD inter-chunk scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bchnp


@partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(S: jnp.ndarray, d: jnp.ndarray, *, interpret: bool = False):
    return ssd_scan_bchnp(S, d, interpret=interpret)


def block_candidates(d_state: int, head_dim: int) -> list[tuple[int, int]]:
    """(N, P) VMEM tile candidates — here the state block is the whole
    (N, P) face; candidates vary the chunk length upstream instead."""
    return [(d_state, head_dim)]


def chunk_candidates(seq: int) -> list[int]:
    """SSD chunk-length candidates for the tile-size autotuner."""
    return [c for c in (64, 128, 256, 512) if c <= seq and seq % c == 0]
