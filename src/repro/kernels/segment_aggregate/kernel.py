"""Fused int8-weight GNN segment aggregation Pallas TPU kernel.

Computes, over one packed sparse batch (features.SparseGraphBatch layout):

    msg = act((x · node_mask) @ (w.f32 * w_scale))       # [M, F]
    out[d] = Σ_{e: scatter[e]=d} edge_mask[e] * msg[gather[e]]
    (mean: divide by Σ edge_mask per destination, floored at 1)

i.e. one GraphSAGE hop's transform+aggregate (`core/gnn.py
`_segment_aggregate``) in a single pass: the message tensor is computed
once into VMEM scratch — with the int8→f32 weight dequantization fused
into the matmul operand, so weights stream from HBM as int8 (¼ the
bytes) — and the packed edge list is walked in blocks of `block_e`
edges without the message tensor ever round-tripping to HBM.

Gather/scatter are phrased as one-hot matmuls (MXU-friendly — the same
trick the guide uses for TPU gathers): for an edge block,
``gsel[e, m] = (m == gather[e])`` picks message rows via ``gsel @ msg``
and ``sselᵀ @ rows`` scatter-adds them (ssel carries edge_mask), so the
whole aggregation runs on the MXU instead of serializing on dynamic
indexing.

Grid: (num_e_blocks,) — sequential on TPU, so `out` and the VMEM
scratch accumulators persist across steps. BlockSpecs:
  x       [M, D]        index (0, 0)    (full)
  w       [D, F]        index (0, 0)    (full; int8 or f32)
  w_scale [1, F]        index (0, 0)
  nmask   [M, 1]        index (0, 0)
  gather  [1, block_e]  index (0, e)
  scatter [1, block_e]  index (0, e)
  emask   [1, block_e]  index (0, e)
  out     [M, F]        index (0, 0)    (revisited every step)
Scratch: msg [M, F] f32 + deg [M, 1] f32 in VMEM. Per-step VMEM ≈
M·D + D·F + 2·M·F + 2·block_e·M floats — M=512, D=F=256, block_e=256
→ ~1.2 MB, far under VMEM. Bucketed capacities (data/batching.py) are
pow2, so M/D/F/E arrive tiling-friendly; `ops.segment_aggregate` pads
the stragglers. `block_e` candidates for the tile-size autotuner come
from `ops.block_candidates` (the `graph_aggregate.block_candidates`
idiom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, nm_ref, g_ref, sc_ref, em_ref, o_ref,
            msg_ref, deg_ref, *, act: str, mean: bool, nsteps: int):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        x = x_ref[...].astype(jnp.float32) * nm_ref[...]
        w = w_ref[...].astype(jnp.float32) * s_ref[...]
        m = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if act == "relu":
            m = jnp.maximum(m, 0.0)
        msg_ref[...] = m
        o_ref[...] = jnp.zeros_like(o_ref)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    gat = g_ref[0]                                    # [block_e] int32
    sct = sc_ref[0]
    em = em_ref[0].astype(jnp.float32)                # [block_e]
    M = msg_ref.shape[0]
    blk = gat.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (blk, M), 1)
    gsel = (cols == gat[:, None]).astype(jnp.float32)            # [blk, M]
    rows = jax.lax.dot_general(gsel, msg_ref[...],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    # padding edges carry edge_mask 0, so ssel zeroes their contribution
    ssel = (cols == sct[:, None]).astype(jnp.float32) * em[:, None]
    o_ref[...] += jax.lax.dot_general(ssel, rows, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    deg_ref[...] += jnp.sum(ssel, axis=0)[:, None]

    @pl.when(e == nsteps - 1)
    def _finish():
        if mean:
            o_ref[...] = o_ref[...] / jnp.maximum(deg_ref[...], 1.0)


def segment_aggregate_mf(x: jnp.ndarray, w: jnp.ndarray,
                         w_scale: jnp.ndarray, gather: jnp.ndarray,
                         scatter: jnp.ndarray, edge_mask: jnp.ndarray,
                         node_mask: jnp.ndarray, *, act: str = "relu",
                         mean: bool = True, block_e: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """x: [M, D]; w: [D, F] (int8 or f32); w_scale: [1, F]; gather/
    scatter/edge_mask: [1, E] with E a multiple of `block_e`; node_mask:
    [M, 1]. Returns [M, F] f32. Shapes must arrive tiling-aligned — use
    `ops.segment_aggregate`, which pads and strips."""
    M, D = x.shape
    F = w.shape[1]
    E = gather.shape[1]
    nsteps = E // block_e
    kernel = functools.partial(_kernel, act=act, mean=mean, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=(nsteps,),
        in_specs=[
            pl.BlockSpec((M, D), lambda e: (0, 0)),
            pl.BlockSpec((D, F), lambda e: (0, 0)),
            pl.BlockSpec((1, F), lambda e: (0, 0)),
            pl.BlockSpec((M, 1), lambda e: (0, 0)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
        ],
        out_specs=pl.BlockSpec((M, F), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((M, F), jnp.float32),
            pltpu.VMEM((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, w_scale, node_mask, gather, scatter, edge_mask)
