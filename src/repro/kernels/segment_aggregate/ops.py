"""Public wrapper for the fused sparse-aggregation kernel.

Pads every operand up to tiling-friendly shapes (M → ×8, D/F → lane
multiples sized for the weight dtype — int8 needs (32, 128) tiles, f32
(8, 128) — E → ×block_e), runs `kernel.segment_aggregate_mf`, and strips
the padding. Padding rows/edges carry zero masks, so they contribute
nothing; padded output channels are sliced off.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_aggregate.kernel import segment_aggregate_mf


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


@partial(jax.jit, static_argnames=("act", "mean", "block_e", "interpret"))
def segment_aggregate(x: jnp.ndarray, w: jnp.ndarray, w_scale: jnp.ndarray,
                      gather: jnp.ndarray, scatter: jnp.ndarray,
                      edge_mask: jnp.ndarray, node_mask: jnp.ndarray, *,
                      act: str = "relu", mean: bool = True,
                      block_e: int = 256,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused transform+segment-aggregate over a packed edge list.

    x: [M, D] f32; w: [D, F] int8 (with per-output-channel `w_scale`
    [1, F] or [F]) or f32 (pass ones); gather/scatter: [E] int32 flat
    node indices; edge_mask: [E]; node_mask: [M]. Returns [M, F] f32 =
    ``segment_aggregate(act((x·node_mask) @ (w·w_scale)), edges)``, the
    quantity `core.gnn._segment_aggregate` computes from a materialized
    message tensor — here the messages stay in VMEM (kernel.py).

    `block_e` is the edge-block width (the kernel's only tunable; see
    `block_candidates`, the `graph_aggregate.block_candidates` idiom).
    """
    M, D = x.shape
    F = w.shape[1]
    E = gather.shape[0]
    # int8 weights tile at (32, 128); f32 operands at (8, 128)
    d_mult = 32 if w.dtype == jnp.int8 else 8
    Mp, Dp, Fp = _pad_to(M, 8), _pad_to(D, d_mult), _pad_to(F, 128)
    block_e = max(min(block_e, _pad_to(E, 8)), 8)
    Ep = _pad_to(E, block_e)

    x = jnp.pad(x.astype(jnp.float32), ((0, Mp - M), (0, Dp - D)))
    w = jnp.pad(w, ((0, Dp - D), (0, Fp - F)))
    w_scale = jnp.pad(w_scale.reshape(1, -1).astype(jnp.float32),
                      ((0, 0), (0, Fp - F)), constant_values=1.0)
    nm = jnp.pad(node_mask.astype(jnp.float32), (0, Mp - M))[:, None]
    gat = jnp.pad(gather.astype(jnp.int32), (0, Ep - E))[None, :]
    sct = jnp.pad(scatter.astype(jnp.int32), (0, Ep - E))[None, :]
    em = jnp.pad(edge_mask.astype(jnp.float32), (0, Ep - E))[None, :]

    out = segment_aggregate_mf(x, w, w_scale, gat, sct, em, nm, act=act,
                               mean=mean, block_e=block_e,
                               interpret=interpret)
    return out[:M, :F]


def block_candidates(edge_capacity: int) -> list[int]:
    """block_e candidates for the tile-size autotuner (mirrors
    `kernels.graph_aggregate.block_candidates` for block_f)."""
    return [b for b in (64, 128, 256, 512, 1024)
            if b <= max(edge_capacity, 64)]
