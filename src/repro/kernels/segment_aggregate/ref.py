"""Edge-loop oracle for segment_aggregate — the fused kernel's semantics
spelled out as a sequential numpy loop over the packed edge list, so the
Pallas one-hot-matmul formulation is checked against an independent
derivation (mirrors graph_aggregate/ref.py)."""
from __future__ import annotations

import numpy as np


def segment_aggregate_ref(x: np.ndarray, w: np.ndarray, w_scale: np.ndarray,
                          gather: np.ndarray, scatter: np.ndarray,
                          edge_mask: np.ndarray, node_mask: np.ndarray, *,
                          act: str = "relu", mean: bool = True) -> np.ndarray:
    """x: [M, D] f32 node buffer; w: [D, F] int8 (or f32) with per-output-
    channel `w_scale` [1, F] (pass ones for f32 weights); gather/scatter:
    [E] flat node indices (message read at `gather`, summed into
    `scatter`); edge_mask: [E]; node_mask: [M]. Returns [M, F] f32 —
    ``segment_aggregate(act((x·node_mask) @ (w·w_scale)), edges)`` with
    optional mean over in-degree, i.e. one GraphSAGE hop's
    transform+aggregate (core/gnn.py `_segment_aggregate`)."""
    xm = np.asarray(x, np.float32) * np.asarray(node_mask, np.float32)[:, None]
    wf = np.asarray(w, np.float32) * np.asarray(w_scale, np.float32).reshape(
        1, -1)
    msg = xm @ wf
    if act == "relu":
        msg = np.maximum(msg, 0.0)
    M, F = msg.shape
    out = np.zeros((M, F), np.float32)
    deg = np.zeros((M,), np.float32)
    for g, s, m in zip(np.asarray(gather), np.asarray(scatter),
                       np.asarray(edge_mask, np.float32)):
        out[s] += m * msg[g]
        deg[s] += m
    if mean:
        out = out / np.maximum(deg, 1.0)[:, None]
    return out
