"""Shared int8 scale math (DESIGN.md §14).

One home for the symmetric-int8 quantization primitives used by both

* `training.compression` — per-leaf gradient compression for the int8
  all-reduce (scalar scale, error feedback), and
* `repro.quant.quantize` — per-channel weight quantization of a trained
  cost model for int8 serving,

so there is exactly one copy of ``round(x / scale).clip(-127, 127)`` in
the tree. The symmetric scheme maps ``x ≈ q * scale`` with ``q ∈ int8``
and no zero point: scales are always positive, zero is exactly
representable, and dequantize∘quantize of an already-quantized array is
the identity (`tests/test_quantization.py` pins the round trip).

`QuantizedLeaf` is the pytree carrying one quantized array: ``q`` (int8)
plus its broadcast-ready ``scale``. It flattens to its two arrays, so
quantized parameter trees pass through `jax.jit`, `lax.scan` (the
scan-over-layers GNN slices the leading layer axis of both fields), and
the checkpoint sidecar writer unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
_TINY = 1e-12       # scale floor: an all-zero channel quantizes to zeros


def amax_scale(amax: jnp.ndarray) -> jnp.ndarray:
    """Symmetric int8 scale for a (per-tensor or per-channel) abs-max.

    >>> float(amax_scale(jnp.asarray(127.0)))
    1.0
    >>> float(amax_scale(jnp.asarray(0.0))) > 0      # floored, never 0
    True
    """
    return jnp.maximum(jnp.asarray(amax, jnp.float32) / INT8_MAX, _TINY)


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """``clip(round(x / scale), -127, 127)`` as int8 (`scale` broadcasts;
    ``round`` is `jnp.round`, i.e. round-half-to-even).

    >>> q = quantize_int8(jnp.asarray([1.0, -0.6, 300.0]), jnp.asarray(1.0))
    >>> q.tolist()
    [1, -1, 127]
    >>> q.dtype.name
    'int8'
    """
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    """``q * scale`` in `dtype`; exact inverse on quantized values.

    >>> x = jnp.asarray([0.5, -1.25, 2.0])
    >>> s = amax_scale(jnp.max(jnp.abs(x)))
    >>> q = quantize_int8(x, s)
    >>> bool(jnp.array_equal(q, quantize_int8(dequantize_int8(q, s), s)))
    True
    """
    return q.astype(dtype) * scale


def per_channel_scale(w: jnp.ndarray, *, channel_axis: int = -1
                      ) -> jnp.ndarray:
    """Per-output-channel scales for a weight tensor: abs-max over every
    axis except `channel_axis`, shaped for broadcasting against `w`
    (kept dims). For a dense ``w [in, out]`` this is one scale per output
    column — the layout `kernels/segment_aggregate` dequantizes in-VMEM.

    >>> w = jnp.asarray([[1.0, -8.0], [2.0, 4.0]])
    >>> s = per_channel_scale(w)                  # [1, 2]: col abs-maxes/127
    >>> [round(float(v) * 127, 4) for v in s[0]]
    [2.0, 8.0]
    """
    axes = tuple(i for i in range(w.ndim)
                 if i != (channel_axis % w.ndim))
    return amax_scale(jnp.max(jnp.abs(w), axis=axes, keepdims=True))


@jax.tree_util.register_pytree_node_class
@dataclass
class QuantizedLeaf:
    """One int8-quantized array: ``dequantize() == q * scale``."""
    q: jnp.ndarray           # int8, the original array's shape
    scale: jnp.ndarray       # f32, broadcastable against ``q``

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return dequantize_int8(self.q, self.scale, dtype)

    @classmethod
    def quantize(cls, w: jnp.ndarray, *, channel_axis: int = -1
                 ) -> "QuantizedLeaf":
        scale = per_channel_scale(w, channel_axis=channel_axis)
        return cls(quantize_int8(w, scale), scale)


def _is_qleaf(x) -> bool:
    return isinstance(x, QuantizedLeaf)


def tree_is_quantized(tree) -> bool:
    """True iff any leaf of `tree` is a `QuantizedLeaf`."""
    return any(_is_qleaf(l) for l in
               jax.tree_util.tree_leaves(tree, is_leaf=_is_qleaf))


def dequantize_tree(tree, dtype=jnp.float32):
    """Materialize the f32 view of a (possibly) quantized parameter tree:
    `QuantizedLeaf`s become ``q * scale``, everything else passes through.
    Inside jit this is the int8 serving path's whole decode cost — one
    fused multiply per quantized leaf, while the weights live in memory
    (and stream from it) as int8."""
    return jax.tree_util.tree_map(
        lambda x: x.dequantize(dtype) if _is_qleaf(x) else x,
        tree, is_leaf=_is_qleaf)


def leaf_f32(x, dtype=jnp.float32):
    """`QuantizedLeaf` → dequantized array; plain arrays pass through."""
    return x.dequantize(dtype) if _is_qleaf(x) else x
