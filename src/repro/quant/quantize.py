"""Per-channel int8 weight quantization of a trained cost model
(DESIGN.md §14).

`quantize_params` walks a trained f32 parameter tree and replaces every
weight matrix (float leaf with ≥2 dims and ≥ `min_size` elements — dense
``w``s, the opcode embedding table, stacked ``[L, ...]`` GNN leaves) with
a `quant.scale.QuantizedLeaf`: symmetric int8 values plus per-output-
channel scales (per *layer and* channel for stacked leaves, so the
scan-over-layers path slices both fields along L). Small leaves — biases,
GAT attention vectors — stay f32; they are noise in the byte count and
disproportionately expensive in error.

Serving the result is `CostModelConfig(precision="int8")` +
`cost_model_apply` (core/model.py): weights live and move as int8 (~¼
the f32 bytes) and decode inside jit — either a fused multiply per leaf,
or in-VMEM inside `kernels/segment_aggregate` on the sparse Pallas path.
`CostModelService` / `LearnedEstimator.from_params` accept a
`QuantizedCostModel` directly and pick the quantized backend themselves.

Activation calibration (`calibrate_activations`) runs a corpus sample
through the f32 sparse forward and records per-stage abs-maxes. The
GraphSAGE stages are l2-normalized, so only the f1 output genuinely
needs data — but the measured scales ship in the `QuantizedCostModel`
(and its sidecar) for any backend that wants full int8×int8 compute.

The sidecar (`save_quantized`/`load_quantized`) is one checksummed npz
next to the training checkpoint — quantize once, serve anywhere — and
round-trips the tree bit-exactly (tests/test_quantization.py).

>>> import jax
>>> from repro.core.model import CostModelConfig, cost_model_init
>>> cfg = CostModelConfig(hidden_dim=16, opcode_embed_dim=4,
...                       reduction="per_node", adjacency="sparse")
>>> params = cost_model_init(jax.random.key(0), cfg)
>>> qm = quantize_params(params, cfg)
>>> qm.serving_config().precision
'int8'
>>> qm.num_quantized > 0 and qm.quantized_bytes() < tree_bytes(params)
True
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.scale import (
    QuantizedLeaf,
    amax_scale,
    dequantize_tree,
    quantize_int8,
)

SIDECAR_VERSION = 1
DEFAULT_MIN_SIZE = 256


# ----------------------------------------------------------------------------
# Tree walking (the training/checkpoint.py key-path convention)
# ----------------------------------------------------------------------------
def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _is_qleaf(x) -> bool:
    return isinstance(x, QuantizedLeaf)


def quantize_params(params, model_cfg=None, *, calib_graphs=None,
                    normalizer=None,
                    min_size: int = DEFAULT_MIN_SIZE) -> "QuantizedCostModel":
    """Quantize a trained f32 tree; returns a `QuantizedCostModel`.

    `model_cfg` (a `CostModelConfig`) is embedded — with
    ``precision="int8"`` — as the model's serving config. `calib_graphs`
    (+ `normalizer`) run activation calibration on a corpus sample.
    """
    def one(path, x):
        key = _key_str(path)
        if (hasattr(x, "ndim") and x.ndim >= 2 and x.size >= min_size
                and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)):
            x = jnp.asarray(x)
            # stacked GNN leaves [L, ...]: scales per layer AND channel so
            # lax.scan can slice the leading axis of q and scale alike
            keep = {x.ndim - 1}
            if "/stacked/" in f"/{key}/":
                keep.add(0)
            axes = tuple(i for i in range(x.ndim) if i not in keep)
            scale = amax_scale(jnp.max(jnp.abs(x), axis=axes, keepdims=True))
            return QuantizedLeaf(quantize_int8(x, scale), scale)
        return x

    qtree = jax.tree_util.tree_map_with_path(one, params)
    config = None
    if model_cfg is not None:
        config = dict(model_cfg.to_dict(), precision="int8")
    act_scales = {}
    if calib_graphs is not None:
        if model_cfg is None:
            raise ValueError("calibration needs model_cfg")
        act_scales = calibrate_activations(params, model_cfg, calib_graphs,
                                           normalizer)
    return QuantizedCostModel(qtree, act_scales=act_scales, config=config)


def dequantize_params(qm: "QuantizedCostModel"):
    """The f32 view of a quantized model's tree (exact: ``q * scale``)."""
    return dequantize_tree(qm.params)


def calibrate_activations(params, model_cfg, graphs, normalizer=None, *,
                          node_budget: int | None = None) -> dict:
    """Per-stage activation abs-maxes from a corpus sample, via the f32
    sparse forward: ``"f1"`` (the embedding+f1 output entering the GNN)
    and ``"gnn_<i>"`` per GraphSAGE hop (l2-normalized, so ≤ 1 by
    construction — recorded anyway as the ground truth). Returns
    {name: float amax}."""
    from repro.core import gnn as G
    from repro.core.model import _mask_kernel_feats
    from repro.data.batching import iter_packed_batches
    from repro.nn.core import dense_apply, embedding_apply

    budget = node_budget or 8 * model_cfg.max_nodes
    amaxes: dict[str, float] = {}

    def note(name, x):
        v = float(jnp.max(jnp.abs(x)))
        amaxes[name] = max(amaxes.get(name, 0.0), v)

    gnn_params = params.get("gnn")
    layers = (G.unstack_params(gnn_params)["layers"]
              if gnn_params is not None else [])
    for enc, _ in iter_packed_batches(list(graphs), budget, normalizer):
        mask = enc.node_mask
        kfeats = _mask_kernel_feats(model_cfg, enc.kernel_feats)
        emb = embedding_apply(params["opcode_embed"], enc.opcodes)
        x = jnp.concatenate([emb, enc.node_feats], axis=-1)
        if model_cfg.kernel_feat_mode == "node":
            x = jnp.concatenate(
                [x, jnp.take(kfeats, enc.graph_ids, axis=0)], axis=-1)
        eps = jax.nn.relu(dense_apply(params["f1"], x)) * mask[:, None]
        note("f1", eps)
        if model_cfg.gnn == "graphsage":
            for i, layer in enumerate(layers):
                eps = G.sage_layer_apply_sparse(
                    layer, eps, enc.edge_src, enc.edge_dst, enc.edge_mask,
                    mask, aggregator=model_cfg.aggregator,
                    directed=model_cfg.directed)
                note(f"gnn_{i}", eps)
    return amaxes


# ----------------------------------------------------------------------------
# The quantized model pytree
# ----------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class QuantizedCostModel:
    """A quantized parameter tree + its calibration + serving config.

    `params` holds `QuantizedLeaf`s at the quantized positions and plain
    f32 arrays elsewhere; it is what `cost_model_apply` consumes under
    ``precision="int8"``. `act_scales` are `calibrate_activations`
    abs-maxes; `config` is the serving `CostModelConfig` as a dict
    (``precision`` already ``"int8"``).
    """
    params: dict
    act_scales: dict = field(default_factory=dict)
    config: dict | None = None

    def tree_flatten(self):
        names = tuple(sorted(self.act_scales))
        vals = tuple(self.act_scales[n] for n in names)
        aux = (names, json.dumps(self.config, sort_keys=True))
        return (self.params, vals), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, config = aux
        params, vals = children
        return cls(params, act_scales=dict(zip(names, vals)),
                   config=json.loads(config))

    def serving_config(self, base=None):
        """The `CostModelConfig` to serve this model under (embedded
        config if present, else `base` with ``precision="int8"``)."""
        from repro.core.model import CostModelConfig
        if self.config is not None:
            return CostModelConfig.from_dict(self.config)
        if base is None:
            raise ValueError("no embedded config; pass the f32 model's "
                             "CostModelConfig as base")
        return CostModelConfig.from_dict(
            dict(base.to_dict(), precision="int8"))

    @property
    def num_quantized(self) -> int:
        return sum(_is_qleaf(l) for l in jax.tree_util.tree_leaves(
            self.params, is_leaf=_is_qleaf))

    def quantized_bytes(self) -> int:
        """Parameter bytes of the quantized tree (int8 payloads + their
        scales + the remaining f32 leaves) — the serving memory/bandwidth
        footprint the weight-bytes benchmark gate measures."""
        total = 0
        for leaf in jax.tree_util.tree_leaves(self.params, is_leaf=_is_qleaf):
            if _is_qleaf(leaf):
                total += leaf.q.size * 1 + leaf.scale.size * 4
            else:
                total += np.asarray(leaf).nbytes
        return total


def tree_bytes(params) -> int:
    """Total bytes of a plain parameter tree (the f32 baseline)."""
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(params)))


# ----------------------------------------------------------------------------
# Checkpoint sidecar (quantize once, serve anywhere)
# ----------------------------------------------------------------------------
def save_quantized(path: str, qm: QuantizedCostModel) -> str:
    """Write `qm` to one npz at `path` (atomic tmp+rename, checksummed
    header — the corpus-store / cache-snapshot idiom). Returns `path`."""
    flat = jax.tree_util.tree_flatten_with_path(
        qm.params, is_leaf=_is_qleaf)[0]
    arrays: dict[str, np.ndarray] = {}
    entries = []
    for i, (p, leaf) in enumerate(flat):
        key = _key_str(p)
        if _is_qleaf(leaf):
            arrays[f"a{i}.q"] = np.asarray(leaf.q)
            arrays[f"a{i}.scale"] = np.asarray(leaf.scale, np.float32)
            entries.append({"key": key, "kind": "int8", "id": f"a{i}"})
        else:
            arrays[f"a{i}.w"] = np.asarray(leaf)
            entries.append({"key": key, "kind": "raw", "id": f"a{i}"})
    digest = hashlib.sha256()
    for name in sorted(arrays):
        digest.update(name.encode())
        digest.update(arrays[name].tobytes())
    header = {"format_version": SIDECAR_VERSION,
              "kind": "quantized_cost_model", "config": qm.config,
              "act_scales": {k: float(v) for k, v in qm.act_scales.items()},
              "leaves": entries, "arrays_sha256": digest.hexdigest()}
    blob = json.dumps(header, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    tmp = path + f".tmp-{os.getpid()}"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(blob, np.uint8), **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _insert(root: dict, parts: list[str], value) -> None:
    node = root
    for a in parts[:-1]:
        node = node.setdefault(a, {})
    node[parts[-1]] = value


def _listify(node):
    """Convert {digit-string: v} dicts back into lists (the ``layers``
    convention of the checkpoint key paths)."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    if out and all(k.isdigit() for k in out):
        return [out[k] for k in sorted(out, key=int)]
    return out


def load_quantized(path: str) -> QuantizedCostModel:
    """Load a `save_quantized` sidecar; bit-exact round trip (the values
    a restored service computes are identical to the exporter's)."""
    with np.load(path) as z:
        header = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        if header.get("format_version") != SIDECAR_VERSION:
            raise ValueError(
                f"{path}: sidecar format_version "
                f"{header.get('format_version')!r} != {SIDECAR_VERSION}")
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    digest = hashlib.sha256()
    for name in sorted(arrays):
        digest.update(name.encode())
        digest.update(arrays[name].tobytes())
    if digest.hexdigest() != header["arrays_sha256"]:
        raise ValueError(f"{path}: arrays checksum mismatch")
    root: dict = {}
    for e in header["leaves"]:
        parts = e["key"].split("/")
        if e["kind"] == "int8":
            leaf = QuantizedLeaf(jnp.asarray(arrays[e["id"] + ".q"]),
                                 jnp.asarray(arrays[e["id"] + ".scale"]))
        else:
            leaf = jnp.asarray(arrays[e["id"] + ".w"])
        _insert(root, parts, leaf)
    return QuantizedCostModel(_listify(root),
                              act_scales=dict(header["act_scales"]),
                              config=header["config"])
