"""Int8 quantized inference for the cost model (DESIGN.md §14).

* `repro.quant.scale` — the shared symmetric-int8 primitives
  (scale/clip/round + `QuantizedLeaf`), also used by
  `training.compression` for the int8 gradient all-reduce;
* `repro.quant.quantize` — per-channel weight quantization of a trained
  model (`quantize_params` → `QuantizedCostModel`), activation
  calibration, and the checkpoint sidecar (`save_quantized` /
  `load_quantized`).

Exports resolve lazily (PEP 562): `repro.quant.scale` names import
without pulling the model stack in.
"""
import importlib

_EXPORTS = {
    # scale math (jax-only)
    "INT8_MAX": "repro.quant.scale",
    "QuantizedLeaf": "repro.quant.scale",
    "amax_scale": "repro.quant.scale",
    "dequantize_int8": "repro.quant.scale",
    "dequantize_tree": "repro.quant.scale",
    "leaf_f32": "repro.quant.scale",
    "per_channel_scale": "repro.quant.scale",
    "quantize_int8": "repro.quant.scale",
    "tree_is_quantized": "repro.quant.scale",
    # model quantization (imports the core model stack)
    "QuantizedCostModel": "repro.quant.quantize",
    "calibrate_activations": "repro.quant.quantize",
    "dequantize_params": "repro.quant.quantize",
    "load_quantized": "repro.quant.quantize",
    "quantize_params": "repro.quant.quantize",
    "save_quantized": "repro.quant.quantize",
    "tree_bytes": "repro.quant.quantize",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value
        return value
    try:
        return importlib.import_module(f"{__name__}.{name}")
    except ModuleNotFoundError as e:
        if e.name != f"{__name__}.{name}":
            raise
        raise AttributeError(
            f"module 'repro.quant' has no attribute {name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(__all__))
