from repro.roofline.analysis import (
    ROOFLINE_HW,
    active_param_count,
    build_table,
    model_flops,
    render_markdown,
    roofline_terms,
)

__all__ = ["ROOFLINE_HW", "active_param_count", "build_table", "model_flops",
           "render_markdown", "roofline_terms"]
