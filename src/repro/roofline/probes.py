"""Scan-corrected cost measurement via probe lowering.

Problem: `compiled.cost_analysis()` counts while-loop bodies ONCE (verified
empirically — see EXPERIMENTS.md §Roofline), so a 61-layer scan × 16-micro-
batch scan under-reports FLOPs/bytes/collectives by ~3 orders of magnitude.

Fix: lower small UNROLLED probe variants of each cell on the same mesh and
solve for the per-layer and per-microbatch costs algebraically:

  train:    F(m, L_1..L_S) = O + m·(H + Σ_s L_s·C_s)
    P1  = F(1, all L_s=1)          = O + H + ΣC_s
    P3  = F(2, all L_s=1)          = O + 2(H + ΣC_s)      → O = 2·P1 − P3
    P2_s = F(1, L_s=2, others 1)   = P1 + C_s             → C_s
    corrected = O + m·(P1 − O + Σ_s (L_s−1)·C_s)

  prefill/decode: F(L) = O' + Σ L_s·C_s,  O' absorbed into P1:
    corrected = P1 + Σ_s (L_s−1)·C_s

Each probe is a real lower+compile on the production mesh, so the costs
include GSPMD collectives — the correction applies to flops, bytes AND
collective bytes uniformly. Probes use the single-pod mesh (the roofline
table is single-pod per the assignment).
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.models.config import ModelConfig, ShapeSpec, Stack

METRICS = ("flops", "bytes", "transcendentals", "all-gather", "all-reduce",
           "reduce-scatter", "all-to-all", "collective-permute")


def _cell_metrics(cell) -> dict:
    cost = cell.cost_analysis
    coll = cell.collective_bytes
    m = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        m[k] = float(coll.get(k, 0.0))
    return m


def _probe_cfg(cfg: ModelConfig, stack_repeats: list[int]) -> ModelConfig:
    stacks = tuple(Stack(s.pattern, r)
                   for s, r in zip(cfg.stacks, stack_repeats))
    return dataclasses.replace(cfg, stacks=stacks, scan_layers=False,
                               scan_microbatch=False)


def _probe_shape(shape: ShapeSpec, cfg: ModelConfig, m: int) -> ShapeSpec:
    if shape.kind != "train":
        return shape
    return ShapeSpec(shape.name, shape.seq_len, cfg.microbatch * m,
                     shape.kind)


def measure_corrected(arch: str, cfg: ModelConfig, shape: ShapeSpec, mesh,
                      mesh_name: str, *, log=print) -> dict:
    """Returns {'corrected': {metric: per-device value}, 'probes': {...},
    'raw_full': {...}, plus the full cell's memory analysis & params}."""
    from repro.launch.lowering import lower_cell

    S = len(cfg.stacks)
    ones = [1] * S

    probes = {}
    # P1: one layer per stack, one microbatch
    log(f"  probe P1 {arch}/{shape.name}")
    p1_cell = lower_cell(arch, _probe_cfg(cfg, ones),
                         _probe_shape(shape, cfg, 1), mesh, mesh_name)
    probes["P1"] = _cell_metrics(p1_cell)

    # P2_s: stack s doubled
    c_s = []
    for s in range(S):
        reps = list(ones)
        reps[s] = 2
        log(f"  probe P2_{s} {arch}/{shape.name}")
        cell = lower_cell(arch, _probe_cfg(cfg, reps),
                          _probe_shape(shape, cfg, 1), mesh, mesh_name)
        probes[f"P2_{s}"] = _cell_metrics(cell)
        c_s.append({k: probes[f"P2_{s}"][k] - probes["P1"][k]
                    for k in METRICS})

    if shape.kind == "train":
        log(f"  probe P3 {arch}/{shape.name}")
        p3_cell = lower_cell(arch, _probe_cfg(cfg, ones),
                             _probe_shape(shape, cfg, 2), mesh, mesh_name)
        probes["P3"] = _cell_metrics(p3_cell)
        m_total = max(shape.global_batch // cfg.microbatch, 1)
        corrected = {}
        for k in METRICS:
            O = max(2 * probes["P1"][k] - probes["P3"][k], 0.0)
            per_micro = probes["P1"][k] - O
            extra_layers = sum((st.repeats - 1) * c[k]
                               for st, c in zip(cfg.stacks, c_s))
            corrected[k] = O + m_total * (per_micro + extra_layers)
    else:
        corrected = {}
        for k in METRICS:
            extra_layers = sum((st.repeats - 1) * c[k]
                               for st, c in zip(cfg.stacks, c_s))
            corrected[k] = probes["P1"][k] + extra_layers

    corrected["collective_total"] = sum(
        corrected[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                               "all-to-all", "collective-permute"))
    return {"corrected": corrected, "probes": probes,
            "per_stack_layer": c_s}


def run_probes(arch: str, shape_name: str, out_dir: str, mesh,
               mesh_name: str) -> dict:
    from repro.models import SHAPES, registry
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    rec = measure_corrected(arch, cfg, shape, mesh, mesh_name)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{mesh_name}__{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec
