"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell, from the SPMD-partitioned per-device
compiled module:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
                  ( = total FLOPs / (chips × peak) — cost_analysis() is
                    per-device under SPMD, verified empirically )
  memory term     = HLO_bytes_per_device / HBM_bw
                  ('bytes accessed' counts operand+output bytes per op —
                   an upper bound on HBM traffic since VMEM reuse is not
                   visible at HLO level; stated with the table)
  collective term = collective_bytes_per_device / link_bw
                  (sum of collective op output bytes in per-device HLO;
                   ring-style (n-1)/n wire factors are ignored — ≤7% at 16)

plus MODEL_FLOPS = 6·N·tokens (train) / 2·N·tokens (inference), N = active
params for MoE, and the ratio MODEL_FLOPS / HLO_FLOPs (total) — the
"useful-compute" fraction that catches remat/redundancy waste.

Hardware constants (TPU v5e-class, per chip): 197 TF/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

ROOFLINE_HW = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link
    "hbm_bytes": 16 * 1024**3, # v5e HBM capacity per chip
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def analytic_memory_bytes(cfg, shape, total_params: int, *, dp: int = 16,
                          tp: int = 16) -> float:
    """Fused-execution HBM-traffic estimate per device (bytes per step).

    HLO 'bytes accessed' counts every op's operands — an UNFUSED upper
    bound (flash-attention scores, MoE dispatch buffers etc. stay in VMEM
    on TPU). This estimate models what actually crosses HBM on a fused TPU
    execution: weight streaming per microbatch (×3 with remat: fwd, re-fwd,
    bwd), optimizer state traffic, gradient-accumulator read-modify-write,
    layer-boundary activations, logits, and KV-cache traffic for serving.
    """
    devices = dp * tp
    params_dev = total_params / devices
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    if shape.kind == "train":
        mb = min(cfg.microbatch, shape.global_batch)
        n_micro = max(shape.global_batch // mb, 1)
        b_dev = max(mb / dp, 1)
        S = shape.seq_len
        weight_passes = 3 if cfg.remat != "none" else 2
        weights = params_dev * 2 * n_micro * weight_passes
        opt = params_dev * (6 if cfg.optimizer == "adafactor" else 20)
        grad_accum = params_dev * 4 * 2 * n_micro
        k_act = 6 if cfg.remat != "none" else 4
        acts = n_micro * L * b_dev * S * D * 2 * k_act
        logits = n_micro * b_dev * S * (V / tp) * 2 * 3
        return weights + opt + grad_accum + acts + logits
    if shape.kind == "prefill":
        b_dev = max(shape.global_batch / dp, 1)
        S = shape.seq_len
        weights = params_dev * 2
        acts = L * b_dev * S * D * 2 * 3
        cache = L * b_dev * S * D * 2       # rough cache-write proxy
        return weights + acts + cache
    # decode: weights + full cache read per token
    b_dev = max(shape.global_batch / dp, 1)
    cache_read = 0.0
    for t in cfg.layer_types():
        mixer = t.split("+")[0]
        if mixer == "attn":
            cache_read += (b_dev * shape.seq_len *
                           cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2
                           / tp)
        elif mixer == "swa":
            w = min(cfg.sliding_window, shape.seq_len)
            cache_read += (b_dev * w * cfg.num_kv_heads *
                           cfg.resolved_head_dim * 2 * 2 / tp)
        elif mixer == "mla":
            cache_read += (b_dev * shape.seq_len *
                           (cfg.mla.kv_lora_rank +
                            cfg.mla.qk_rope_head_dim) * 2 / tp)
        elif mixer == "ssd":
            d_inner = cfg.ssm.expand * cfg.d_model
            H = d_inner // cfg.ssm.head_dim
            cache_read += b_dev * H / tp * cfg.ssm.d_state * \
                cfg.ssm.head_dim * 4 * 2
        elif mixer == "rglru":
            W = cfg.rglru.lru_width or cfg.d_model
            cache_read += b_dev * W / tp * 4 * 2
    if cfg.moe is not None:
        # decode streams only routed experts' weights
        mc = cfg.moe
        moe_layers = sum(1 for t in cfg.layer_types() if t.endswith("+moe"))
        all_exp = moe_layers * mc.num_experts * 3 * D * mc.d_ff_expert
        act_exp = moe_layers * min(
            mc.top_k * shape.global_batch, mc.num_experts) * 3 * D * \
            mc.d_ff_expert
        params_active_dev = (total_params - all_exp + act_exp) / devices
        weights = params_active_dev * 2
    else:
        weights = params_dev * 2
    return weights + cache_read


# ----------------------------------------------------------------------------
# MODEL_FLOPS
# ----------------------------------------------------------------------------
def _expert_params(cfg) -> tuple[int, int]:
    """(total expert params, active expert params) across all layers."""
    if cfg.moe is None:
        return 0, 0
    mc = cfg.moe
    moe_layers = sum(1 for t in cfg.layer_types() if t.endswith("+moe"))
    per_expert = 3 * cfg.d_model * mc.d_ff_expert
    total = moe_layers * mc.num_experts * per_expert
    active = moe_layers * mc.top_k * per_expert
    return total, active


def active_param_count(cfg, total_params: int) -> int:
    total_exp, active_exp = _expert_params(cfg)
    return int(total_params - total_exp + active_exp)


def model_flops(cfg, shape, total_params: int) -> float:
    """6·N·D for training, 2·N·D for inference forward (N = active params,
    D = tokens processed)."""
    n_active = active_param_count(cfg, total_params)
    # embedding gather does no matmul flops; subtract the embed table
    n_active -= cfg.vocab_size * cfg.d_model
    if shape.kind == "decode":
        tokens = shape.global_batch * 1
        mult = 2.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    # unembedding matmul is real compute: add 2·d·V per token (×3 for bwd)
    lm_head = 2.0 * cfg.d_model * cfg.vocab_size * tokens
    if shape.kind == "train":
        lm_head *= 3.0
    return mult * n_active * tokens + lm_head


# ----------------------------------------------------------------------------
# Per-cell terms
# ----------------------------------------------------------------------------
@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops_total: float
    model_flops: float
    useful_ratio: float
    peak_mem_gb: float
    fits_hbm: bool
    note: str = ""
    memory_upper_s: float = 0.0    # unfused HLO-bytes upper bound

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms) — 1.0 means perfectly
        compute-bound (the best an optimizer can do is reach the compute
        roofline)."""
        return self.compute_s / max(self.bound_time, 1e-30)


def roofline_terms(rec: dict, cfg, shape, hw=ROOFLINE_HW) -> RooflineRow:
    devices = rec.get("devices", 1)
    cost = rec.get("cost", {})
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = rec.get("collectives", {})
    coll_dev = float(sum(v for k, v in coll.items() if k in _COLL_OPS))

    compute_s = flops_dev / hw["peak_flops"]
    memory_s = bytes_dev / hw["hbm_bw"]
    collective_s = coll_dev / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    total_params = rec.get("params_bytes", 0) // 2   # bf16
    mf = model_flops(cfg, shape, total_params)
    hlo_total = flops_dev * devices
    ratio = mf / hlo_total if hlo_total > 0 else float("nan")

    peak = rec.get("memory", {}).get("peak_memory_in_bytes", 0)
    if not peak:
        m = rec.get("memory", {})
        peak = (m.get("argument_size_in_bytes", 0) +
                m.get("temp_size_in_bytes", 0) +
                m.get("output_size_in_bytes", 0) -
                m.get("alias_size_in_bytes", 0))
    note = _suggestion(dominant, ratio, shape)
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        devices=devices, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        hlo_flops_total=hlo_total, model_flops=mf, useful_ratio=ratio,
        peak_mem_gb=peak / 1024**3, fits_hbm=peak <= hw["hbm_bytes"],
        note=note)


def _suggestion(dominant: str, ratio: float, shape) -> str:
    if dominant == "compute":
        if ratio < 0.5:
            return ("compute-bound but <50% useful FLOPs — reduce remat "
                    "recompute / dead padding work")
        return "compute-bound — already at the right wall; fuse or lower precision"
    if dominant == "memory":
        if shape.kind == "decode":
            return ("memory-bound (weight/cache streaming) — batch more "
                    "decode requests per step or quantize weights/cache")
        return ("memory-bound — increase arithmetic intensity: larger "
                "microbatch, fused matmuls, fewer materialized intermediates")
    return ("collective-bound — reshard to cut gathered bytes (FSDP→TP "
            "ratio), overlap collectives with compute, or compress")


# ----------------------------------------------------------------------------
# Table over all dry-run records
# ----------------------------------------------------------------------------
def build_table(dryrun_dir: str) -> list[RooflineRow]:
    from repro.models import SHAPES, registry
    rows = []
    for fname in sorted(os.listdir(dryrun_dir)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fname)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        cfg = registry.get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        rows.append(roofline_terms(rec, cfg, shape))
    return rows


def render_markdown(rows: list[RooflineRow]) -> str:
    out = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| bound | useful FLOPs | peak mem/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for r in sorted(rows, key=lambda r: (r.mesh, r.arch, r.shape)):
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{100*r.useful_ratio:.0f}% | {r.peak_mem_gb:.2f} GiB | "
            f"{'yes' if r.fits_hbm else 'NO'} |")
    return "\n".join(out)
