# The paper's primary contribution: a learned performance model for tensor
# programs (kernel graphs), plus the analytical baseline and the measurement
# oracle (TPU timing simulator). See DESIGN.md for the layer map.
from repro.core.graph import KernelGraph, Node, Program
from repro.core.model import CostModelConfig, cost_model_apply, cost_model_init
from repro.core.simulator import TPUSimulator, V5E, HardwareSpec
from repro.core.analytical import AnalyticalModel

__all__ = [
    "KernelGraph", "Node", "Program",
    "CostModelConfig", "cost_model_apply", "cost_model_init",
    "TPUSimulator", "V5E", "HardwareSpec",
    "AnalyticalModel",
]
