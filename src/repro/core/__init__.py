# The paper's primary contribution: a learned performance model for tensor
# programs (kernel graphs), plus the analytical baseline and the measurement
# oracle (TPU timing simulator). See DESIGN.md for the layer map.
#
# Exports resolve lazily (PEP 562): the graph IR / simulator / analytical
# layer is pure numpy, and corpus-builder workers (repro.launch.build_corpus)
# import it without paying for — or fork-racing with — the jax-backed model
# stack, which loads on first touch of a model symbol.
import importlib

_EXPORTS = {
    "KernelGraph": "repro.core.graph",
    "Node": "repro.core.graph",
    "Program": "repro.core.graph",
    "CostModelConfig": "repro.core.model",          # imports jax
    "cost_model_apply": "repro.core.model",         # imports jax
    "cost_model_init": "repro.core.model",          # imports jax
    "TPUSimulator": "repro.core.simulator",
    "V5E": "repro.core.simulator",
    "HardwareSpec": "repro.core.simulator",
    "AnalyticalModel": "repro.core.analytical",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value      # cache: next access skips __getattr__
        return value
    try:                             # `repro.core.features`-style access
        return importlib.import_module(f"{__name__}.{name}")
    except ModuleNotFoundError as e:
        if e.name != f"{__name__}.{name}":
            raise                    # real dependency failure inside the
                                     # submodule (e.g. jax missing)
        raise AttributeError(
            f"module 'repro.core' has no attribute {name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(__all__))
