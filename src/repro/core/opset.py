"""Opcode registry for tensor-program kernel graphs.

This is the shared vocabulary between (a) the synthetic program generator,
(b) the jaxpr importer, (c) the feature extractor, (d) the analytical model,
and (e) the ground-truth simulator. Each opcode carries the static semantics
the cost layers need: which functional unit it exercises, FLOPs per output
element, whether it hits the transcendental unit, and fusibility class.

The categories mirror XLA HLO opcodes (the paper's node vocabulary).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class OpInfo:
    name: str
    index: int
    unit: str            # 'mxu' | 'vpu' | 'mem' | 'special' | 'none'
    flops_per_elem: float  # FLOPs per output element (contractions override)
    transcendental: bool = False
    elementwise: bool = False
    fusible: bool = True   # can be fused into a producer/consumer group
    fusion_root_only: bool = False  # contraction: may only root a fusion
    arity: int = 1


_OPS: list[OpInfo] = []


def _op(name: str, unit: str, flops: float, *, trans=False, ew=False,
        fusible=True, root_only=False, arity=1) -> OpInfo:
    info = OpInfo(name, len(_OPS), unit, flops, transcendental=trans,
                  elementwise=ew, fusible=fusible, fusion_root_only=root_only,
                  arity=arity)
    _OPS.append(info)
    return info


# --- graph boundary ---------------------------------------------------------
PARAMETER = _op("parameter", "none", 0.0, arity=0)
CONSTANT = _op("constant", "none", 0.0, arity=0)
IOTA = _op("iota", "vpu", 0.0, arity=0)
RNG = _op("rng", "special", 4.0, trans=True, arity=0)

# --- elementwise unary ------------------------------------------------------
NEG = _op("negate", "vpu", 1.0, ew=True)
ABS = _op("abs", "vpu", 1.0, ew=True)
EXP = _op("exponential", "special", 4.0, trans=True, ew=True)
LOG = _op("log", "special", 4.0, trans=True, ew=True)
TANH = _op("tanh", "special", 6.0, trans=True, ew=True)
RSQRT = _op("rsqrt", "special", 2.0, trans=True, ew=True)
SQRT = _op("sqrt", "special", 2.0, trans=True, ew=True)
ERF = _op("erf", "special", 8.0, trans=True, ew=True)
LOGISTIC = _op("logistic", "special", 5.0, trans=True, ew=True)
SIGN = _op("sign", "vpu", 1.0, ew=True)
FLOOR = _op("floor", "vpu", 1.0, ew=True)
CONVERT = _op("convert", "vpu", 1.0, ew=True)
NOT = _op("not", "vpu", 1.0, ew=True)
SIN = _op("sine", "special", 6.0, trans=True, ew=True)
COS = _op("cosine", "special", 6.0, trans=True, ew=True)

# --- elementwise binary / ternary -------------------------------------------
ADD = _op("add", "vpu", 1.0, ew=True, arity=2)
SUB = _op("subtract", "vpu", 1.0, ew=True, arity=2)
MUL = _op("multiply", "vpu", 1.0, ew=True, arity=2)
DIV = _op("divide", "vpu", 3.0, ew=True, arity=2)
POW = _op("power", "special", 8.0, trans=True, ew=True, arity=2)
MAX = _op("maximum", "vpu", 1.0, ew=True, arity=2)
MIN = _op("minimum", "vpu", 1.0, ew=True, arity=2)
REM = _op("remainder", "vpu", 4.0, ew=True, arity=2)
AND = _op("and", "vpu", 1.0, ew=True, arity=2)
OR = _op("or", "vpu", 1.0, ew=True, arity=2)
COMPARE = _op("compare", "vpu", 1.0, ew=True, arity=2)
SELECT = _op("select", "vpu", 1.0, ew=True, arity=3)
CLAMP = _op("clamp", "vpu", 2.0, ew=True, arity=3)

# --- data movement / layout --------------------------------------------------
BROADCAST = _op("broadcast", "mem", 0.0)
RESHAPE = _op("reshape", "mem", 0.0)
TRANSPOSE = _op("transpose", "mem", 0.0)
CONCATENATE = _op("concatenate", "mem", 0.0, arity=2)
SLICE = _op("slice", "mem", 0.0)
PAD = _op("pad", "mem", 0.0)
REVERSE = _op("reverse", "mem", 0.0)
COPY = _op("copy", "mem", 0.0)
DYNAMIC_SLICE = _op("dynamic-slice", "mem", 0.0, arity=2)
DYNAMIC_UPDATE_SLICE = _op("dynamic-update-slice", "mem", 0.0, arity=3)
GATHER = _op("gather", "mem", 0.0, arity=2)
SCATTER = _op("scatter", "mem", 1.0, arity=3)

# --- reductions --------------------------------------------------------------
REDUCE_SUM = _op("reduce-sum", "vpu", 1.0)
REDUCE_MAX = _op("reduce-max", "vpu", 1.0)
REDUCE_MIN = _op("reduce-min", "vpu", 1.0)
REDUCE_PROD = _op("reduce-prod", "vpu", 1.0)
REDUCE_AND = _op("reduce-and", "vpu", 1.0)
REDUCE_OR = _op("reduce-or", "vpu", 1.0)
CUMSUM = _op("cumsum", "vpu", 1.0)
ARGMAX = _op("argmax", "vpu", 2.0)
SORT = _op("sort", "vpu", 8.0, fusible=False)
TOPK = _op("top-k", "vpu", 6.0, fusible=False)

# --- contractions (MXU) -------------------------------------------------------
DOT = _op("dot", "mxu", 2.0, root_only=True, arity=2)   # flops set from K dim
CONV = _op("convolution", "mxu", 2.0, root_only=True, arity=2)

# --- collectives / misc (appear when importing sharded jaxprs) ----------------
ALL_REDUCE = _op("all-reduce", "mem", 1.0, fusible=False)
ALL_GATHER = _op("all-gather", "mem", 0.0, fusible=False)
REDUCE_SCATTER = _op("reduce-scatter", "mem", 1.0, fusible=False)
ALL_TO_ALL = _op("all-to-all", "mem", 0.0, fusible=False)
COLLECTIVE_PERMUTE = _op("collective-permute", "mem", 0.0, fusible=False)
CUSTOM_CALL = _op("custom-call", "vpu", 2.0, fusible=False)
WHILE = _op("while", "none", 0.0, fusible=False)
SCAN = _op("scan", "none", 0.0, fusible=False)

OPCODES: tuple[OpInfo, ...] = tuple(_OPS)
NUM_OPCODES: int = len(OPCODES)
OP_BY_NAME: dict[str, OpInfo] = {o.name: o for o in OPCODES}
OP_BY_INDEX: dict[int, OpInfo] = {o.index: o for o in OPCODES}

ELEMENTWISE_UNARY = tuple(o for o in OPCODES if o.elementwise and o.arity == 1)
ELEMENTWISE_BINARY = tuple(o for o in OPCODES if o.elementwise and o.arity == 2)
TRANSCENDENTAL = tuple(o for o in OPCODES if o.transcendental)
REDUCTIONS = (REDUCE_SUM, REDUCE_MAX, REDUCE_MIN, REDUCE_PROD, CUMSUM)
CONTRACTIONS = (DOT, CONV)


# Map of jax primitive names -> OpInfo, used by the jaxpr importer.
JAX_PRIMITIVE_MAP: dict[str, OpInfo] = {
    "add": ADD, "add_any": ADD, "sub": SUB, "mul": MUL, "div": DIV,
    "max": MAX, "min": MIN, "pow": POW, "integer_pow": POW, "rem": REM,
    "and": AND, "or": OR, "xor": OR, "not": NOT,
    "neg": NEG, "abs": ABS, "exp": EXP, "exp2": EXP, "log": LOG,
    "log1p": LOG, "expm1": EXP, "tanh": TANH, "rsqrt": RSQRT, "sqrt": SQRT,
    "erf": ERF, "logistic": LOGISTIC, "sign": SIGN, "floor": FLOOR,
    "ceil": FLOOR, "round": FLOOR, "sin": SIN, "cos": COS,
    "convert_element_type": CONVERT, "bitcast_convert_type": CONVERT,
    "eq": COMPARE, "ne": COMPARE, "lt": COMPARE, "le": COMPARE,
    "gt": COMPARE, "ge": COMPARE, "select_n": SELECT, "clamp": CLAMP,
    "broadcast_in_dim": BROADCAST, "reshape": RESHAPE,
    "squeeze": RESHAPE, "expand_dims": RESHAPE, "transpose": TRANSPOSE,
    "concatenate": CONCATENATE, "slice": SLICE, "pad": PAD, "rev": REVERSE,
    "copy": COPY, "dynamic_slice": DYNAMIC_SLICE,
    "dynamic_update_slice": DYNAMIC_UPDATE_SLICE,
    "gather": GATHER, "scatter": SCATTER, "scatter_add": SCATTER,
    "scatter-add": SCATTER,
    "reduce_sum": REDUCE_SUM, "reduce_max": REDUCE_MAX,
    "reduce_min": REDUCE_MIN, "reduce_prod": REDUCE_PROD,
    "reduce_and": REDUCE_AND, "reduce_or": REDUCE_OR,
    "cumsum": CUMSUM, "cumlogsumexp": CUMSUM, "cummax": CUMSUM,
    "argmax": ARGMAX, "argmin": ARGMAX, "reduce_precision": CONVERT,
    "sort": SORT, "top_k": TOPK, "iota": IOTA,
    "dot_general": DOT, "conv_general_dilated": CONV,
    "psum": ALL_REDUCE, "all_gather": ALL_GATHER,
    "psum_scatter": REDUCE_SCATTER, "all_to_all": ALL_TO_ALL,
    "ppermute": COLLECTIVE_PERMUTE,
    "random_bits": RNG, "random_seed": RNG, "random_wrap": RNG,
    "random_fold_in": RNG, "threefry2x32": RNG,
    "stop_gradient": COPY, "while": WHILE, "scan": SCAN,
    "custom_jvp_call": CUSTOM_CALL, "custom_vjp_call": CUSTOM_CALL,
    "remat": CUSTOM_CALL, "checkpoint": CUSTOM_CALL,
    "erf_inv": ERF, "atan2": SIN, "asin": SIN, "acos": SIN, "atan": SIN,
    "sinh": SIN, "cosh": COS, "asinh": SIN, "acosh": COS, "atanh": TANH,
    "square": MUL, "is_finite": COMPARE, "nextafter": ADD,
    "real": COPY, "imag": COPY, "conj": COPY, "complex": ADD,
    "cbrt": RSQRT, "population_count": ABS, "clz": ABS,
    "shift_left": MUL, "shift_right_logical": DIV,
    "shift_right_arithmetic": DIV,
}
