"""Kernel-graph IR.

Three representations:

* `Node` / `KernelGraph` — host-side (numpy / python) graph with full
  static semantics. This is what the generator, importer, simulator and
  analytical model operate on. Nodes are stored in topological order
  (guaranteed by construction in the generator/importer) — the paper's LSTM
  reduction runs over topologically sorted nodes.
* `features.GraphBatch` — a padded, masked, device-ready pytree produced
  by `features.encode_batch`. The adjacency is dense `[B, N, N]`
  (`adj[b, d, s] = 1` iff edge s→d), which on TPU turns neighbor
  aggregation into an MXU matmul (see DESIGN.md §3).
* `features.SparseGraphBatch` — the packed equivalent (flat node/edge
  buffers + segment ids) produced by `features.encode_sparse_batch` via
  the bucketing batcher in `repro.data.batching` (DESIGN.md §4).

`KernelGraph.canonical_hash()` content-addresses a graph (structure +
tile, invariant to node renumbering) — the serving cache key
(`repro.serving`, DESIGN.md §8).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core import opset
from repro.core.opset import OpInfo


@dataclass
class Node:
    """One tensor operation. `shape` is the output tensor shape."""
    op: OpInfo
    shape: tuple[int, ...]
    dtype_bytes: int = 4
    inputs: tuple[int, ...] = ()          # indices of producer nodes
    is_output: bool = False
    # contraction metadata (dot/conv): reduced dimension size
    contract_dim: int = 0
    # convolution filter spatial size (kh, kw) when op is CONV
    filter_size: tuple[int, int] = (0, 0)
    # reduction: which dims are reduced (sizes)
    reduced_dims: tuple[int, ...] = ()

    @property
    def volume(self) -> int:
        v = 1
        for d in self.shape:
            v *= int(d)
        return int(v)

    @property
    def bytes_out(self) -> int:
        return self.volume * self.dtype_bytes

    def flops(self) -> float:
        """Total FLOPs to produce this node's output tensor."""
        if self.op is opset.DOT:
            return 2.0 * self.volume * max(self.contract_dim, 1)
        if self.op is opset.CONV:
            kh, kw = self.filter_size
            return 2.0 * self.volume * max(self.contract_dim, 1) * max(kh, 1) * max(kw, 1)
        if self.op.unit in ("mem", "none"):
            return 0.0
        in_vol = self.volume
        if self.reduced_dims:
            red = 1
            for d in self.reduced_dims:
                red *= max(int(d), 1)
            in_vol = self.volume * red
        return self.op.flops_per_elem * in_vol

    def transcendental_count(self) -> float:
        if not self.op.transcendental:
            return 0.0
        return float(self.volume)

    # --- serialization (on-disk corpus store; repro.data.store) -------------
    def to_dict(self) -> dict:
        """JSON-able representation; exact inverse of `Node.from_dict`."""
        return {"op": self.op.name, "shape": list(self.shape),
                "dtype_bytes": int(self.dtype_bytes),
                "inputs": list(self.inputs),
                "is_output": bool(self.is_output),
                "contract_dim": int(self.contract_dim),
                "filter_size": list(self.filter_size),
                "reduced_dims": list(self.reduced_dims)}

    @staticmethod
    def from_dict(d: dict) -> "Node":
        return Node(opset.OP_BY_NAME[d["op"]], tuple(d["shape"]),
                    int(d["dtype_bytes"]), tuple(d["inputs"]),
                    bool(d["is_output"]), int(d["contract_dim"]),
                    tuple(d["filter_size"]), tuple(d["reduced_dims"]))


@dataclass
class KernelGraph:
    """A kernel: a fused subgraph executed as one unit."""
    nodes: list[Node]
    program: str = "synthetic"           # program this kernel came from
    name: str = "kernel"
    tile_size: tuple[int, ...] = ()      # set per-sample for the tile task

    def __post_init__(self):
        self._check_topo()

    def _check_topo(self) -> None:
        for i, n in enumerate(self.nodes):
            for j in n.inputs:
                if not (0 <= j < i):
                    raise ValueError(
                        f"nodes must be topologically ordered; node {i} "
                        f"({n.op.name}) has input {j}")

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def root(self) -> Node:
        """The kernel's dominant output node (last output, else last node)."""
        for n in reversed(self.nodes):
            if n.is_output:
                return n
        return self.nodes[-1]

    @property
    def output_nodes(self) -> list[Node]:
        outs = [n for n in self.nodes if n.is_output]
        return outs if outs else [self.nodes[-1]]

    @property
    def parameter_nodes(self) -> list[Node]:
        return [n for n in self.nodes if n.op is opset.PARAMETER]

    def edges(self) -> list[tuple[int, int]]:
        """(src, dst) pairs."""
        es = []
        for d, n in enumerate(self.nodes):
            for s in n.inputs:
                es.append((s, d))
        return es

    def unique_edges(self) -> list[tuple[int, int]]:
        """(src, dst) pairs with multi-edges collapsed (a node consuming the
        same producer twice, e.g. add(x, x), yields one edge) — the same
        set semantics as the dense `features.adjacency` matrix, which the
        sparse edge-list encoding must match for numerical equivalence.

        Memoized: the sparse batcher asks for the edge set several times
        per encode (bucketing + capacity checks + the write loop), every
        training step.
        """
        cached = getattr(self, "_unique_edges", None)
        if cached is None:
            seen: set[tuple[int, int]] = set()
            cached = []
            for e in self.edges():
                if e not in seen:
                    seen.add(e)
                    cached.append(e)
            self._unique_edges = cached
        return cached

    def fan_out(self) -> np.ndarray:
        fo = np.zeros((self.num_nodes,), np.int32)
        for d, n in enumerate(self.nodes):
            for s in n.inputs:
                fo[s] += 1
        return fo

    def depth(self) -> int:
        """Critical-path length (number of nodes on the longest chain)."""
        dep = np.zeros((self.num_nodes,), np.int64)
        for i, n in enumerate(self.nodes):
            dep[i] = 1 + max((dep[j] for j in n.inputs), default=0)
        return int(dep.max(initial=0))

    # --- static analysis (the paper's 4 optional kernel features) -----------
    def total_flops(self) -> float:
        return float(sum(n.flops() for n in self.nodes))

    def bytes_read(self) -> float:
        """Bytes read from HBM: kernel inputs (parameters/constants)."""
        return float(sum(n.bytes_out for n in self.nodes
                         if n.op in (opset.PARAMETER, opset.CONSTANT)))

    def bytes_written(self) -> float:
        return float(sum(n.bytes_out for n in self.output_nodes))

    def transcendental_total(self) -> float:
        return float(sum(n.transcendental_count() for n in self.nodes))

    def with_tile(self, tile: Sequence[int]) -> "KernelGraph":
        g = KernelGraph(self.nodes, self.program, self.name, tuple(int(t) for t in tile))
        cached = getattr(self, "_unique_edges", None)
        if cached is not None:       # same nodes ⇒ same edge set
            g._unique_edges = cached
        digests = getattr(self, "_node_digests", None)
        if digests is not None:      # same nodes ⇒ same node digests
            g._node_digests = digests
        return g

    # --- content addressing (serving cache key; docs/SERVING.md) ------------
    def _merkle_node_digests(self) -> list[bytes]:
        """Per-node Merkle digests: each covers the node's semantic content
        (op, shape, dtype size, output flag, contraction/filter/reduction
        metadata, fan-out) plus the digests of its producers in input
        order, so it identifies the node's whole ancestor cone —
        independent of node indices. Memoized, and copied by `with_tile`
        (same nodes ⇒ same digests)."""
        cached = getattr(self, "_node_digests", None)
        if cached is None:
            fan_out = self.fan_out()
            cached = []
            for i, n in enumerate(self.nodes):
                h = hashlib.blake2b(digest_size=16)
                h.update(repr((n.op.index, n.shape, n.dtype_bytes,
                               n.is_output, n.contract_dim, n.filter_size,
                               n.reduced_dims, int(fan_out[i]))).encode())
                for j in n.inputs:
                    h.update(cached[j])
                cached.append(h.digest())
            self._node_digests = cached
        return cached

    def structural_digest(self, *, order_sensitive: bool = False) -> bytes:
        """Digest of the graph structure: node count + the Merkle node
        digests. By default the digests are *sorted*, so any topological-
        order-preserving relabeling (`renumbered`) produces the same
        bytes; `order_sensitive=True` keeps them in stored node order,
        for consumers that are not permutation-invariant (the LSTM
        reduction runs over topologically sorted node order)."""
        digests = self._merkle_node_digests()
        top = hashlib.blake2b(digest_size=16)
        top.update(len(self.nodes).to_bytes(8, "little"))
        for d in (digests if order_sensitive else sorted(digests)):
            top.update(d)
        return top.digest()

    def canonical_hash(self, *, order_sensitive: bool = False) -> str:
        """Content-addressed identity of (structure, tile_size) — the
        prediction-cache key used by `repro.serving`. Deliberately excludes
        `program`/`name` (labels don't affect predictions) and is invariant
        to node renumbering, mirroring the set semantics of `unique_edges`:
        two graphs with equal hashes encode to equivalent feature batches.

        `order_sensitive=True` additionally hashes the node *order*, for
        models whose predictions depend on it (`reduction="lstm"`;
        `CostModelService` selects this automatically).

        >>> from repro.core import opset
        >>> from repro.core.graph import KernelGraph, Node
        >>> g = KernelGraph([Node(opset.PARAMETER, (8, 8)),
        ...                  Node(opset.PARAMETER, (4, 8)),
        ...                  Node(opset.DOT, (4, 8), inputs=(1, 0),
        ...                       contract_dim=8, is_output=True)],
        ...                 name="demo")
        >>> g.canonical_hash() == g.renumbered([1, 0, 2]).canonical_hash()
        True
        >>> g.canonical_hash() == g.with_tile((8, 8)).canonical_hash()
        False
        >>> h = lambda x: x.canonical_hash(order_sensitive=True)
        >>> h(g) == h(g.renumbered([1, 0, 2]))     # distinct params swapped
        False
        """
        cached = getattr(self, "_canonical_hash", None)
        if cached is None:
            cached = self._canonical_hash = {}
        key = cached.get(order_sensitive)
        if key is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(self.structural_digest(order_sensitive=order_sensitive))
            h.update(repr(self.tile_size).encode())
            key = cached[order_sensitive] = h.hexdigest()
        return key

    # --- serialization (on-disk corpus store; repro.data.store) -------------
    def to_dict(self) -> dict:
        """JSON-able representation of the full kernel (nodes + labels +
        tile). `from_dict` is an exact inverse: the round trip preserves
        content addressing, so a stored kernel dedups against its source.

        >>> from repro.core import opset
        >>> from repro.core.graph import KernelGraph, Node
        >>> g = KernelGraph([Node(opset.PARAMETER, (8, 4)),
        ...                  Node(opset.TANH, (8, 4), inputs=(0,),
        ...                       is_output=True)], program="mlp_0")
        >>> g2 = KernelGraph.from_dict(g.to_dict())
        >>> (g2.program, g2.canonical_hash() == g.canonical_hash())
        ('mlp_0', True)
        """
        return {"nodes": [n.to_dict() for n in self.nodes],
                "program": self.program, "name": self.name,
                "tile_size": list(self.tile_size)}

    @staticmethod
    def from_dict(d: dict) -> "KernelGraph":
        return KernelGraph([Node.from_dict(n) for n in d["nodes"]],
                           program=d["program"], name=d["name"],
                           tile_size=tuple(d["tile_size"]))

    def renumbered(self, perm: Sequence[int]) -> "KernelGraph":
        """Relabel nodes by `perm` (new order = [nodes[p] for p in perm]).

        Only valid if the permutation preserves topological order; used by
        tests for permutation-invariance checks at the encoding level.
        """
        inv = {p: i for i, p in enumerate(perm)}
        new_nodes = []
        for p in perm:
            n = self.nodes[p]
            new_nodes.append(Node(n.op, n.shape, n.dtype_bytes,
                                  tuple(inv[j] for j in n.inputs),
                                  n.is_output, n.contract_dim,
                                  n.filter_size, n.reduced_dims))
        return KernelGraph(new_nodes, self.program, self.name, self.tile_size)


@dataclass
class Program:
    """A tensor program: a list of primitive ops (pre-fusion graph) or, once
    fused, a list of kernels."""
    name: str
    kernels: list[KernelGraph] = field(default_factory=list)

    def total_runtime(self, timer) -> float:
        """Program runtime = Σ kernel runtimes (paper §2.1)."""
        return float(sum(timer(k) for k in self.kernels))


def validate_graph(g: KernelGraph, max_nodes: int | None = None) -> None:
    if g.num_nodes == 0:
        raise ValueError("empty kernel graph")
    if max_nodes is not None and g.num_nodes > max_nodes:
        raise ValueError(f"kernel has {g.num_nodes} nodes > cap {max_nodes}")
    for i, n in enumerate(g.nodes):
        if n.op.arity == 0 and n.inputs:
            raise ValueError(f"node {i} ({n.op.name}) is nullary but has inputs")
        if len(n.shape) > 6:
            raise ValueError(f"node {i}: rank {len(n.shape)} > 6 unsupported")
