"""The analytical baseline — the paper's Appendix-A model, reimplemented.

XLA's hand-tuned model estimates a kernel's data-transfer time and compute
time per tile iteration and takes the **maximum** of the two. It is heavily
tuned: it models tile-dependent operand re-reads, achieved bandwidth as a
function of transfer size ("larger transfers are more efficient"), and
lane-padded compute (tiles are rounded up to the 8×128 vector/MXU lanes).

Its blind spots are exactly the ones Appendix A admits:
  (i)   bi-directional transfer interactions (in/out folded together, no
        pipeline fill/drain),
  (ii)  instruction scheduling (no ILP/critical-path factor),
  (iii) register usage effects (no fan-out pressure penalty),
  (iv)  dynamic stalls & fixed overheads (no kernel launch cost, no per-tile
        sequencing bubble, no separate transcendental unit, and its DMA
        latency constant is hand-tuned slightly off the real machine).

Those are what the ground-truth simulator adds — the learned model has real
signal to pick up, mirroring the paper's result structure (analytical is
good at within-kernel tile ranking, poor at absolute cross-kernel runtimes).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.graph import KernelGraph
from repro.core.simulator import (
    HardwareSpec,
    TileStats,
    V5E,
    _round_up,
    tile_stats,
)


@dataclass
class AnalyticalModel:
    """max(compute, transfer) per tile — hand-tuned constants."""
    hw: HardwareSpec = V5E
    mxu_utilization: float = 0.78        # single hand-tuned constant
    vpu_utilization: float = 0.6
    dma_latency: float = 0.8e-6          # hand-tuned; real machine is 1.2e-6
    loop_cost: float = 2.0e-8            # per-iteration bookkeeping (tuned;
    #                                      the machine's true bubble is ~8x)

    def _dma_eff(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 1.0
        return max(nbytes / (nbytes + self.hw.hbm_bw * self.dma_latency),
                   0.02)

    def predict(self, g: KernelGraph, tile: tuple[int, ...] | None = None) -> float:
        st: TileStats = tile_stats(g, tile, self.hw)
        if st.vmem_per_tile > self.hw.vmem_bytes * self.hw.vmem_usable_frac:
            # analytical model rejects invalid tiles with a large constant
            return 1.0

        # lane-padded compute: tiles round up to the 8x128 hardware lanes
        t = st.tile
        last = t[-1] if t else 1
        second = t[-2] if len(t) >= 2 else 1
        pad = (_round_up(last, 128) / max(last, 1)) * \
              (_round_up(second, 8) / max(second, 1))
        mxu_t = st.mxu_flops_per_tile * pad / (self.hw.peak_mxu_flops *
                                               self.mxu_utilization)
        # one vector rate for everything non-MXU (no transcendental unit)
        vpu_t = (st.vpu_flops_per_tile /
                 (self.hw.peak_vpu_flops * self.vpu_utilization))
        compute_t = mxu_t + vpu_t

        bytes_tile = st.bytes_in_per_tile + st.bytes_out_per_tile
        mem_t = bytes_tile / (self.hw.hbm_bw * self._dma_eff(bytes_tile))

        return st.num_tiles * (max(compute_t, mem_t) + self.loop_cost)

    def best_tile(self, g: KernelGraph, tiles) -> tuple[int, ...]:
        """Compiler default: pick argmin over enumerated tiles."""
        best, best_t = None, float("inf")
        for t in tiles:
            p = self.predict(g, t)
            if p < best_t:
                best, best_t = t, p
        return tuple(best) if best is not None else ()


def fit_type_coefficients(model: AnalyticalModel, kernels, measured) -> dict:
    """Paper §5.2: scale the analytical output per kernel *type* so it can be
    compared on absolute runtimes (the model's scales differ across types).
    Coefficient = Σ true / Σ predicted per type."""
    sums: dict[str, list[float]] = {}
    for g, y in zip(kernels, measured):
        ty = kernel_type(g)
        s = sums.setdefault(ty, [0.0, 0.0])
        s[0] += y
        s[1] += model.predict(g)
    return {ty: (s[0] / s[1] if s[1] > 0 else 1.0) for ty, s in sums.items()}


def kernel_type(g: KernelGraph) -> str:
    has_conv = any(n.op.name == "convolution" for n in g.nodes)
    has_dot = any(n.op.name == "dot" for n in g.nodes)
    if has_conv:
        return "conv"
    if has_dot:
        return "dot"
    if any(n.op.name.startswith("reduce") for n in g.nodes):
        return "reduce"
    return "elementwise"


def predict_scaled(model: AnalyticalModel, coeffs: dict, g: KernelGraph) -> float:
    return model.predict(g) * coeffs.get(kernel_type(g), 1.0)
