"""Import jitted JAX functions as cost-model programs.

`import_jaxpr(fn, *args)` traces a function, walks its (flattened) jaxpr
and converts every equation into a `Node` — the same pre-fusion program
representation the synthetic generator emits. The fusion machinery and
datasets then treat imported programs exactly like synthetic ones, which is
how the 10 assigned architectures join the cost-model corpus (paper §4's
"programs from production models", here from the model zoo itself).

Control-flow primitives (scan/while/cond) are inlined one body iteration
deep — matching how the cost model sees kernels (XLA kernels never span
loop boundaries).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.extend import core as jcore

from repro.core import opset
from repro.core.graph import KernelGraph, Node

_MAX_NODES_PER_PROGRAM = 4096


def _dtype_bytes(aval) -> int:
    try:
        return max(int(np.dtype(aval.dtype).itemsize), 1)
    except Exception:                                  # noqa: BLE001
        return 4


def _shape(aval) -> tuple[int, ...]:
    shape = tuple(int(d) for d in getattr(aval, "shape", ()) or ())
    return shape[:6] if shape else (1,)


def _op_for(eqn) -> opset.OpInfo:
    name = eqn.primitive.name
    if name == "reduce_sum" or name in opset.JAX_PRIMITIVE_MAP:
        return opset.JAX_PRIMITIVE_MAP.get(name, opset.CUSTOM_CALL)
    return opset.JAX_PRIMITIVE_MAP.get(name, opset.CUSTOM_CALL)


def _contract_dim(eqn) -> int:
    if eqn.primitive.name != "dot_general":
        return 0
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs_aval = eqn.invars[0].aval
    d = 1
    for axis in lc:
        d *= int(lhs_aval.shape[axis])
    return d


def _conv_meta(eqn):
    if eqn.primitive.name != "conv_general_dilated":
        return 0, (0, 0)
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    spatial = tuple(int(rhs.shape[i]) for i in dn.rhs_spec[2:])
    in_ch = int(rhs.shape[dn.rhs_spec[1]])
    kh = spatial[0] if spatial else 1
    kw = spatial[1] if len(spatial) > 1 else 1
    return in_ch, (kh, kw)


def _reduced_dims(eqn) -> tuple[int, ...]:
    name = eqn.primitive.name
    if name.startswith("reduce_") and "axes" in eqn.params:
        in_aval = eqn.invars[0].aval
        return tuple(int(in_aval.shape[a]) for a in eqn.params["axes"])[:2]
    return ()


def jaxpr_to_program(closed_jaxpr, name: str, program: str) -> KernelGraph:
    """Flatten a ClosedJaxpr (inlining inner jaxprs once) to a program."""
    nodes: list[Node] = []
    var_to_node: dict = {}

    def add_node(n: Node):
        nodes.append(n)
        return len(nodes) - 1

    def ensure_input(v) -> int | None:
        """Map a jaxpr var/literal to a node index (parameter/constant)."""
        if isinstance(v, jcore.Literal):
            return add_node(Node(opset.CONSTANT, _shape(v.aval),
                                 _dtype_bytes(v.aval)))
        if v in var_to_node:
            return var_to_node[v]
        idx = add_node(Node(opset.PARAMETER, _shape(v.aval),
                            _dtype_bytes(v.aval)))
        var_to_node[v] = idx
        return idx

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if len(nodes) >= _MAX_NODES_PER_PROGRAM:
                return
            prim = eqn.primitive.name
            inner = None
            for key, p in eqn.params.items():
                if key == "branches" and isinstance(p, (tuple, list)) and p:
                    p = p[0]
                if isinstance(p, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                    inner = p
                    break
            if inner is not None:
                ij = getattr(inner, "jaxpr", inner)
                # bind inner invars to outer inputs where arity matches
                for iv, ov in zip(ij.invars[-len(eqn.invars):], eqn.invars):
                    if not isinstance(ov, jcore.Literal) and \
                            ov in var_to_node:
                        var_to_node[iv] = var_to_node[ov]
                walk(ij)
                for outv, innerv in zip(eqn.outvars, ij.outvars):
                    if not isinstance(innerv, jcore.Literal) and \
                            innerv in var_to_node:
                        var_to_node[outv] = var_to_node[innerv]
                continue
            op = _op_for(eqn)
            inputs = []
            for v in eqn.invars:
                idx = ensure_input(v)
                if idx is not None:
                    inputs.append(idx)
            out = eqn.outvars[0]
            contract = _contract_dim(eqn)
            filt = (0, 0)
            if prim == "conv_general_dilated":
                contract, filt = _conv_meta(eqn)
            node = Node(op, _shape(out.aval), _dtype_bytes(out.aval),
                        tuple(inputs[:3]), False, contract, filt,
                        _reduced_dims(eqn))
            idx = add_node(node)
            for ov in eqn.outvars:
                var_to_node[ov] = idx

    jaxpr = closed_jaxpr.jaxpr
    for v in jaxpr.invars:
        var_to_node[v] = add_node(
            Node(opset.PARAMETER, _shape(v.aval), _dtype_bytes(v.aval)))
    walk(jaxpr)
    # mark outputs
    for v in jaxpr.outvars:
        if not isinstance(v, jcore.Literal) and v in var_to_node:
            i = var_to_node[v]
            n = nodes[i]
            nodes[i] = Node(n.op, n.shape, n.dtype_bytes, n.inputs, True,
                            n.contract_dim, n.filter_size, n.reduced_dims)
    if not any(n.is_output for n in nodes):
        n = nodes[-1]
        nodes[-1] = Node(n.op, n.shape, n.dtype_bytes, n.inputs, True,
                         n.contract_dim, n.filter_size, n.reduced_dims)
    return KernelGraph(nodes, program=program, name=name)


def import_jaxpr(fn, *args, name: str = "imported",
                 program: str | None = None) -> KernelGraph:
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_to_program(closed, name, program or name)


def import_arch_program(arch: str, seq: int = 64, batch: int = 2
                        ) -> KernelGraph:
    """Trace one smoke-scale forward pass of an assigned architecture into
    a cost-model program (corpus entry `arch_<name>`)."""
    from repro.models import registry
    from repro.models import lm
    from repro.models.config import ShapeSpec
    from repro.models.inputs import make_batch

    cfg = registry.get_smoke_config(arch)
    shape = ShapeSpec("import", seq, batch, "train")
    batch_data = make_batch(cfg, shape)
    params = lm.init_params(jax.random.key(0), cfg)

    def fwd(params, batch_data):
        return lm.loss_fn(params, cfg, batch_data)

    return import_jaxpr(fwd, params, batch_data,
                        name=f"arch_{arch}", program=f"arch_{arch}")
