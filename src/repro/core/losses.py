"""Training objectives (paper §3.3).

* Tile-size task: pairwise rank loss, Eq. (1) —
    L = Σ_i Σ_j φ(y'_i − y'_j) · pos(y_i − y_j) / (n(n−1)/2)
  with φ = hinge (1−z)_+ or logistic log(1+e^(−z)). Pairs are only compared
  within the same ranking group (same kernel, different tile sizes) — group
  ids mask cross-kernel pairs.

* Fusion task: squared error on log-transformed targets (runtimes span ns→s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _phi(z: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "hinge":
        return jax.nn.relu(1.0 - z)
    if kind == "logistic":
        return jnp.log1p(jnp.exp(-z))
    raise ValueError(f"unknown rank loss {kind!r}")


def pairwise_rank_loss(preds: jnp.ndarray, targets: jnp.ndarray,
                       group_ids: jnp.ndarray | None = None,
                       valid: jnp.ndarray | None = None,
                       *, phi: str = "hinge") -> jnp.ndarray:
    """preds/targets: [n]. group_ids: [n] int — pairs must share a group.

    pos(y_i - y_j) selects pairs where i is truly slower than j; the model is
    pushed to predict y'_i > y'_j for those (φ penalizes small/negative
    margins y'_i − y'_j).
    """
    n = preds.shape[0]
    dz = preds[:, None] - preds[None, :]
    dy = targets[:, None] - targets[None, :]
    pos = (dy > 0).astype(preds.dtype)
    pair = pos
    if group_ids is not None:
        same = (group_ids[:, None] == group_ids[None, :]).astype(preds.dtype)
        pair = pair * same
    if valid is not None:
        v = valid.astype(preds.dtype)
        pair = pair * v[:, None] * v[None, :]
    diag = 1.0 - jnp.eye(n, dtype=preds.dtype)
    pair = pair * diag
    loss = jnp.sum(_phi(dz, phi) * pair)
    return loss / (n * (n - 1) / 2.0)


def log_mse_loss(preds: jnp.ndarray, targets: jnp.ndarray,
                 valid: jnp.ndarray | None = None,
                 *, eps: float = 1e-12) -> jnp.ndarray:
    """preds are log-runtime estimates; targets are raw runtimes (seconds)."""
    err = (preds - jnp.log(targets + eps)) ** 2
    if valid is None:
        return jnp.mean(err)
    v = valid.astype(preds.dtype)
    return jnp.sum(err * v) / jnp.maximum(jnp.sum(v), 1.0)


def mse_loss(preds: jnp.ndarray, targets: jnp.ndarray,
             valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Plain MSE on raw targets — the 'MSE loss (not rank)' ablation row."""
    err = (preds - targets) ** 2
    if valid is None:
        return jnp.mean(err)
    v = valid.astype(preds.dtype)
    return jnp.sum(err * v) / jnp.maximum(jnp.sum(v), 1.0)
