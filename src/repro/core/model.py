"""The learned performance model (paper §3).

Pipeline:
  opcode embedding ⊕ node scalar features [⊕ kernel features (option 1)]
    → f1 → GNN (GraphSAGE | GAT | none)
    → node-final MLP (3 layers, Table 5)
    → reduction (per-node | column-wise | LSTM | Transformer)
      [⊕ kernel features (option 2)]
    → linear head (no activation) → scalar prediction per kernel.

The scalar is a log-runtime estimate for the fusion task and an arbitrary
ranking score for the tile-size task (trained with pairwise rank loss).
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from repro.core import features as F
from repro.core import gnn as G
from repro.core import reductions as R
from repro.core.opset import NUM_OPCODES
from repro.nn.core import (
    dense_apply,
    dense_init,
    dropout,
    embedding_apply,
    embedding_init,
    mlp_apply,
    mlp_init,
)

# batches must be jit-traceable before any apply; features.py defers this
# so its numpy-only consumers never import jax
F.register_pytrees()


@dataclass
class CostModelConfig:
    gnn: str = "graphsage"               # graphsage | gat | none
    reduction: str = "transformer"       # per_node | column_wise | lstm | transformer
    hidden_dim: int = 192
    opcode_embed_dim: int = 64           # paper uses 256; scaled for CPU CI
    gnn_layers: int = 3                  # Table 5
    node_final_layers: int = 3           # Table 5
    aggregator: str = "mean"             # Table 5
    directed: bool = True                # 'vanilla'; False = ablation
    kernel_feat_mode: str = "node"       # 'node' (option 1) | 'kernel' (option 2)
    include_static_perf: bool = True
    include_tile: bool = True
    transformer_layers: int = 1
    transformer_heads: int = 4
    gat_heads: int = 2
    dropout: float = 0.1
    max_nodes: int = 64
    use_pallas_aggregate: bool = False   # fused Pallas graph_aggregate path
    # batched-graph representation the data path should produce for this
    # model: 'dense' ([B,N,N] padded adjacency, MXU matmul aggregation) or
    # 'sparse' (packed SparseGraphBatch + segment_sum). `cost_model_apply`
    # itself dispatches on the batch type; samplers/evaluators/autotuners
    # read this field to pick the encoder. See DESIGN.md §4.
    adjacency: str = "dense"             # dense | sparse | segmented
    # Store GNN layer params stacked ([L, ...] leaves) and run message
    # passing as one `lax.scan` over the layer axis: the layer body traces
    # once per bucket shape instead of `gnn_layers` times, so compile cost
    # is depth-independent (DESIGN.md §12). Either layout of an on-disk
    # checkpoint restores into either setting (training/checkpoint.py).
    scan_layers: bool = False
    # Numeric format of the parameter tree `cost_model_apply` receives:
    # 'f32' (plain arrays) or 'int8' (repro.quant — weights are
    # `QuantizedLeaf`s, dequantized inside jit; with use_pallas_aggregate
    # on the sparse layouts the GNN f2 weights instead stay int8 all the
    # way into the fused segment_aggregate kernel). Inference-only: the
    # trainer always trains f32 and `repro.quant.quantize_params`
    # produces the int8 tree afterwards (DESIGN.md §14).
    precision: str = "f32"

    def __post_init__(self):
        if self.adjacency not in ("dense", "sparse", "segmented"):
            raise ValueError(f"unknown adjacency {self.adjacency!r} "
                             "(dense | sparse | segmented)")
        if self.precision not in ("f32", "int8"):
            raise ValueError(f"unknown precision {self.precision!r} "
                             "(f32 | int8)")
        if self.use_pallas_aggregate and self.gnn != "graphsage":
            raise ValueError(
                f"use_pallas_aggregate supports gnn='graphsage' only, got "
                f"gnn={self.gnn!r} (dense layout: kernels/graph_aggregate; "
                "sparse/segmented: kernels/segment_aggregate)")

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "CostModelConfig":
        return CostModelConfig(**d)


def cost_model_init(rng, cfg: CostModelConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, 8)
    d = cfg.hidden_dim
    in_dim = cfg.opcode_embed_dim + F.NODE_FEATURE_DIM
    if cfg.kernel_feat_mode == "node":
        in_dim += F.KERNEL_FEATURE_DIM
    params = {
        "opcode_embed": embedding_init(keys[0], NUM_OPCODES,
                                       cfg.opcode_embed_dim, dtype=dtype),
        "f1": dense_init(keys[1], in_dim, d, bias=False, dtype=dtype),
        "node_final": mlp_init(keys[3], [d] * (cfg.node_final_layers + 1),
                               bias=False, dtype=dtype),
        "reduction": R.reduction_init(
            keys[4], cfg.reduction, d,
            transformer_layers=cfg.transformer_layers,
            transformer_heads=cfg.transformer_heads, dtype=dtype),
    }
    if cfg.gnn == "graphsage":
        params["gnn"] = G.sage_init(keys[2], d, cfg.gnn_layers,
                                    directed=cfg.directed, dtype=dtype)
    elif cfg.gnn == "gat":
        params["gnn"] = G.gat_init(keys[2], d, max(cfg.gnn_layers, 1),
                                   cfg.gat_heads, directed=cfg.directed,
                                   dtype=dtype)
    elif cfg.gnn != "none":
        raise ValueError(f"unknown gnn {cfg.gnn!r}")
    if cfg.scan_layers and "gnn" in params and params["gnn"]["layers"]:
        params["gnn"] = G.stack_params(params["gnn"])

    if cfg.reduction == "per_node":
        params["node_head"] = dense_init(keys[5], d, 1, bias=False, dtype=dtype)
        if cfg.kernel_feat_mode == "kernel":
            params["kernel_head"] = dense_init(
                keys[6], F.KERNEL_FEATURE_DIM, 1, bias=False, dtype=dtype)
    else:
        out_dim = R.reduction_out_dim(cfg.reduction, d)
        if cfg.kernel_feat_mode == "kernel":
            out_dim += F.KERNEL_FEATURE_DIM
        params["head"] = dense_init(keys[5], out_dim, 1, bias=False, dtype=dtype)
    return params


def cost_model_apply(params: dict, cfg: CostModelConfig, batch,
                     *, rng=None, deterministic: bool = True) -> jnp.ndarray:
    """batch: features.GraphBatch or features.SparseGraphBatch (pytrees).
    Returns predictions [B] (one per graph slot). Both representations share
    one parameter tree and agree numerically (DESIGN.md §4)."""
    if cfg.precision == "int8":
        from repro.quant.scale import dequantize_tree
        # sparse/segmented + Pallas: the GNN tree stays quantized — its f2
        # weights feed the segment_aggregate kernel as int8 and are
        # dequantized in-VMEM; everything else decodes here, inside jit
        keep_gnn = (cfg.use_pallas_aggregate and "gnn" in params
                    and not isinstance(batch, F.GraphBatch))
        gnn_q = params["gnn"] if keep_gnn else None
        params = dequantize_tree(params)
        if gnn_q is not None:
            params = dict(params, gnn=gnn_q)
    if isinstance(batch, F.SegmentedGraphBatch):
        return _cost_model_apply_segmented(params, cfg, batch, rng=rng,
                                           deterministic=deterministic)
    if isinstance(batch, F.SparseGraphBatch):
        return _cost_model_apply_sparse(params, cfg, batch, rng=rng,
                                        deterministic=deterministic)
    opcodes = batch.opcodes
    node_feats = batch.node_feats
    adj = batch.adj
    mask = batch.node_mask
    kfeats = batch.kernel_feats

    if not cfg.include_tile:
        kfeats = kfeats.at[:, F.TILE_SLICE].set(0.0)
    if not cfg.include_static_perf:
        kfeats = kfeats.at[:, F.STATIC_PERF_SLICE].set(0.0)

    emb = embedding_apply(params["opcode_embed"], opcodes)      # [B,N,E]
    x = jnp.concatenate([emb, node_feats], axis=-1)
    if cfg.kernel_feat_mode == "node":
        B, N = opcodes.shape
        kf = jnp.broadcast_to(kfeats[:, None, :], (B, N, kfeats.shape[-1]))
        x = jnp.concatenate([x, kf], axis=-1)

    eps = jax.nn.relu(dense_apply(params["f1"], x)) * mask[..., None]

    if cfg.gnn == "graphsage":
        eps = G.sage_apply(params["gnn"], eps, adj, mask,
                           aggregator=cfg.aggregator, directed=cfg.directed,
                           use_pallas=cfg.use_pallas_aggregate)
    elif cfg.gnn == "gat":
        eps = G.gat_apply(params["gnn"], eps, adj, mask,
                          num_heads=cfg.gat_heads, directed=cfg.directed)

    sub = None if rng is None else jax.random.fold_in(rng, 1)
    eps = dropout(sub, eps, cfg.dropout, deterministic)
    eps = mlp_apply(params["node_final"], eps, final_act=True)
    eps = eps * mask[..., None]

    if cfg.reduction == "per_node":
        per_node = dense_apply(params["node_head"], eps)[..., 0]  # [B,N]
        y = jnp.sum(per_node * mask, axis=1)
        if cfg.kernel_feat_mode == "kernel":
            y = y + dense_apply(params["kernel_head"], kfeats)[..., 0]
        return y

    kappa = R.reduction_apply(params["reduction"], cfg.reduction, eps, mask,
                              transformer_heads=cfg.transformer_heads,
                              rng=rng, dropout_rate=cfg.dropout,
                              deterministic=deterministic)
    if cfg.kernel_feat_mode == "kernel":
        kappa = jnp.concatenate([kappa, kfeats], axis=-1)
    return dense_apply(params["head"], kappa)[..., 0]


def _mask_kernel_feats(cfg: CostModelConfig, kfeats: jnp.ndarray):
    if not cfg.include_tile:
        kfeats = kfeats.at[:, F.TILE_SLICE].set(0.0)
    if not cfg.include_static_perf:
        kfeats = kfeats.at[:, F.STATIC_PERF_SLICE].set(0.0)
    return kfeats


def _embed_sparse(params: dict, cfg: CostModelConfig, batch) -> jnp.ndarray:
    """Embed + f1 + GNN over a flat sparse node buffer: the per-node half
    of the sparse forward pass, shared by the plain sparse path and the
    segmented path (which runs it on segment blocks before reassembly)."""
    mask = batch.node_mask                       # [M]
    kfeats = _mask_kernel_feats(cfg, batch.kernel_feats)

    emb = embedding_apply(params["opcode_embed"], batch.opcodes)  # [M, E]
    x = jnp.concatenate([emb, batch.node_feats], axis=-1)
    if cfg.kernel_feat_mode == "node":
        x = jnp.concatenate(
            [x, jnp.take(kfeats, batch.graph_ids, axis=0)], axis=-1)

    eps = jax.nn.relu(dense_apply(params["f1"], x)) * mask[:, None]

    if cfg.gnn == "graphsage":
        if cfg.use_pallas_aggregate:
            # fused kernels/segment_aggregate path (f32 or int8 f2 weights)
            eps = G.sage_apply_sparse_q(params["gnn"], eps, batch.edge_src,
                                        batch.edge_dst, batch.edge_mask,
                                        mask, aggregator=cfg.aggregator,
                                        directed=cfg.directed)
        else:
            eps = G.sage_apply_sparse(params["gnn"], eps, batch.edge_src,
                                      batch.edge_dst, batch.edge_mask, mask,
                                      aggregator=cfg.aggregator,
                                      directed=cfg.directed)
    elif cfg.gnn == "gat":
        eps = G.gat_apply_sparse(params["gnn"], eps, batch.edge_src,
                                     batch.edge_dst, batch.edge_mask, mask,
                                     num_heads=cfg.gat_heads,
                                     directed=cfg.directed)
    return eps


def _cost_model_apply_sparse(params: dict, cfg: CostModelConfig, batch,
                             *, rng=None,
                             deterministic: bool = True) -> jnp.ndarray:
    """Sparse/packed forward pass: flat [M, ·] node buffer, segment_sum
    aggregation, per-graph readout via segment ids (or a gather into a
    [G, R, D] layout for the sequence reductions)."""
    eps = _embed_sparse(params, cfg, batch)
    return _readout_sparse(params, cfg, eps, batch.node_mask,
                           batch.graph_ids, batch.kernel_feats,
                           batch.gather_idx, batch.gather_mask,
                           rng=rng, deterministic=deterministic)


def _cost_model_apply_segmented(params: dict, cfg: CostModelConfig, batch,
                                *, rng=None,
                                deterministic: bool = True) -> jnp.ndarray:
    """Whole-program forward pass (DESIGN.md §12): run the per-node half on
    the inner segment batch, scatter owned-node embeddings back into
    whole-graph node order, then read out per original graph. Graphs that
    fit one segment go through bit-identically to the sparse path."""
    eps_in = _embed_sparse(params, cfg, batch.inner)       # [M_inner, D]
    M = batch.num_nodes
    # halo + padding rows target the dummy slot M and are dropped; owned
    # slots are written exactly once (owned sets partition the graph)
    buf = jnp.zeros((M + 1, eps_in.shape[-1]), eps_in.dtype)
    eps = buf.at[batch.scatter_idx].set(eps_in)[:M]
    return _readout_sparse(params, cfg, eps, batch.node_mask,
                           batch.graph_ids, batch.kernel_feats,
                           batch.gather_idx, batch.gather_mask,
                           rng=rng, deterministic=deterministic)


def _readout_sparse(params: dict, cfg: CostModelConfig, eps: jnp.ndarray,
                    mask: jnp.ndarray, gids: jnp.ndarray,
                    kfeats: jnp.ndarray, gather_idx: jnp.ndarray,
                    gather_mask: jnp.ndarray, *, rng=None,
                    deterministic: bool = True) -> jnp.ndarray:
    """node-final MLP + reduction + head over a flat [M, D] embedding
    buffer with per-node graph ids — the per-graph half of the sparse
    forward pass (also the segmented path's outer readout)."""
    num_graphs = kfeats.shape[0]
    kfeats = _mask_kernel_feats(cfg, kfeats)

    sub = None if rng is None else jax.random.fold_in(rng, 1)
    eps = dropout(sub, eps, cfg.dropout, deterministic)
    eps = mlp_apply(params["node_final"], eps, final_act=True)
    eps = eps * mask[:, None]

    if cfg.reduction == "per_node":
        per_node = dense_apply(params["node_head"], eps)[..., 0]   # [M]
        y = jax.ops.segment_sum(per_node * mask, gids, num_segments=num_graphs)
        if cfg.kernel_feat_mode == "kernel":
            y = y + dense_apply(params["kernel_head"], kfeats)[..., 0]
        return y

    if cfg.reduction == "column_wise":
        s = jax.ops.segment_sum(eps * mask[:, None], gids,
                                num_segments=num_graphs)
        cnt = jax.ops.segment_sum(mask, gids, num_segments=num_graphs)
        n = jnp.maximum(cnt, 1.0)
        neg = jnp.finfo(eps.dtype).min
        mx = jax.ops.segment_max(jnp.where(mask[:, None] > 0, eps, neg),
                                 gids, num_segments=num_graphs)
        # padding graph slots have no nodes; zero them instead of -inf/min
        # so the head stays finite (their predictions are masked by `valid`)
        mx = jnp.where(cnt[:, None] > 0, mx, 0.0)
        kappa = jnp.concatenate([s / n[:, None], mx], axis=-1)
    else:
        # sequence reductions (LSTM/Transformer) need per-graph node order;
        # gather the flat buffer into [G, R, D] (R = packed reduce capacity,
        # typically ≪ the dense path's max_nodes × slot padding)
        eps_pad = jnp.concatenate(
            [eps, jnp.zeros((1, eps.shape[-1]), eps.dtype)], axis=0)
        seq = jnp.take(eps_pad, gather_idx, axis=0)                # [G, R, D]
        kappa = R.reduction_apply(params["reduction"], cfg.reduction, seq,
                                  gather_mask,
                                  transformer_heads=cfg.transformer_heads,
                                  rng=rng, dropout_rate=cfg.dropout,
                                  deterministic=deterministic)
    if cfg.kernel_feat_mode == "kernel":
        kappa = jnp.concatenate([kappa, kfeats], axis=-1)
    return dense_apply(params["head"], kappa)[..., 0]


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))
