"""Feature extraction — paper §3.1.

Node features: opcode (categorical, embedded by the model) + scalar features
describing the node: output shape (variable-length → fixed sub-vector + sum +
product, §3.1 "Variable-Sized Features"), rank, dtype size, layout flag,
parameter/output flags, fan-in/fan-out, reduction dims, conv filter size.

Kernel features: tile size (same variable-length encoding; zeros for the
fusion task) + the four optional static performance features (FLOPs, bytes
read, bytes written, transcendental-unit instruction count).

All magnitude features go through log1p before [0,1] min-max scaling; the
normalizer statistics are fit on the training set only (paper footnote 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph

SHAPE_SUBVEC = 6          # fixed sub-vector length for per-dimension features
TILE_SUBVEC = 6


def _subvec(values: Sequence[int], k: int) -> np.ndarray:
    """Encode a variable-length list: pad/truncate to k, append sum, product,
    log1p(product). Product is 'critical' per the paper (tensor volume)."""
    v = np.zeros((k + 3,), np.float64)
    vals = [float(x) for x in values][:k]
    v[:len(vals)] = vals
    arr = np.asarray(list(values), np.float64)
    total = float(arr.sum()) if arr.size else 0.0
    prod = float(arr.prod()) if arr.size else 0.0    # f64: no int overflow
    v[k] = total
    v[k + 1] = prod
    v[k + 2] = np.log1p(prod)
    return v


SHAPE_FEATS = SHAPE_SUBVEC + 3
TILE_FEATS = TILE_SUBVEC + 3

# node scalar features layout:
#   [shape subvec+3 | rank | dtype_bytes | row_major flag | is_param |
#    is_output | fan_in | fan_out | reduced subvec(2)+3 | filter(2)+3 |
#    contract_dim | log1p(flops) | log1p(bytes_out) | elementwise flag |
#    transcendental flag ]
NODE_FEATURE_DIM = SHAPE_FEATS + 7 + (2 + 3) + (2 + 3) + 1 + 2 + 2

# kernel scalar features layout:
#   [tile subvec+3 | 4 static perf features (log1p) | num_nodes | depth]
KERNEL_FEATURE_DIM = TILE_FEATS + 4 + 2
STATIC_PERF_SLICE = slice(TILE_FEATS, TILE_FEATS + 4)
TILE_SLICE = slice(0, TILE_FEATS)


def node_features(g: KernelGraph) -> np.ndarray:
    n_nodes = g.num_nodes
    fan_out = g.fan_out()
    feats = np.zeros((n_nodes, NODE_FEATURE_DIM), np.float64)
    for i, n in enumerate(g.nodes):
        parts = [
            _subvec(n.shape, SHAPE_SUBVEC),
            np.array([
                len(n.shape),
                n.dtype_bytes,
                1.0,                                   # default row-major layout
                1.0 if n.op is opset.PARAMETER else 0.0,
                1.0 if n.is_output else 0.0,
                float(len(n.inputs)),
                float(fan_out[i]),
            ]),
            _subvec(n.reduced_dims, 2),
            _subvec(n.filter_size if n.op is opset.CONV else (), 2),
            np.array([float(n.contract_dim)]),
            np.array([np.log1p(n.flops()), np.log1p(n.bytes_out)]),
            np.array([1.0 if n.op.elementwise else 0.0,
                      1.0 if n.op.transcendental else 0.0]),
        ]
        feats[i] = np.concatenate(parts)
    return feats


def kernel_features(g: KernelGraph, *, include_static_perf: bool = True,
                    include_tile: bool = True) -> np.ndarray:
    tile = g.tile_size if include_tile else ()
    static = np.zeros((4,), np.float64)
    if include_static_perf:
        static = np.array([
            np.log1p(g.total_flops()),
            np.log1p(g.bytes_read()),
            np.log1p(g.bytes_written()),
            np.log1p(g.transcendental_total()),
        ])
    return np.concatenate([
        _subvec(tile, TILE_SUBVEC),
        static,
        np.array([float(g.num_nodes), float(g.depth())]),
    ])


def opcode_ids(g: KernelGraph) -> np.ndarray:
    return np.array([n.op.index for n in g.nodes], np.int32)


def adjacency(g: KernelGraph, n_max: int) -> np.ndarray:
    """Dense directed adjacency: adj[d, s] = 1 iff edge s -> d."""
    a = np.zeros((n_max, n_max), np.float32)
    for s, d in g.edges():
        if s < n_max and d < n_max:
            a[d, s] = 1.0
    return a


# ----------------------------------------------------------------------------
# Normalization (fit on train set only)
# ----------------------------------------------------------------------------
@dataclass
class FeatureNormalizer:
    node_min: np.ndarray
    node_max: np.ndarray
    kernel_min: np.ndarray
    kernel_max: np.ndarray

    @staticmethod
    def fit(node_feats: Sequence[np.ndarray],
            kernel_feats: Sequence[np.ndarray]) -> "FeatureNormalizer":
        nf = np.concatenate([f for f in node_feats], axis=0)
        kf = np.stack(list(kernel_feats), axis=0)
        return FeatureNormalizer(
            node_min=nf.min(axis=0), node_max=nf.max(axis=0),
            kernel_min=kf.min(axis=0), kernel_max=kf.max(axis=0))

    def transform_node(self, f: np.ndarray) -> np.ndarray:
        rng = np.maximum(self.node_max - self.node_min, 1e-9)
        return np.clip((f - self.node_min) / rng, 0.0, 1.0)

    def transform_kernel(self, f: np.ndarray) -> np.ndarray:
        rng = np.maximum(self.kernel_max - self.kernel_min, 1e-9)
        return np.clip((f - self.kernel_min) / rng, 0.0, 1.0)

    def to_dict(self) -> dict:
        return {"node_min": self.node_min.tolist(),
                "node_max": self.node_max.tolist(),
                "kernel_min": self.kernel_min.tolist(),
                "kernel_max": self.kernel_max.tolist()}

    @staticmethod
    def from_dict(d: dict) -> "FeatureNormalizer":
        return FeatureNormalizer(
            np.asarray(d["node_min"]), np.asarray(d["node_max"]),
            np.asarray(d["kernel_min"]), np.asarray(d["kernel_max"]))


# ----------------------------------------------------------------------------
# Batched device encoding
# ----------------------------------------------------------------------------
@dataclass
class GraphBatch:
    """Padded batch pytree. All arrays are numpy here; the trainer moves them
    to device. Registered as a pytree below so it can cross jit boundaries."""
    opcodes: np.ndarray        # [B, N] int32
    node_feats: np.ndarray     # [B, N, F_node] float32
    adj: np.ndarray            # [B, N, N] float32  (adj[b, d, s])
    node_mask: np.ndarray      # [B, N] float32
    kernel_feats: np.ndarray   # [B, F_kernel] float32

    @property
    def batch_size(self) -> int:
        return self.opcodes.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.opcodes.shape[1]


def _graphbatch_flatten(b: GraphBatch):
    return ((b.opcodes, b.node_feats, b.adj, b.node_mask, b.kernel_feats), None)


def _graphbatch_unflatten(_, children):
    return GraphBatch(*children)


import jax.tree_util as _jtu  # noqa: E402

_jtu.register_pytree_node(GraphBatch, _graphbatch_flatten, _graphbatch_unflatten)


def encode_graph(g: KernelGraph, n_max: int,
                 normalizer: FeatureNormalizer | None = None,
                 *, include_static_perf: bool = True) -> dict:
    """Encode one kernel to padded arrays (raw, unnormalized by default)."""
    n = min(g.num_nodes, n_max)
    ops = np.zeros((n_max,), np.int32)
    ops[:n] = opcode_ids(g)[:n]
    nf_raw = node_features(g)[:n]
    kf_raw = kernel_features(g, include_static_perf=include_static_perf)
    if normalizer is not None:
        nf_raw = normalizer.transform_node(nf_raw)
        kf_raw = normalizer.transform_kernel(kf_raw)
    nf = np.zeros((n_max, NODE_FEATURE_DIM), np.float32)
    nf[:n] = nf_raw
    mask = np.zeros((n_max,), np.float32)
    mask[:n] = 1.0
    return {
        "opcodes": ops,
        "node_feats": nf,
        "adj": adjacency(g, n_max),
        "node_mask": mask,
        "kernel_feats": kf_raw.astype(np.float32),
    }


def encode_batch(graphs: Sequence[KernelGraph], n_max: int,
                 normalizer: FeatureNormalizer | None = None,
                 *, include_static_perf: bool = True) -> GraphBatch:
    enc = [encode_graph(g, n_max, normalizer,
                        include_static_perf=include_static_perf)
           for g in graphs]
    return GraphBatch(
        opcodes=np.stack([e["opcodes"] for e in enc]),
        node_feats=np.stack([e["node_feats"] for e in enc]),
        adj=np.stack([e["adj"] for e in enc]),
        node_mask=np.stack([e["node_mask"] for e in enc]),
        kernel_feats=np.stack([e["kernel_feats"] for e in enc]),
    )


def fit_normalizer(graphs: Sequence[KernelGraph],
                   *, include_static_perf: bool = True) -> FeatureNormalizer:
    nfs = [node_features(g) for g in graphs]
    kfs = [kernel_features(g, include_static_perf=include_static_perf)
           for g in graphs]
    return FeatureNormalizer.fit(nfs, kfs)
