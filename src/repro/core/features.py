"""Feature extraction — paper §3.1.

Node features: opcode (categorical, embedded by the model) + scalar features
describing the node: output shape (variable-length → fixed sub-vector + sum +
product, §3.1 "Variable-Sized Features"), rank, dtype size, layout flag,
parameter/output flags, fan-in/fan-out, reduction dims, conv filter size.

Kernel features: tile size (same variable-length encoding; zeros for the
fusion task) + the four optional static performance features (FLOPs, bytes
read, bytes written, transcendental-unit instruction count).

All magnitude features go through log1p before [0,1] min-max scaling; the
normalizer statistics are fit on the training set only (paper footnote 1).
"""
from __future__ import annotations

import os
import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph

SHAPE_SUBVEC = 6          # fixed sub-vector length for per-dimension features
TILE_SUBVEC = 6


def _subvec(values: Sequence[int], k: int) -> np.ndarray:
    """Encode a variable-length list: pad/truncate to k, append sum, product,
    log1p(product). Product is 'critical' per the paper (tensor volume)."""
    v = np.zeros((k + 3,), np.float64)
    vals = [float(x) for x in values][:k]
    v[:len(vals)] = vals
    arr = np.asarray(list(values), np.float64)
    total = float(arr.sum()) if arr.size else 0.0
    prod = float(arr.prod()) if arr.size else 0.0    # f64: no int overflow
    v[k] = total
    v[k + 1] = prod
    v[k + 2] = np.log1p(prod)
    return v


def _subvec_rows(seqs: Sequence[Sequence[int]], k: int) -> np.ndarray:
    """Row-batched `_subvec`: one [len(seqs), k+3] array, no per-row numpy
    allocations. Bit-identical to stacking `_subvec(s, k)` per row (the
    values are small integers, exact in f64 regardless of reduction
    order)."""
    n = len(seqs)
    out = np.zeros((n, k + 3), np.float64)
    if n == 0:
        return out
    lens = np.fromiter((len(s) for s in seqs), np.int64, count=n)
    L = int(lens.max())
    if L == 0:
        return out
    total = int(lens.sum())
    flat = np.fromiter((float(x) for s in seqs for x in s), np.float64,
                       count=total)
    vals = np.zeros((n, L), np.float64)
    row = np.repeat(np.arange(n), lens)
    starts = np.zeros((n,), np.int64)
    np.cumsum(lens[:-1], out=starts[1:])
    col = np.arange(total) - np.repeat(starts, lens)
    vals[row, col] = flat
    out[:, :min(L, k)] = vals[:, :k]
    out[:, k] = vals.sum(axis=1)
    mask = np.arange(L)[None, :] < lens[:, None]
    out[:, k + 1] = np.where(lens > 0,
                             np.where(mask, vals, 1.0).prod(axis=1), 0.0)
    out[:, k + 2] = np.log1p(out[:, k + 1])
    return out


SHAPE_FEATS = SHAPE_SUBVEC + 3
TILE_FEATS = TILE_SUBVEC + 3

# node scalar features layout:
#   [shape subvec+3 | rank | dtype_bytes | row_major flag | is_param |
#    is_output | fan_in | fan_out | reduced subvec(2)+3 | filter(2)+3 |
#    contract_dim | log1p(flops) | log1p(bytes_out) | elementwise flag |
#    transcendental flag ]
NODE_FEATURE_DIM = SHAPE_FEATS + 7 + (2 + 3) + (2 + 3) + 1 + 2 + 2

# kernel scalar features layout:
#   [tile subvec+3 | 4 static perf features (log1p) | num_nodes | depth]
KERNEL_FEATURE_DIM = TILE_FEATS + 4 + 2
STATIC_PERF_SLICE = slice(TILE_FEATS, TILE_FEATS + 4)
TILE_SLICE = slice(0, TILE_FEATS)


def node_features(g: KernelGraph) -> np.ndarray:
    """Per-node scalar features, vectorized over nodes: one Python pass
    collects the scalars, then whole columns are written at once — no
    per-node `np.concatenate`/`np.array` churn. Matches
    `node_features_reference` bit for bit."""
    nodes = g.nodes
    n_nodes = g.num_nodes
    feats = np.empty((n_nodes, NODE_FEATURE_DIM), np.float64)
    feats[:, :SHAPE_FEATS] = _subvec_rows([n.shape for n in nodes],
                                          SHAPE_SUBVEC)
    c = SHAPE_FEATS
    feats[:, c] = [float(len(n.shape)) for n in nodes]          # rank
    feats[:, c + 1] = [float(n.dtype_bytes) for n in nodes]
    feats[:, c + 2] = 1.0                          # default row-major layout
    feats[:, c + 3] = [1.0 if n.op is opset.PARAMETER else 0.0 for n in nodes]
    feats[:, c + 4] = [1.0 if n.is_output else 0.0 for n in nodes]
    feats[:, c + 5] = [float(len(n.inputs)) for n in nodes]
    feats[:, c + 6] = g.fan_out()
    c += 7
    feats[:, c:c + 5] = _subvec_rows([n.reduced_dims for n in nodes], 2)
    c += 5
    feats[:, c:c + 5] = _subvec_rows(
        [n.filter_size if n.op is opset.CONV else () for n in nodes], 2)
    c += 5
    feats[:, c] = [float(n.contract_dim) for n in nodes]
    feats[:, c + 1] = np.log1p([n.flops() for n in nodes])
    feats[:, c + 2] = np.log1p([float(n.bytes_out) for n in nodes])
    feats[:, c + 3] = [1.0 if n.op.elementwise else 0.0 for n in nodes]
    feats[:, c + 4] = [1.0 if n.op.transcendental else 0.0 for n in nodes]
    return feats


def node_features_reference(g: KernelGraph) -> np.ndarray:
    """The original per-node-loop encoder. Kept as the equivalence oracle
    for tests and as the baseline for `benchmarks/bench_input_pipeline.py`
    — not used on any hot path."""
    n_nodes = g.num_nodes
    fan_out = g.fan_out()
    feats = np.zeros((n_nodes, NODE_FEATURE_DIM), np.float64)
    for i, n in enumerate(g.nodes):
        parts = [
            _subvec(n.shape, SHAPE_SUBVEC),
            np.array([
                len(n.shape),
                n.dtype_bytes,
                1.0,                                   # default row-major layout
                1.0 if n.op is opset.PARAMETER else 0.0,
                1.0 if n.is_output else 0.0,
                float(len(n.inputs)),
                float(fan_out[i]),
            ]),
            _subvec(n.reduced_dims, 2),
            _subvec(n.filter_size if n.op is opset.CONV else (), 2),
            np.array([float(n.contract_dim)]),
            np.array([np.log1p(n.flops()), np.log1p(n.bytes_out)]),
            np.array([1.0 if n.op.elementwise else 0.0,
                      1.0 if n.op.transcendental else 0.0]),
        ]
        feats[i] = np.concatenate(parts)
    return feats


def kernel_features(g: KernelGraph, *, include_static_perf: bool = True,
                    include_tile: bool = True) -> np.ndarray:
    tile = g.tile_size if include_tile else ()
    static = np.zeros((4,), np.float64)
    if include_static_perf:
        static = np.array([
            np.log1p(g.total_flops()),
            np.log1p(g.bytes_read()),
            np.log1p(g.bytes_written()),
            np.log1p(g.transcendental_total()),
        ])
    return np.concatenate([
        _subvec(tile, TILE_SUBVEC),
        static,
        np.array([float(g.num_nodes), float(g.depth())]),
    ])


def opcode_ids(g: KernelGraph) -> np.ndarray:
    return np.array([n.op.index for n in g.nodes], np.int32)


def adjacency(g: KernelGraph, n_max: int) -> np.ndarray:
    """Dense directed adjacency: adj[d, s] = 1 iff edge s -> d."""
    a = np.zeros((n_max, n_max), np.float32)
    for s, d in g.edges():
        if s < n_max and d < n_max:
            a[d, s] = 1.0
    return a


# ----------------------------------------------------------------------------
# Normalization (fit on train set only)
# ----------------------------------------------------------------------------
@dataclass
class FeatureNormalizer:
    """Per-feature min-max scaling to [0, 1], statistics fit on the
    training set only (paper footnote 1); out-of-range values clip.

    >>> import numpy as np
    >>> n = FeatureNormalizer(node_min=np.zeros(2), node_max=np.full(2, 2.0),
    ...                       kernel_min=np.zeros(1), kernel_max=np.ones(1))
    >>> n.transform_node(np.array([[1.0, 4.0]])).tolist()
    [[0.5, 1.0]]
    """
    node_min: np.ndarray
    node_max: np.ndarray
    kernel_min: np.ndarray
    kernel_max: np.ndarray

    @staticmethod
    def fit(node_feats: Sequence[np.ndarray],
            kernel_feats: Sequence[np.ndarray]) -> "FeatureNormalizer":
        nf = np.concatenate([f for f in node_feats], axis=0)
        kf = np.stack(list(kernel_feats), axis=0)
        return FeatureNormalizer(
            node_min=nf.min(axis=0), node_max=nf.max(axis=0),
            kernel_min=kf.min(axis=0), kernel_max=kf.max(axis=0))

    def transform_node(self, f: np.ndarray) -> np.ndarray:
        rng = np.maximum(self.node_max - self.node_min, 1e-9)
        return np.clip((f - self.node_min) / rng, 0.0, 1.0)

    def transform_kernel(self, f: np.ndarray) -> np.ndarray:
        rng = np.maximum(self.kernel_max - self.kernel_min, 1e-9)
        return np.clip((f - self.kernel_min) / rng, 0.0, 1.0)

    def to_dict(self) -> dict:
        return {"node_min": self.node_min.tolist(),
                "node_max": self.node_max.tolist(),
                "kernel_min": self.kernel_min.tolist(),
                "kernel_max": self.kernel_max.tolist()}

    @staticmethod
    def from_dict(d: dict) -> "FeatureNormalizer":
        return FeatureNormalizer(
            np.asarray(d["node_min"]), np.asarray(d["node_max"]),
            np.asarray(d["kernel_min"]), np.asarray(d["kernel_max"]))


# ----------------------------------------------------------------------------
# Encode-once structural cache (DESIGN.md §9)
# ----------------------------------------------------------------------------
@dataclass
class EncodedKernel:
    """The tile-independent ("structural") encoding of one kernel, computed
    once and shared by every tile configuration of that kernel.

    Node features, opcode ids, the unique edge list, and the kernel scalar
    features minus the tile sub-vector are all pure functions of the graph
    structure — only `TILE_SLICE` of the kernel features changes with
    `KernelGraph.with_tile`. The cached arrays are read-only; consumers
    copy into their own batch buffers.

    Per-consumer memos hang off the entry so repeated encodes stay cheap:
    a dense adjacency per `n_max`, and the normalized node features for
    the most recent `FeatureNormalizer` (held weakly; normalizers are
    fit once and never mutated — see `FeatureNormalizer.fit`).
    """
    key: bytes                     # structural_digest(order_sensitive=True)
    opcodes: np.ndarray            # [n] int32
    node_feats: np.ndarray         # [n, NODE_FEATURE_DIM] float64, raw
    kernel_feats_base: np.ndarray  # [KERNEL_FEATURE_DIM] f64, TILE_SLICE = 0
    edges: np.ndarray              # [e, 2] int32 unique (src, dst)
    _adj: dict = field(default_factory=dict, init=False, repr=False)
    _norm: tuple | None = field(default=None, init=False, repr=False)

    @property
    def num_nodes(self) -> int:
        return self.opcodes.shape[0]

    def kernel_feats(self, tile: Sequence[int] = (), *,
                     include_static_perf: bool = True) -> np.ndarray:
        """Assemble the per-config kernel feature vector: copy the cached
        structural part and rewrite only `TILE_SLICE` (and zero
        `STATIC_PERF_SLICE` when the ablation asks for it). Bit-identical
        to `kernel_features(g.with_tile(tile), ...)`."""
        kf = self.kernel_feats_base.copy()
        if len(tile):
            kf[TILE_SLICE] = _subvec(tile, TILE_SUBVEC)
        if not include_static_perf:
            kf[STATIC_PERF_SLICE] = 0.0
        return kf

    def normalized_node_feats(self, normalizer: "FeatureNormalizer | None"
                              ) -> np.ndarray:
        """Node features through `normalizer` (raw when None), memoized for
        the last normalizer seen (training/eval/serving each use one)."""
        if normalizer is None:
            return self.node_feats
        memo = self._norm
        if memo is not None and memo[0]() is normalizer:
            return memo[1]
        arr = normalizer.transform_node(self.node_feats)
        arr.setflags(write=False)
        self._norm = (weakref.ref(normalizer), arr)
        return arr

    def dense_adj(self, n_max: int) -> np.ndarray:
        """Dense directed adjacency padded/truncated to `n_max`, memoized
        per width. Same semantics as `adjacency(g, n_max)`."""
        a = self._adj.get(n_max)
        if a is None:
            a = np.zeros((n_max, n_max), np.float32)
            e = self.edges
            if e.size:
                keep = (e[:, 0] < n_max) & (e[:, 1] < n_max)
                a[e[keep, 1], e[keep, 0]] = 1.0
            a.setflags(write=False)
            self._adj[n_max] = a
        return a


def _build_encoded(g: KernelGraph) -> EncodedKernel:
    ops = opcode_ids(g)
    nf = node_features(g)
    kf = kernel_features(g, include_tile=False)
    edges = np.asarray(g.unique_edges(), np.int32).reshape(-1, 2)
    for a in (ops, nf, kf, edges):
        a.setflags(write=False)
    return EncodedKernel(key=g.structural_digest(order_sensitive=True),
                         opcodes=ops, node_feats=nf, kernel_feats_base=kf,
                         edges=edges)


@dataclass(frozen=True)
class EncodeCacheStats:
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class EncodeCache:
    """Bounded, thread-safe LRU of `EncodedKernel` entries keyed by
    `KernelGraph.structural_digest(order_sensitive=True)` — the node-order-
    sensitive structural identity, so every `with_tile` variant of a kernel
    maps to one entry while reordered (even isomorphic) node lists encode
    separately (feature rows follow node order).

    Capacity 0 disables storage (every call encodes fresh). The process-
    wide default cache is sized by the `REPRO_ENCODE_CACHE` env var
    (default 4096 entries); swap it with `set_encode_cache`.

    >>> from repro.core import opset
    >>> from repro.core.graph import KernelGraph, Node
    >>> g = KernelGraph([Node(opset.PARAMETER, (8, 8), is_output=True)])
    >>> c = EncodeCache(4)
    >>> a = c.get_or_encode(g)
    >>> b = c.get_or_encode(g.with_tile((8, 8)))   # tile variant: same entry
    >>> a is b, c.stats().hits, c.stats().misses
    (True, 1, 1)
    >>> bool(np.any(a.kernel_feats((8, 8))[TILE_SLICE]
    ...             != a.kernel_feats(())[TILE_SLICE]))
    True
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._entries: OrderedDict[bytes, EncodedKernel] = OrderedDict()
        self._lock = threading.Lock()
        self._hits = self._misses = self._evictions = 0

    def get_or_encode(self, g: KernelGraph) -> EncodedKernel:
        if self.capacity <= 0:
            with self._lock:
                self._misses += 1
            return _build_encoded(g)
        key = g.structural_digest(order_sensitive=True)
        with self._lock:
            enc = self._entries.get(key)
            if enc is not None:
                self._hits += 1
                self._entries.move_to_end(key)
                return enc
            self._misses += 1
        enc = _build_encoded(g)          # encode outside the lock
        with self._lock:
            racer = self._entries.get(key)
            if racer is not None:        # another thread encoded it first
                return racer
            self._entries[key] = enc
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions += 1
        return enc

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._hits = self._misses = self._evictions = 0

    def stats(self) -> EncodeCacheStats:
        with self._lock:
            return EncodeCacheStats(self._hits, self._misses,
                                    self._evictions, len(self._entries),
                                    self.capacity)


_ENCODE_CACHE = EncodeCache(int(os.environ.get("REPRO_ENCODE_CACHE", "4096")))


def encode_cache() -> EncodeCache:
    """The process-wide structural-encode cache all encoders share."""
    return _ENCODE_CACHE


def set_encode_cache(cache: EncodeCache) -> EncodeCache:
    """Swap the process-wide cache (benchmarks/tests); returns the old one.
    `EncodeCache(0)` effectively disables caching."""
    global _ENCODE_CACHE
    old = _ENCODE_CACHE
    _ENCODE_CACHE = cache
    return old


def encode_structural(g: KernelGraph,
                      cache: EncodeCache | None = None) -> EncodedKernel:
    """The cached tile-independent encoding of `g` (see `EncodedKernel`)."""
    return (cache if cache is not None else _ENCODE_CACHE).get_or_encode(g)


# ----------------------------------------------------------------------------
# Batched device encoding
# ----------------------------------------------------------------------------
@dataclass
class GraphBatch:
    """Padded batch pytree. All arrays are numpy here; the trainer moves them
    to device. Registered as a pytree below so it can cross jit boundaries."""
    opcodes: np.ndarray        # [B, N] int32
    node_feats: np.ndarray     # [B, N, F_node] float32
    adj: np.ndarray            # [B, N, N] float32  (adj[b, d, s])
    node_mask: np.ndarray      # [B, N] float32
    kernel_feats: np.ndarray   # [B, F_kernel] float32

    @property
    def batch_size(self) -> int:
        return self.opcodes.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.opcodes.shape[1]


def _graphbatch_flatten(b: GraphBatch):
    return ((b.opcodes, b.node_feats, b.adj, b.node_mask, b.kernel_feats), None)


def _graphbatch_unflatten(_, children):
    return GraphBatch(*children)


_PYTREES_REGISTERED = False


def register_pytrees() -> None:
    """Register `GraphBatch` / `SparseGraphBatch` as jax pytrees.

    Idempotent, and deliberately NOT a module-import side effect: this
    module is otherwise numpy-only, and its cheap consumers — the socket
    serving client, the replay-stream builder, feature normalizer fitting
    — must not pay the jax import. Every jit consumer reaches batches
    through `repro.core.model`, which calls this at import time.
    """
    global _PYTREES_REGISTERED
    if _PYTREES_REGISTERED:
        return
    import jax.tree_util as jtu
    jtu.register_pytree_node(GraphBatch, _graphbatch_flatten,
                             _graphbatch_unflatten)
    jtu.register_pytree_node(SparseGraphBatch, _sparsebatch_flatten,
                             _sparsebatch_unflatten)
    jtu.register_pytree_node(SegmentedGraphBatch, _segmentedbatch_flatten,
                             _segmentedbatch_unflatten)
    _PYTREES_REGISTERED = True


def encode_graph(g: KernelGraph, n_max: int,
                 normalizer: FeatureNormalizer | None = None,
                 *, include_static_perf: bool = True,
                 cache: EncodeCache | None = None) -> dict:
    """Encode one kernel to padded arrays (raw, unnormalized by default).

    The tile-independent work comes from the structural `EncodeCache`
    (process default unless `cache` is given); per call only the tile
    sub-vector is rewritten and the padded copies made. The returned
    "adj" array is the cache's read-only memo — copy before mutating.
    """
    enc = encode_structural(g, cache)
    n = min(enc.num_nodes, n_max)
    ops = np.zeros((n_max,), np.int32)
    ops[:n] = enc.opcodes[:n]
    nf_raw = enc.normalized_node_feats(normalizer)[:n]
    kf_raw = enc.kernel_feats(g.tile_size,
                              include_static_perf=include_static_perf)
    if normalizer is not None:
        kf_raw = normalizer.transform_kernel(kf_raw)
    nf = np.zeros((n_max, NODE_FEATURE_DIM), np.float32)
    nf[:n] = nf_raw
    mask = np.zeros((n_max,), np.float32)
    mask[:n] = 1.0
    return {
        "opcodes": ops,
        "node_feats": nf,
        "adj": enc.dense_adj(n_max),
        "node_mask": mask,
        "kernel_feats": kf_raw.astype(np.float32),
    }


# ----------------------------------------------------------------------------
# Sparse packed encoding (DESIGN.md §4)
# ----------------------------------------------------------------------------
@dataclass
class SparseGraphBatch:
    """Packed sparse batch: every graph's nodes live in one flat node buffer
    and every edge in one flat edge list, so memory and aggregation cost are
    linear in Σ nodes / Σ edges instead of quadratic in the padded per-graph
    node count (contrast `GraphBatch`; see DESIGN.md §4).

    Padding conventions (all jit-safe, no dynamic shapes):
      * padding nodes: `node_mask == 0`, `graph_ids == 0` — their
        contributions are always multiplied by the mask before segment ops;
      * padding edges: `edge_mask == 0`, endpoints point at node 0;
      * `gather_idx[g, r]` maps (graph slot, node position) to a flat node
        index for the sequence reductions (LSTM/Transformer); padding
        positions hold the sentinel `num_nodes`, resolved against a zero row
        appended at apply time;
      * padding graph slots: `graph_mask == 0` — their predictions are
        garbage by construction and must be dropped via `valid`/`graph_mask`.
    """
    opcodes: np.ndarray        # [M] int32
    node_feats: np.ndarray     # [M, F_node] float32
    node_mask: np.ndarray      # [M] float32
    graph_ids: np.ndarray      # [M] int32 — graph slot per node
    edge_src: np.ndarray       # [E] int32
    edge_dst: np.ndarray       # [E] int32
    edge_mask: np.ndarray      # [E] float32
    kernel_feats: np.ndarray   # [G, F_kernel] float32
    graph_mask: np.ndarray     # [G] float32
    gather_idx: np.ndarray     # [G, R] int32
    gather_mask: np.ndarray    # [G, R] float32

    @property
    def batch_size(self) -> int:       # graph slots (mirrors GraphBatch API)
        return self.kernel_feats.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.opcodes.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edge_src.shape[0]

    @property
    def reduce_capacity(self) -> int:
        return self.gather_idx.shape[1]


def _sparsebatch_flatten(b: SparseGraphBatch):
    return ((b.opcodes, b.node_feats, b.node_mask, b.graph_ids,
             b.edge_src, b.edge_dst, b.edge_mask, b.kernel_feats,
             b.graph_mask, b.gather_idx, b.gather_mask), None)


def _sparsebatch_unflatten(_, children):
    return SparseGraphBatch(*children)


@dataclass
class SegmentedGraphBatch:
    """A batch of whole-program graphs too big for one bucket
    (`repro.data.segmentation`; DESIGN.md §12).

    `inner` is an ordinary `SparseGraphBatch` whose graph slots are the
    *segments* of every member graph (owned nodes plus halo copies), so
    message passing reuses the bucketed sparse path unchanged. After the
    GNN, `scatter_idx` reassembles owned-node embeddings into whole-graph
    node order — halo and padding rows scatter to the dummy slot
    `num_nodes` (one past the outer buffer) and are dropped. The outer
    arrays mirror `SparseGraphBatch`'s readout fields, one slot per
    *original* graph: `kernel_feats` / `gather_idx` / masks describe the
    whole graphs, with the same `gather_idx` sentinel convention
    (`num_nodes` → appended zero row).
    """
    inner: "SparseGraphBatch"
    scatter_idx: np.ndarray    # [M_inner] int32 — outer slot or num_nodes
    node_mask: np.ndarray      # [M_outer] float32
    graph_ids: np.ndarray      # [M_outer] int32
    kernel_feats: np.ndarray   # [G, F_kernel] float32 (whole graphs)
    graph_mask: np.ndarray     # [G] float32
    gather_idx: np.ndarray     # [G, R] int32
    gather_mask: np.ndarray    # [G, R] float32

    @property
    def batch_size(self) -> int:       # original-graph slots
        return self.kernel_feats.shape[0]

    @property
    def num_nodes(self) -> int:        # outer (reassembled) node capacity
        return self.node_mask.shape[0]

    @property
    def reduce_capacity(self) -> int:
        return self.gather_idx.shape[1]


def _segmentedbatch_flatten(b: SegmentedGraphBatch):
    return ((b.inner, b.scatter_idx, b.node_mask, b.graph_ids,
             b.kernel_feats, b.graph_mask, b.gather_idx, b.gather_mask), None)


def _segmentedbatch_unflatten(_, children):
    return SegmentedGraphBatch(*children)


def encode_sparse_batch(graphs: Sequence[KernelGraph],
                        normalizer: FeatureNormalizer | None = None,
                        *, include_static_perf: bool = True,
                        node_capacity: int | None = None,
                        edge_capacity: int | None = None,
                        graph_capacity: int | None = None,
                        reduce_capacity: int | None = None
                        ) -> SparseGraphBatch:
    """Pack `graphs` (in order — slot g holds graphs[g]) into one
    SparseGraphBatch. Capacities default to the exact required sizes; the
    bucketing batcher in `repro.data.batching` passes rounded-up capacities
    so jit compiles one executable per bucket.
    """
    if not graphs:
        raise ValueError("empty graph list")
    n_real = sum(g.num_nodes for g in graphs)
    e_real = sum(len(g.unique_edges()) for g in graphs)
    r_real = max(g.num_nodes for g in graphs)
    M = node_capacity if node_capacity is not None else n_real
    E = max(edge_capacity if edge_capacity is not None else e_real, 1)
    G = graph_capacity if graph_capacity is not None else len(graphs)
    R = reduce_capacity if reduce_capacity is not None else r_real
    if M < n_real:
        raise ValueError(f"node_capacity {M} < total nodes {n_real}")
    if E < e_real:
        raise ValueError(f"edge_capacity {E} < total edges {e_real}")
    if G < len(graphs):
        raise ValueError(f"graph_capacity {G} < num graphs {len(graphs)}")
    if R < r_real:
        raise ValueError(f"reduce_capacity {R} < max graph size {r_real}")

    opcodes = np.zeros((M,), np.int32)
    nf = np.zeros((M, NODE_FEATURE_DIM), np.float32)
    node_mask = np.zeros((M,), np.float32)
    graph_ids = np.zeros((M,), np.int32)
    edge_src = np.zeros((E,), np.int32)
    edge_dst = np.zeros((E,), np.int32)
    edge_mask = np.zeros((E,), np.float32)
    kf = np.zeros((G, KERNEL_FEATURE_DIM), np.float32)
    graph_mask = np.zeros((G,), np.float32)
    gather_idx = np.full((G, R), M, np.int32)      # sentinel = zero row
    gather_mask = np.zeros((G, R), np.float32)

    n_off = e_off = 0
    for gi, g in enumerate(graphs):
        enc = encode_structural(g)
        n = enc.num_nodes
        opcodes[n_off:n_off + n] = enc.opcodes
        kf_raw = enc.kernel_feats(g.tile_size,
                                  include_static_perf=include_static_perf)
        if normalizer is not None:
            kf_raw = normalizer.transform_kernel(kf_raw)
        nf[n_off:n_off + n] = enc.normalized_node_feats(normalizer)
        node_mask[n_off:n_off + n] = 1.0
        graph_ids[n_off:n_off + n] = gi
        kf[gi] = kf_raw
        graph_mask[gi] = 1.0
        gather_idx[gi, :n] = np.arange(n_off, n_off + n, dtype=np.int32)
        gather_mask[gi, :n] = 1.0
        arr = enc.edges
        if arr.size:
            k = arr.shape[0]
            edge_src[e_off:e_off + k] = arr[:, 0] + n_off
            edge_dst[e_off:e_off + k] = arr[:, 1] + n_off
            edge_mask[e_off:e_off + k] = 1.0
            e_off += k
        n_off += n
    return SparseGraphBatch(opcodes, nf, node_mask, graph_ids,
                            edge_src, edge_dst, edge_mask, kf, graph_mask,
                            gather_idx, gather_mask)


def encode_batch(graphs: Sequence[KernelGraph], n_max: int,
                 normalizer: FeatureNormalizer | None = None,
                 *, include_static_perf: bool = True) -> GraphBatch:
    enc = [encode_graph(g, n_max, normalizer,
                        include_static_perf=include_static_perf)
           for g in graphs]
    return GraphBatch(
        opcodes=np.stack([e["opcodes"] for e in enc]),
        node_feats=np.stack([e["node_feats"] for e in enc]),
        adj=np.stack([e["adj"] for e in enc]),
        node_mask=np.stack([e["node_mask"] for e in enc]),
        kernel_feats=np.stack([e["kernel_feats"] for e in enc]),
    )


def fit_normalizer(graphs: Sequence[KernelGraph],
                   *, include_static_perf: bool = True) -> FeatureNormalizer:
    nfs = [node_features(g) for g in graphs]
    kfs = [kernel_features(g, include_static_perf=include_static_perf)
           for g in graphs]
    return FeatureNormalizer.fit(nfs, kfs)
