"""Batched evaluation of cost models against the measurement oracle —
produces the paper's Table-2/8 style per-program metrics."""
from __future__ import annotations


import jax
import numpy as np

from repro.core import features as F
from repro.core.analytical import AnalyticalModel, predict_scaled
from repro.core.metrics import (
    kendall_tau,
    mape,
    program_kendall,
    tile_size_ape,
)
from repro.core.model import CostModelConfig, cost_model_apply


def make_predict_fn(model_cfg: CostModelConfig):
    @jax.jit
    def predict(params, batch):
        return cost_model_apply(params, model_cfg, batch, deterministic=True)
    return predict


def predict_kernels(params, model_cfg: CostModelConfig, graphs, normalizer,
                    *, max_nodes: int = 64, chunk: int = 128,
                    predict_fn=None, adjacency: str | None = None,
                    node_budget: int | None = None) -> np.ndarray:
    """Predict scores for a list of KernelGraphs (batched inference).

    dense     — fixed-size chunks padded to `chunk` graphs × `max_nodes`
                nodes, so every call hits one compiled shape.
    sparse    — kernels packed into flat buffers of ≤ `node_budget` total
                nodes (default 8 × max_nodes) with pow2-bucketed
                capacities, so an arbitrary corpus runs through a handful
                of compiled shapes and small kernels never pay big
                kernels' padding. Kernels beyond the budget still score
                (oversized singleton packs).
    segmented — whole-program graphs of any size: each graph segmented
                into ≤ `node_budget` blocks (default 8 × max_nodes) and
                reassembled before readout (DESIGN.md §12); chunks of
                `chunk` graphs per device batch.

    `adjacency` defaults to `model_cfg.adjacency`.

    This is the *direct* path — no prediction cache; high-traffic clients
    should go through `repro.serving.CostModelService`, which adds the
    content-addressed cache and request coalescing on top of the same
    encoders (docs/SERVING.md). Encoding itself still rides the shared
    `features.EncodeCache` (DESIGN.md §9): a tile sweep over one kernel
    pays the structural encode once (plus a tile-slice rewrite per
    config), and the dense path's pad slots (`[part[-1]] * pad`) are
    cache hits instead of fresh encodes.
    """
    if adjacency is None:
        adjacency = model_cfg.adjacency
    predict = predict_fn or make_predict_fn(model_cfg)
    if not len(graphs):
        return np.zeros((0,), np.float32)
    if adjacency == "sparse":
        from repro.data.batching import iter_packed_batches
        budget = node_budget or 8 * max_nodes
        out = np.zeros((len(graphs),), np.float32)
        for enc, idx in iter_packed_batches(graphs, budget, normalizer):
            preds = np.asarray(predict(params, enc))
            out[idx] = preds[:len(idx)]
        return out
    if adjacency == "segmented":
        from repro.data.batching import encode_segmented
        budget = node_budget or 8 * max_nodes
        out = []
        for i in range(0, len(graphs), chunk):
            part = graphs[i:i + chunk]
            enc = encode_segmented(part, budget, normalizer)
            preds = np.asarray(predict(params, enc))
            out.append(preds[:len(part)])
        return np.concatenate(out) if out else np.zeros((0,), np.float32)
    out = []
    for i in range(0, len(graphs), chunk):
        part = graphs[i:i + chunk]
        pad = chunk - len(part)
        enc = F.encode_batch(part + [part[-1]] * pad, max_nodes, normalizer)
        preds = np.asarray(predict(params, enc))
        out.append(preds[:len(part)])
    return np.concatenate(out) if out else np.zeros((0,), np.float32)


# ----------------------------------------------------------------------------
# Tile-size task (Table 2 left): Tile-Size APE + per-kernel Kendall τ
# ----------------------------------------------------------------------------
def eval_tile_program(records, scorer) -> dict:
    """records: TileKernelRecords of ONE program.
    scorer(kernel, tiles) -> predicted scores (lower = faster)."""
    per_kernel = []
    for r in records:
        pred = scorer(r.kernel, r.tiles)
        per_kernel.append({"true": r.runtimes, "pred": pred})
    return {
        "ape": tile_size_ape(per_kernel),
        "kendall": program_kendall(per_kernel),
    }


def learned_tile_scorer(params, model_cfg, normalizer, *, max_nodes=64,
                        chunk=128, adjacency=None, node_budget=None,
                        service=None, cache_capacity=65536):
    """Tile scorer backed by a `repro.search.LearnedEstimator` (and so by
    a `repro.serving.CostModelService`): every (kernel, tile) query goes
    through the content-addressed prediction cache + coalescer, so
    revisited candidates (top-k re-ranks, repeated eval sweeps) are scored
    once. Pass an existing `service` to share its cache across scorers;
    otherwise one is built from these arguments (`cache_capacity=0` falls
    back to direct uncached scoring)."""
    from repro.search import LearnedEstimator
    est = LearnedEstimator.from_params(params, model_cfg, normalizer,
                                       max_nodes=max_nodes, chunk=chunk,
                                       adjacency=adjacency,
                                       node_budget=node_budget,
                                       service=service,
                                       cache_capacity=cache_capacity)
    return est.tile_scorer()


def analytical_tile_scorer(model: AnalyticalModel):
    def scorer(kernel, tiles):
        return np.array([model.predict(kernel, t) for t in tiles])
    return scorer


def eval_tile_task(dataset, scorer) -> dict:
    """Returns per-program metrics + median/mean summary (Table 2 style)."""
    per_prog = {}
    for prog, recs in dataset.by_program().items():
        per_prog[prog] = eval_tile_program(recs, scorer)
    apes = [m["ape"] for m in per_prog.values()]
    taus = [m["kendall"] for m in per_prog.values()]
    return {
        "per_program": per_prog,
        "median_ape": float(np.median(apes)) if apes else float("nan"),
        "mean_ape": float(np.mean(apes)) if apes else float("nan"),
        "median_kendall": float(np.median(taus)) if taus else float("nan"),
        "mean_kendall": float(np.mean(taus)) if taus else float("nan"),
    }


# ----------------------------------------------------------------------------
# Fusion task (Table 2 right): MAPE + Kendall τ on absolute runtimes
# ----------------------------------------------------------------------------
def eval_fusion_task(dataset, predict_runtimes, *,
                     min_runtime: float = 0.0) -> dict:
    """predict_runtimes(kernels) -> seconds. Kernels filtered to
    runtime >= min_runtime (the paper reports ≥5µs separately)."""
    per_prog = {}
    for prog, recs in dataset.by_program().items():
        recs = [r for r in recs if r.runtime >= min_runtime]
        if not recs:
            continue
        true = np.array([r.runtime for r in recs])
        pred = predict_runtimes([r.kernel for r in recs])
        per_prog[prog] = {
            "mape": mape(pred, true),
            "kendall": kendall_tau(pred, true),
            "n": len(recs),
        }
    mapes = [m["mape"] for m in per_prog.values()]
    taus = [m["kendall"] for m in per_prog.values()]
    return {
        "per_program": per_prog,
        "median_mape": float(np.median(mapes)) if mapes else float("nan"),
        "mean_mape": float(np.mean(mapes)) if mapes else float("nan"),
        "median_kendall": float(np.median(taus)) if taus else float("nan"),
        "mean_kendall": float(np.mean(taus)) if taus else float("nan"),
    }


def learned_runtime_predictor(params, model_cfg, normalizer, *,
                              max_nodes=64, chunk=128, adjacency=None,
                              node_budget=None, service=None,
                              cache_capacity=65536):
    """Fusion-task model predicts log-runtime; exponentiate. Scores
    through a `repro.search.LearnedEstimator` (see `learned_tile_scorer`
    for the `service`/`cache_capacity` contract)."""
    from repro.search import LearnedEstimator
    est = LearnedEstimator.from_params(params, model_cfg, normalizer,
                                       max_nodes=max_nodes, chunk=chunk,
                                       adjacency=adjacency,
                                       node_budget=node_budget,
                                       service=service,
                                       cache_capacity=cache_capacity)
    return est.runtime_predictor()


def analytical_runtime_predictor(model: AnalyticalModel, coeffs: dict):
    def predict_runtimes(kernels):
        return np.array([predict_scaled(model, coeffs, k) for k in kernels])
    return predict_runtimes
