"""Node-embedding → kernel-embedding reductions (paper §3.2).

Four options, all mask-aware:
  * per-node:     scalar head per node, summed (no kernel embedding)
  * column-wise:  concat(masked mean, masked max) — Table 5's fixed choice
  * LSTM:         final state over topologically sorted node embeddings
  * Transformer:  encoder over node embeddings, sum-reduced (Table 5)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.lstm import lstm_apply, lstm_init
from repro.nn.transformer import encoder_apply, encoder_init

REDUCTIONS = ("per_node", "column_wise", "lstm", "transformer")


def reduction_init(rng, kind: str, dim: int, *, transformer_layers: int = 1,
                   transformer_heads: int = 4, dtype=jnp.float32) -> dict:
    if kind == "per_node":
        return {}
    if kind == "column_wise":
        return {}
    if kind == "lstm":
        return {"lstm": lstm_init(rng, dim, dim, dtype)}
    if kind == "transformer":
        return {"encoder": encoder_init(rng, dim, transformer_heads,
                                        transformer_layers, dtype=dtype)}
    raise ValueError(f"unknown reduction {kind!r}")


def reduction_out_dim(kind: str, dim: int) -> int:
    if kind == "column_wise":
        return 2 * dim
    if kind == "per_node":
        return 0      # per-node predicts directly; no kernel embedding
    return dim


def masked_mean(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    s = jnp.sum(x * mask[..., None], axis=1)
    n = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / n


def masked_max(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    neg = jnp.finfo(x.dtype).min
    xm = jnp.where(mask[..., None] > 0, x, neg)
    return jnp.max(xm, axis=1)


def reduction_apply(params: dict, kind: str, eps: jnp.ndarray,
                    node_mask: jnp.ndarray, *, transformer_heads: int = 4,
                    rng=None, dropout_rate: float = 0.0,
                    deterministic: bool = True) -> jnp.ndarray:
    """eps: [B, N, D] -> kernel embedding [B, out_dim].

    per_node is handled in model.py (it never builds a kernel embedding).
    """
    if kind == "column_wise":
        return jnp.concatenate(
            [masked_mean(eps, node_mask), masked_max(eps, node_mask)], axis=-1)
    if kind == "lstm":
        return lstm_apply(params["lstm"], eps, node_mask)
    if kind == "transformer":
        enc = encoder_apply(params["encoder"], eps, node_mask,
                            transformer_heads, rng=rng,
                            dropout_rate=dropout_rate,
                            deterministic=deterministic)
        return jnp.sum(enc * node_mask[..., None], axis=1)   # Table 5: sum
    raise ValueError(f"unknown reduction {kind!r}")
