"""Ground-truth "hardware": a TPU-v5e-flavored kernel timing simulator.

This container is CPU-only, so real TPU measurement is a hardware gate; per
the task instructions we simulate it. The simulator is the *measurement
oracle* for the whole repo: datasets are labeled with it, the autotuner's
"run on real hardware" steps call it, and the learned model is evaluated
against it.

It deliberately models second-order effects the analytical baseline
(`repro.core.analytical`, mirroring the paper's Appendix A) does not:

* MXU/VPU tile-alignment utilization (multiples of 128 / 8),
* a smooth DMA bandwidth ramp (small transfers get a fraction of peak),
* per-kernel launch overhead and pipeline fill/drain,
* an instruction-scheduling (ILP) factor from graph depth vs. width and a
  register-pressure penalty from fan-out,
* a separate, slower transcendental unit,
* seeded lognormal measurement noise (targets = min of 3 runs, like §4).

Constants match the roofline constants used in EXPERIMENTS.md.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e-sim"
    peak_mxu_flops: float = 197e12     # bf16; f32 contracts at half rate
    peak_vpu_flops: float = 4.9e12     # 8x128 lanes * ~4.8 GHz-equivalent
    trans_flops: float = 0.6e12        # transcendental unit
    hbm_bw: float = 819e9              # bytes/s
    dma_latency: float = 1.2e-6        # seconds; drives the bandwidth ramp
    vmem_bytes: int = 128 * 1024 * 1024
    vmem_usable_frac: float = 0.75     # compiler reservations
    launch_overhead: float = 2.0e-6    # per-kernel dispatch
    tile_setup: float = 0.15e-6        # per-tile sequencing bubble
    ici_bw: float = 50e9               # per link, used by roofline elsewhere


V5E = HardwareSpec()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // max(b, 1))


def _round_up(a: int, q: int) -> int:
    return _ceil_div(a, q) * q


def _tile_clamped(tile: tuple[int, ...], shape: tuple[int, ...]) -> tuple[int, ...]:
    if not tile:
        tile = shape
    if len(tile) != len(shape):
        # pad/truncate defensively (importer guarantees match normally)
        tile = tuple(tile[:len(shape)]) + shape[len(tile):]
    return tuple(min(max(int(t), 1), int(d)) for t, d in zip(tile, shape))


def default_tile(shape: tuple[int, ...], hw: HardwareSpec = V5E) -> tuple[int, ...]:
    """A plausible compiler-default tile: full shape clipped to ~1/8 VMEM."""
    if not shape:
        return ()
    budget = hw.vmem_bytes * hw.vmem_usable_frac / 8
    tile = [int(d) for d in shape]
    # shrink the major-most dims first, like a row-major tiler would
    i = 0
    def vol(t):
        v = 4
        for x in t:
            v *= x
        return v
    while vol(tile) > budget and i < len(tile):
        while tile[i] > 1 and vol(tile) > budget:
            tile[i] = max(tile[i] // 2, 1)
        i += 1
    return tuple(tile)


@dataclass
class TileStats:
    """Per-tile-iteration statistics shared by simulator & analytical model."""
    num_tiles: int
    tile_frac: float
    bytes_in_per_tile: float
    bytes_out_per_tile: float
    vmem_per_tile: float
    mxu_flops_per_tile: float
    vpu_flops_per_tile: float
    trans_per_tile: float
    tile: tuple[int, ...]


def tile_stats(g: KernelGraph, tile: tuple[int, ...] | None = None,
               hw: HardwareSpec = V5E) -> TileStats:
    root = g.root
    shape = root.shape if root.shape else (1,)
    t = _tile_clamped(tile if tile is not None else g.tile_size, shape)
    num_tiles = 1
    for d, ts in zip(shape, t):
        num_tiles *= _ceil_div(int(d), ts)
    tile_vol = 1
    for ts in t:
        tile_vol *= ts
    root_vol = max(root.volume, 1)
    frac = min(tile_vol / root_vol, 1.0)

    # --- data movement per tile ------------------------------------------
    bytes_in = 0.0
    vmem_in = 0.0
    for p in g.nodes:
        if p.op not in (opset.PARAMETER, opset.CONSTANT):
            continue
        pb = float(p.bytes_out)
        if p.volume >= root_vol:                      # streamed activation
            per = pb * frac
        elif p.volume * 64 >= root_vol:               # sizable weight operand
            per = pb * math.sqrt(frac)                # re-read across tiles
        else:                                         # small constants
            per = pb
        bytes_in += per
        vmem_in += per
    bytes_out = 0.0
    for o in g.output_nodes:
        bytes_out += float(o.bytes_out) * frac
    # intermediates live tile-granular in scratchpad
    vmem_mid = 0.0
    for n in g.nodes:
        if n.op in (opset.PARAMETER, opset.CONSTANT):
            continue
        vmem_mid += float(n.bytes_out) * frac
    vmem = 2.0 * (vmem_in + bytes_out) + vmem_mid     # double buffering

    # --- compute per tile ---------------------------------------------------
    mxu = vpu = trans = 0.0
    for n in g.nodes:
        f = n.flops() * frac
        if n.op.unit == "mxu":
            mxu += f
        elif n.op.unit == "special":
            vpu += f
            trans += n.transcendental_count() * frac
        elif n.op.unit == "vpu":
            vpu += f
    return TileStats(num_tiles=int(num_tiles), tile_frac=frac,
                     bytes_in_per_tile=bytes_in, bytes_out_per_tile=bytes_out,
                     vmem_per_tile=vmem, mxu_flops_per_tile=mxu,
                     vpu_flops_per_tile=vpu, trans_per_tile=trans, tile=t)


def tile_fits_vmem(g: KernelGraph, tile: tuple[int, ...],
                   hw: HardwareSpec = V5E) -> bool:
    st = tile_stats(g, tile, hw)
    return st.vmem_per_tile <= hw.vmem_bytes * hw.vmem_usable_frac


def _util_dim(t: int, q: int) -> float:
    return t / _round_up(max(t, 1), q)


def _mxu_util(tile: tuple[int, ...]) -> float:
    last = tile[-1] if tile else 1
    second = tile[-2] if len(tile) >= 2 else 1
    return _util_dim(last, 128) * _util_dim(second, 8)


def _vpu_util(tile: tuple[int, ...]) -> float:
    last = tile[-1] if tile else 1
    return 0.4 + 0.6 * _util_dim(last, 128)


class TPUSimulator:
    """The 'real hardware'. `measure()` = run on the accelerator."""

    def __init__(self, hw: HardwareSpec = V5E, noise_sigma: float = 0.025,
                 seed: int = 0):
        self.hw = hw
        self.noise_sigma = noise_sigma
        self.seed = seed

    # ------------------------------------------------------------------
    def _ilp_factor(self, g: KernelGraph) -> float:
        n = max(g.num_nodes, 1)
        depth = g.depth()
        serial = 1.0 + 0.18 * max(depth - 1, 0) / n
        fo = g.fan_out()
        max_fo = int(fo.max(initial=0))
        reg = 1.0 + min(0.035 * max(max_fo - 6, 0), 0.5)
        return serial * reg

    def _dma_eff(self, nbytes: float) -> float:
        """Fraction of peak bandwidth achieved for a transfer of nbytes."""
        if nbytes <= 0:
            return 1.0
        ramp = nbytes / (nbytes + self.hw.hbm_bw * self.hw.dma_latency)
        return max(ramp, 0.02)

    def _dtype_rate_scale(self, g: KernelGraph) -> float:
        """f32 contractions run the MXU at half bf16 rate."""
        root = g.root
        for n in g.nodes:
            if n.op.unit == "mxu":
                return 1.0 if n.dtype_bytes <= 2 else 0.5
        return 1.0 if root.dtype_bytes <= 2 else 0.5

    def ideal_time(self, g: KernelGraph, tile: tuple[int, ...] | None = None) -> float:
        """Noise-free modeled runtime in seconds."""
        hw = self.hw
        st = tile_stats(g, tile, hw)
        if st.vmem_per_tile > hw.vmem_bytes * hw.vmem_usable_frac:
            # the compiler would reject this tile; an autotuner that forces it
            # sees a spilled, very slow execution
            spill = st.vmem_per_tile / (hw.vmem_bytes * hw.vmem_usable_frac)
            spill_penalty = 4.0 * spill
        else:
            spill_penalty = 1.0

        mxu_rate = hw.peak_mxu_flops * self._dtype_rate_scale(g)
        mxu_t = st.mxu_flops_per_tile / (mxu_rate * max(_mxu_util(st.tile), 1e-3))
        vpu_t = st.vpu_flops_per_tile / (hw.peak_vpu_flops * _vpu_util(st.tile))
        trans_t = st.trans_per_tile / hw.trans_flops
        compute_t = (mxu_t + vpu_t + trans_t) * self._ilp_factor(g)

        bytes_tile = st.bytes_in_per_tile + st.bytes_out_per_tile
        mem_t = bytes_tile / (hw.hbm_bw * self._dma_eff(bytes_tile))

        steady = max(compute_t, mem_t) + hw.tile_setup
        fill = st.bytes_in_per_tile / (hw.hbm_bw * self._dma_eff(st.bytes_in_per_tile))
        drain = st.bytes_out_per_tile / (hw.hbm_bw * self._dma_eff(st.bytes_out_per_tile))
        total = hw.launch_overhead + fill + drain + st.num_tiles * steady
        return total * spill_penalty

    # ------------------------------------------------------------------
    def _noise(self, g: KernelGraph, tile, run: int) -> float:
        key = f"{g.program}|{g.name}|{tuple(tile) if tile else g.tile_size}|{run}|{self.seed}"
        h = zlib.crc32(key.encode())
        rng = np.random.default_rng(h)
        return float(np.exp(rng.normal(0.0, self.noise_sigma)))

    def measure(self, g: KernelGraph, tile: tuple[int, ...] | None = None,
                runs: int = 3) -> float:
        """Measured runtime: min over `runs` noisy executions (paper §4)."""
        base = self.ideal_time(g, tile)
        return min(base * self._noise(g, tile, r) for r in range(max(runs, 1)))

    def measure_program(self, kernels, runs: int = 3) -> float:
        return float(sum(self.measure(k, runs=runs) for k in kernels))
