"""Evaluation metrics (paper §5).

* Kendall's τ rank correlation (within-kernel, averaged per program).
* MAPE — fusion task absolute-runtime accuracy.
* Tile-Size APE (Eq. 2) — how far the chosen-per-kernel tiles put the whole
  program from its per-kernel-optimal runtime.
"""
from __future__ import annotations

import numpy as np


def kendall_tau(preds, targets) -> float:
    """O(n²) Kendall tau-a; n per kernel is small (≤ hundreds here)."""
    p = np.asarray(preds, np.float64)
    t = np.asarray(targets, np.float64)
    n = len(p)
    if n < 2:
        return 0.0
    dp = np.sign(p[:, None] - p[None, :])
    dt = np.sign(t[:, None] - t[None, :])
    iu = np.triu_indices(n, k=1)
    concordant = np.sum(dp[iu] * dt[iu])
    total = n * (n - 1) / 2.0
    return float(concordant / total)


def mape(preds, targets, *, eps: float = 1e-12) -> float:
    p = np.asarray(preds, np.float64)
    t = np.asarray(targets, np.float64)
    return float(100.0 * np.mean(np.abs(p - t) / np.maximum(np.abs(t), eps)))


def tile_size_ape(per_kernel: list[dict]) -> float:
    """Eq. 2. per_kernel: [{'true': [runtime per config],
                            'pred': [score per config]}, ...] for one program.

    For each kernel pick argmin of predictions, compare its *true* runtime to
    the true optimum; normalize by the all-optimal program runtime.
    """
    num = 0.0
    den = 0.0
    for k in per_kernel:
        true = np.asarray(k["true"], np.float64)
        pred = np.asarray(k["pred"], np.float64)
        if len(true) == 0:
            continue
        chosen = float(true[int(np.argmin(pred))])
        best = float(true.min())
        num += abs(chosen - best)
        den += best
    return float(100.0 * num / max(den, 1e-30))


def program_kendall(per_kernel: list[dict]) -> float:
    """Mean within-kernel Kendall τ between predictions and targets."""
    taus = []
    for k in per_kernel:
        if len(k["true"]) >= 2:
            # τ between predicted and true runtimes (both ascending = good)
            taus.append(kendall_tau(k["pred"], k["true"]))
    return float(np.mean(taus)) if taus else 0.0


def geometric_mean(xs) -> float:
    xs = np.asarray(xs, np.float64)
    xs = np.maximum(xs, 1e-12)
    return float(np.exp(np.mean(np.log(xs))))
