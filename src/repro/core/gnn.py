"""Graph neural networks over batched kernel graphs (paper §3.2).

GraphSAGE (the paper's choice) and GAT (the ablation alternative), both
direction-aware: incoming and outgoing edges aggregate through separate
feedforward modules ('Undirected' ablation shares them).

Aggregation is a dense masked-adjacency matmul — `adj[b, d, s] @ h[b, s, :]`
— which is the TPU-native formulation (MXU-friendly; see DESIGN.md §3).
`repro.kernels.graph_aggregate` provides the fused Pallas version; this file
is the jnp reference path used for training on CPU and as the kernel oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import (
    dense_apply,
    dense_init,
    l2_normalize,
)


# ----------------------------------------------------------------------------
# GraphSAGE
# ----------------------------------------------------------------------------
def sage_layer_init(rng, dim: int, *, directed: bool, dtype=jnp.float32) -> dict:
    k_in, k_out, k3 = jax.random.split(rng, 3)
    params = {
        "f2_in": dense_init(k_in, dim, dim, bias=False, dtype=dtype),
        # concat(self, agg_in[, agg_out]) -> dim
        "f3": dense_init(k3, dim * (3 if directed else 2), dim, bias=False,
                         dtype=dtype),
    }
    if directed:
        params["f2_out"] = dense_init(k_out, dim, dim, bias=False, dtype=dtype)
    return params


def _aggregate(adj: jnp.ndarray, h: jnp.ndarray, node_mask: jnp.ndarray,
               aggregator: str) -> jnp.ndarray:
    """adj: [B,N,N] (adj[b,d,s]); h: [B,N,D]; returns [B,N,D] per-dst agg."""
    h = h * node_mask[..., None]
    agg = jnp.einsum("bds,bsh->bdh", adj, h)
    if aggregator == "mean":
        deg = jnp.sum(adj, axis=-1, keepdims=True)
        agg = agg / jnp.maximum(deg, 1.0)
    return agg


def sage_layer_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
                     node_mask: jnp.ndarray, *, aggregator: str = "mean",
                     directed: bool = True,
                     use_pallas: bool = False) -> jnp.ndarray:
    """One GraphSAGE hop:
    eps_i^k = l2( f3( concat(eps_i, Σ_{j∈in(i)} f2_in(eps_j)
                              [, Σ_{j∈out(i)} f2_out(eps_j)]) ) )

    use_pallas=True routes the transform+aggregate through the fused
    repro.kernels.graph_aggregate kernel (beyond-paper optimization —
    interpret-mode on CPU, real VMEM fusion on TPU).
    """
    if use_pallas:
        from repro.kernels.graph_aggregate.ops import graph_aggregate
        import jax as _jax
        interp = _jax.default_backend() == "cpu"
        mean = aggregator == "mean"
        agg_in = graph_aggregate(adj, eps, params["f2_in"]["w"],
                                 act="relu", mean=mean, interpret=interp)
        parts = [eps, agg_in]
        if directed:
            adj_t = jnp.swapaxes(adj, -1, -2)
            parts.append(graph_aggregate(adj_t, eps, params["f2_out"]["w"],
                                         act="relu", mean=mean,
                                         interpret=interp))
        else:
            adj_t = jnp.swapaxes(adj, -1, -2)
            agg_out = graph_aggregate(adj_t, eps, params["f2_in"]["w"],
                                      act="relu", mean=mean,
                                      interpret=interp)
            parts[1] = 0.5 * (agg_in + agg_out)
        h = dense_apply(params["f3"], jnp.concatenate(parts, axis=-1))
        h = jax.nn.relu(h)
        return l2_normalize(h, axis=-1) * node_mask[..., None]

    msg_in = jax.nn.relu(dense_apply(params["f2_in"], eps))
    agg_in = _aggregate(adj, msg_in, node_mask, aggregator)
    parts = [eps, agg_in]
    if directed:
        msg_out = jax.nn.relu(dense_apply(params["f2_out"], eps))
        # outgoing edges: transpose the adjacency
        agg_out = _aggregate(jnp.swapaxes(adj, -1, -2), msg_out, node_mask,
                             aggregator)
        parts.append(agg_out)
    else:
        # undirected ablation: same module, symmetrized adjacency
        agg_out = _aggregate(jnp.swapaxes(adj, -1, -2), msg_in, node_mask,
                             aggregator)
        parts[1] = 0.5 * (agg_in + agg_out)
    h = dense_apply(params["f3"], jnp.concatenate(parts, axis=-1))
    h = jax.nn.relu(h)
    return l2_normalize(h, axis=-1) * node_mask[..., None]


def sage_init(rng, dim: int, num_layers: int, *, directed: bool = True,
              dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, max(num_layers, 1))
    return {"layers": [sage_layer_init(keys[i], dim, directed=directed,
                                       dtype=dtype)
                       for i in range(num_layers)]}


def sage_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
               node_mask: jnp.ndarray, *, aggregator: str = "mean",
               directed: bool = True, use_pallas: bool = False) -> jnp.ndarray:
    for layer in params["layers"]:
        eps = sage_layer_apply(layer, eps, adj, node_mask,
                               aggregator=aggregator, directed=directed,
                               use_pallas=use_pallas)
    return eps


# ----------------------------------------------------------------------------
# GAT
# ----------------------------------------------------------------------------
def gat_layer_init(rng, dim: int, num_heads: int, *, directed: bool,
                   dtype=jnp.float32) -> dict:
    assert dim % num_heads == 0
    hd = dim // num_heads
    ks = jax.random.split(rng, 6)
    params = {
        "w_in": dense_init(ks[0], dim, dim, bias=False, dtype=dtype),
        "a_src_in": jax.random.normal(ks[1], (num_heads, hd), dtype) * 0.1,
        "a_dst_in": jax.random.normal(ks[2], (num_heads, hd), dtype) * 0.1,
        "proj": dense_init(ks[3], dim * (2 if directed else 1), dim,
                           bias=False, dtype=dtype),
    }
    if directed:
        params["w_out"] = dense_init(ks[4], dim, dim, bias=False, dtype=dtype)
        params["a_src_out"] = jax.random.normal(ks[5], (num_heads, hd),
                                                dtype) * 0.1
        # independent copy — an aliased leaf would be donated twice
        params["a_dst_out"] = params["a_dst_in"] + 0.0
    return params


def _gat_attend(h: jnp.ndarray, adj: jnp.ndarray, a_src: jnp.ndarray,
                a_dst: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """Masked multi-head attention aggregation over in-edges of `adj`."""
    B, N, D = h.shape
    hd = D // num_heads
    hh = h.reshape(B, N, num_heads, hd)
    e_src = jnp.einsum("bnhd,hd->bnh", hh, a_src)   # score contribution of src
    e_dst = jnp.einsum("bnhd,hd->bnh", hh, a_dst)
    # logits[b, h, d, s] = leaky_relu(e_dst[d] + e_src[s])
    logits = jax.nn.leaky_relu(
        e_dst.transpose(0, 2, 1)[:, :, :, None] +
        e_src.transpose(0, 2, 1)[:, :, None, :], 0.2)
    neg = jnp.finfo(logits.dtype).min
    mask = adj[:, None, :, :] > 0
    logits = jnp.where(mask, logits, neg)
    alpha = jax.nn.softmax(logits, axis=-1)
    # rows with no in-edges get a uniform softmax over masked -inf -> nan-free
    alpha = jnp.where(jnp.any(mask, axis=-1, keepdims=True), alpha, 0.0)
    out = jnp.einsum("bhds,bshx->bdhx", alpha, hh)
    return out.reshape(B, N, D)


def gat_layer_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
                    node_mask: jnp.ndarray, *, num_heads: int,
                    directed: bool = True) -> jnp.ndarray:
    h_in = dense_apply(params["w_in"], eps)
    agg_in = _gat_attend(h_in, adj, params["a_src_in"], params["a_dst_in"],
                         num_heads)
    if directed:
        h_out = dense_apply(params["w_out"], eps)
        agg_out = _gat_attend(h_out, jnp.swapaxes(adj, -1, -2),
                              params["a_src_out"], params["a_dst_out"],
                              num_heads)
        agg = jnp.concatenate([agg_in, agg_out], axis=-1)
    else:
        sym = jnp.maximum(adj, jnp.swapaxes(adj, -1, -2))
        agg = _gat_attend(h_in, sym, params["a_src_in"], params["a_dst_in"],
                          num_heads)
    h = dense_apply(params["proj"], agg)
    h = jax.nn.elu(h) + eps          # residual keeps training stable
    return h * node_mask[..., None]


def gat_init(rng, dim: int, num_layers: int, num_heads: int, *,
             directed: bool = True, dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, max(num_layers, 1))
    return {"layers": [gat_layer_init(keys[i], dim, num_heads,
                                      directed=directed, dtype=dtype)
                       for i in range(num_layers)]}


def gat_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
              node_mask: jnp.ndarray, *, num_heads: int,
              directed: bool = True) -> jnp.ndarray:
    for layer in params["layers"]:
        eps = gat_layer_apply(layer, eps, adj, node_mask, num_heads=num_heads,
                              directed=directed)
    return eps
