"""Graph neural networks over batched kernel graphs (paper §3.2).

GraphSAGE (the paper's choice) and GAT (the ablation alternative), both
direction-aware: incoming and outgoing edges aggregate through separate
feedforward modules ('Undirected' ablation shares them).

Two numerically equivalent aggregation backends share one parameter tree:

* dense — a masked-adjacency matmul `adj[b, d, s] @ h[b, s, :]`, the
  TPU-native formulation (MXU-friendly; see DESIGN.md §3).
  `repro.kernels.graph_aggregate` provides the fused Pallas version; the
  jnp path here is used for training on CPU and as the kernel oracle.
* sparse — `jax.ops.segment_sum` over a packed edge list
  (`*_apply_sparse`), linear in edge count instead of quadratic in the
  padded node count; used with `features.SparseGraphBatch` (DESIGN.md §4).

Two numerically equivalent *layer-stack* layouts share the same layer code
(DESIGN.md §12):

* unrolled — `{"layers": [layer_0, ..., layer_{L-1}]}`, a Python loop;
  each `jit` trace inlines every layer, so trace/compile cost grows with
  depth × number of batch shapes.
* stacked — `{"stacked": tree}` where each leaf carries a leading layer
  axis `[L, ...]`; every `*_apply` runs the layer body once under
  `jax.lax.scan`, so trace cost is depth-independent (the scan-over-layers
  idiom). `stack_params` / `unstack_params` convert between the layouts
  bit-exactly, and `training.checkpoint.restore_checkpoint` restores
  either layout from either on-disk layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import (
    dense_apply,
    dense_init,
    l2_normalize,
)


# ----------------------------------------------------------------------------
# Layer-stack layout converters + scan-over-layers driver (DESIGN.md §12)
# ----------------------------------------------------------------------------
def stack_params(params: dict) -> dict:
    """Convert an unrolled GNN parameter tree (``{"layers": [...]}``) to the
    stacked layout (``{"stacked": tree}``, leaves ``[L, ...]``).

    Stacking is exact (`jnp.stack` of the per-layer leaves), so predictions
    and gradients through the scan path match the unrolled path.

    >>> import jax, numpy as np
    >>> p = sage_init(jax.random.key(0), 8, 3, directed=True)
    >>> s = stack_params(p)
    >>> s["stacked"]["f2_in"]["w"].shape
    (3, 8, 8)
    >>> u = unstack_params(s)
    >>> bool(np.array_equal(u["layers"][1]["f3"]["w"],
    ...                     p["layers"][1]["f3"]["w"]))
    True
    """
    if "stacked" in params:
        return params
    layers = params["layers"]
    if not layers:
        raise ValueError("cannot stack an empty layer list")
    return {"stacked": jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *layers)}


def unstack_params(params: dict) -> dict:
    """Inverse of `stack_params`: split the leading layer axis back into a
    per-layer list. Exact (pure slicing)."""
    if "layers" in params:
        return params
    stacked = params["stacked"]
    num_layers = int(jax.tree_util.tree_leaves(stacked)[0].shape[0])
    return {"layers": [jax.tree_util.tree_map(lambda x: x[i], stacked)
                       for i in range(num_layers)]}


def num_layers(params: dict) -> int:
    """Depth of a GNN parameter tree in either layout."""
    if "stacked" in params:
        return int(jax.tree_util.tree_leaves(params["stacked"])[0].shape[0])
    return len(params["layers"])


def _apply_stack(params: dict, eps: jnp.ndarray, layer_fn) -> jnp.ndarray:
    """Run `layer_fn(layer_params, h) -> h` over every layer of `params`.

    Stacked layout → one `lax.scan` (the layer body traces once per
    enclosing jit trace, regardless of depth); unrolled layout → a Python
    loop (the body traces once per layer).
    """
    if "stacked" in params:
        def body(h, layer):
            return layer_fn(layer, h), None
        eps, _ = jax.lax.scan(body, eps, params["stacked"])
        return eps
    for layer in params["layers"]:
        eps = layer_fn(layer, eps)
    return eps


# Layer-body trace counters (benchmarks/bench_giant_graphs.py): every call
# of a `*_layer_apply*` body bumps one of these. Under jit that happens at
# *trace* time only, so the counters measure exactly the trace/compile
# blowup the scan path removes: unrolled traces the body depth× per batch
# shape, stacked traces it once per shape.
_TRACE_COUNTS = {"dense": 0, "sparse": 0}


def reset_layer_trace_counts() -> None:
    _TRACE_COUNTS["dense"] = 0
    _TRACE_COUNTS["sparse"] = 0


def layer_trace_counts() -> dict:
    return dict(_TRACE_COUNTS)


# ----------------------------------------------------------------------------
# GraphSAGE
# ----------------------------------------------------------------------------
def sage_layer_init(rng, dim: int, *, directed: bool, dtype=jnp.float32) -> dict:
    k_in, k_out, k3 = jax.random.split(rng, 3)
    params = {
        "f2_in": dense_init(k_in, dim, dim, bias=False, dtype=dtype),
        # concat(self, agg_in[, agg_out]) -> dim
        "f3": dense_init(k3, dim * (3 if directed else 2), dim, bias=False,
                         dtype=dtype),
    }
    if directed:
        params["f2_out"] = dense_init(k_out, dim, dim, bias=False, dtype=dtype)
    return params


def _aggregate(adj: jnp.ndarray, h: jnp.ndarray, node_mask: jnp.ndarray,
               aggregator: str) -> jnp.ndarray:
    """adj: [B,N,N] (adj[b,d,s]); h: [B,N,D]; returns [B,N,D] per-dst agg."""
    h = h * node_mask[..., None]
    agg = jnp.einsum("bds,bsh->bdh", adj, h)
    if aggregator == "mean":
        deg = jnp.sum(adj, axis=-1, keepdims=True)
        agg = agg / jnp.maximum(deg, 1.0)
    return agg


def sage_layer_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
                     node_mask: jnp.ndarray, *, aggregator: str = "mean",
                     directed: bool = True,
                     use_pallas: bool = False) -> jnp.ndarray:
    """One GraphSAGE hop:
    eps_i^k = l2( f3( concat(eps_i, Σ_{j∈in(i)} f2_in(eps_j)
                              [, Σ_{j∈out(i)} f2_out(eps_j)]) ) )

    use_pallas=True routes the transform+aggregate through the fused
    repro.kernels.graph_aggregate kernel (beyond-paper optimization —
    interpret-mode on CPU, real VMEM fusion on TPU).
    """
    _TRACE_COUNTS["dense"] += 1
    if use_pallas:
        from repro.kernels.graph_aggregate.ops import graph_aggregate
        import jax as _jax
        interp = _jax.default_backend() == "cpu"
        mean = aggregator == "mean"
        agg_in = graph_aggregate(adj, eps, params["f2_in"]["w"],
                                 act="relu", mean=mean, interpret=interp)
        parts = [eps, agg_in]
        if directed:
            adj_t = jnp.swapaxes(adj, -1, -2)
            parts.append(graph_aggregate(adj_t, eps, params["f2_out"]["w"],
                                         act="relu", mean=mean,
                                         interpret=interp))
        else:
            adj_t = jnp.swapaxes(adj, -1, -2)
            agg_out = graph_aggregate(adj_t, eps, params["f2_in"]["w"],
                                      act="relu", mean=mean,
                                      interpret=interp)
            parts[1] = 0.5 * (agg_in + agg_out)
        h = dense_apply(params["f3"], jnp.concatenate(parts, axis=-1))
        h = jax.nn.relu(h)
        return l2_normalize(h, axis=-1) * node_mask[..., None]

    msg_in = jax.nn.relu(dense_apply(params["f2_in"], eps))
    agg_in = _aggregate(adj, msg_in, node_mask, aggregator)
    parts = [eps, agg_in]
    if directed:
        msg_out = jax.nn.relu(dense_apply(params["f2_out"], eps))
        # outgoing edges: transpose the adjacency
        agg_out = _aggregate(jnp.swapaxes(adj, -1, -2), msg_out, node_mask,
                             aggregator)
        parts.append(agg_out)
    else:
        # undirected ablation: same module, symmetrized adjacency
        agg_out = _aggregate(jnp.swapaxes(adj, -1, -2), msg_in, node_mask,
                             aggregator)
        parts[1] = 0.5 * (agg_in + agg_out)
    h = dense_apply(params["f3"], jnp.concatenate(parts, axis=-1))
    h = jax.nn.relu(h)
    return l2_normalize(h, axis=-1) * node_mask[..., None]


def sage_init(rng, dim: int, num_layers: int, *, directed: bool = True,
              dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, max(num_layers, 1))
    return {"layers": [sage_layer_init(keys[i], dim, directed=directed,
                                       dtype=dtype)
                       for i in range(num_layers)]}


def sage_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
               node_mask: jnp.ndarray, *, aggregator: str = "mean",
               directed: bool = True, use_pallas: bool = False) -> jnp.ndarray:
    def layer_fn(layer, h):
        return sage_layer_apply(layer, h, adj, node_mask,
                                aggregator=aggregator, directed=directed,
                                use_pallas=use_pallas)
    return _apply_stack(params, eps, layer_fn)


# ----------------------------------------------------------------------------
# Sparse (segment-sum) backend — flat [M, D] node buffer + packed edge list
# ----------------------------------------------------------------------------
def _segment_aggregate(msg: jnp.ndarray, gather: jnp.ndarray,
                       scatter: jnp.ndarray, edge_mask: jnp.ndarray,
                       node_mask: jnp.ndarray, aggregator: str) -> jnp.ndarray:
    """Aggregate per-node messages along edges.

    msg: [M, D]; gather/scatter: [E] flat node indices (message taken at
    `gather`, summed into `scatter`); returns [M, D]. With gather=src,
    scatter=dst this is in-edge aggregation (== dense `adj @ h`); swapped,
    out-edge aggregation (== dense `adjᵀ @ h`).
    """
    m = msg * node_mask[:, None]
    w = edge_mask[:, None]
    agg = jax.ops.segment_sum(m[gather] * w, scatter,
                              num_segments=msg.shape[0])
    if aggregator == "mean":
        deg = jax.ops.segment_sum(edge_mask, scatter,
                                  num_segments=msg.shape[0])
        agg = agg / jnp.maximum(deg, 1.0)[:, None]
    return agg


def sage_layer_apply_sparse(params: dict, eps: jnp.ndarray,
                            edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                            edge_mask: jnp.ndarray, node_mask: jnp.ndarray,
                            *, aggregator: str = "mean",
                            directed: bool = True) -> jnp.ndarray:
    """Sparse twin of `sage_layer_apply` over a flat node buffer.

    Takes the same parameter tree; numerically equivalent to the dense path
    on the same graphs (tests/test_sparse_batching.py pins this).
    """
    _TRACE_COUNTS["sparse"] += 1
    msg_in = jax.nn.relu(dense_apply(params["f2_in"], eps))
    agg_in = _segment_aggregate(msg_in, edge_src, edge_dst, edge_mask,
                                node_mask, aggregator)
    parts = [eps, agg_in]
    if directed:
        msg_out = jax.nn.relu(dense_apply(params["f2_out"], eps))
        agg_out = _segment_aggregate(msg_out, edge_dst, edge_src, edge_mask,
                                     node_mask, aggregator)
        parts.append(agg_out)
    else:
        agg_out = _segment_aggregate(msg_in, edge_dst, edge_src, edge_mask,
                                     node_mask, aggregator)
        parts[1] = 0.5 * (agg_in + agg_out)
    h = dense_apply(params["f3"], jnp.concatenate(parts, axis=-1))
    h = jax.nn.relu(h)
    return l2_normalize(h, axis=-1) * node_mask[:, None]


def sage_apply_sparse(params: dict, eps: jnp.ndarray, edge_src: jnp.ndarray,
                      edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                      node_mask: jnp.ndarray, *, aggregator: str = "mean",
                      directed: bool = True) -> jnp.ndarray:
    def layer_fn(layer, h):
        return sage_layer_apply_sparse(layer, h, edge_src, edge_dst,
                                       edge_mask, node_mask,
                                       aggregator=aggregator,
                                       directed=directed)
    return _apply_stack(params, eps, layer_fn)


def _f2_qs(leaf: dict):
    """(weights, per-output-channel scale) of one f2 module for the fused
    kernel: int8 q + its scale for a `quant.scale.QuantizedLeaf`, the f32
    weight with unit scales otherwise (the kernel's dequant is then a
    no-op multiply, so the f32 sparse-Pallas path costs nothing extra)."""
    from repro.quant.scale import QuantizedLeaf
    w = leaf["w"]
    if isinstance(w, QuantizedLeaf):
        return w.q, w.scale.reshape(1, -1)
    return w, jnp.ones((1, w.shape[-1]), jnp.float32)


def sage_layer_apply_sparse_q(params: dict, eps: jnp.ndarray,
                              edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                              edge_mask: jnp.ndarray, node_mask: jnp.ndarray,
                              *, aggregator: str = "mean",
                              directed: bool = True,
                              interpret: bool = False) -> jnp.ndarray:
    """`sage_layer_apply_sparse` with the transform+aggregate fused into
    the `repro.kernels.segment_aggregate` Pallas kernel (inference-only —
    the kernel has no VJP; the trainer stays on the jnp twin). The f2
    weights may be int8 `QuantizedLeaf`s (dequantized in-VMEM, DESIGN.md
    §14) or plain f32; f3 is dequantized outside the kernel either way."""
    from repro.kernels.segment_aggregate.ops import segment_aggregate
    from repro.quant.scale import leaf_f32
    _TRACE_COUNTS["sparse"] += 1
    mean = aggregator == "mean"

    def fused(leaf, gather, scatter):
        w, scale = _f2_qs(leaf)
        return segment_aggregate(eps, w, scale, gather, scatter, edge_mask,
                                 node_mask, act="relu", mean=mean,
                                 interpret=interpret)

    agg_in = fused(params["f2_in"], edge_src, edge_dst)
    parts = [eps, agg_in]
    if directed:
        parts.append(fused(params["f2_out"], edge_dst, edge_src))
    else:
        agg_out = fused(params["f2_in"], edge_dst, edge_src)
        parts[1] = 0.5 * (agg_in + agg_out)
    f3 = {"w": leaf_f32(params["f3"]["w"])}
    h = dense_apply(f3, jnp.concatenate(parts, axis=-1))
    h = jax.nn.relu(h)
    return l2_normalize(h, axis=-1) * node_mask[:, None]


def sage_apply_sparse_q(params: dict, eps: jnp.ndarray,
                        edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                        edge_mask: jnp.ndarray, node_mask: jnp.ndarray, *,
                        aggregator: str = "mean", directed: bool = True,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed twin of `sage_apply_sparse` (f32 or int8 params).
    `interpret` defaults to CPU-backend detection, like the dense
    `use_pallas` path."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    def layer_fn(layer, h):
        return sage_layer_apply_sparse_q(layer, h, edge_src, edge_dst,
                                         edge_mask, node_mask,
                                         aggregator=aggregator,
                                         directed=directed,
                                         interpret=interpret)
    return _apply_stack(params, eps, layer_fn)


# ----------------------------------------------------------------------------
# GAT
# ----------------------------------------------------------------------------
def gat_layer_init(rng, dim: int, num_heads: int, *, directed: bool,
                   dtype=jnp.float32) -> dict:
    assert dim % num_heads == 0
    hd = dim // num_heads
    ks = jax.random.split(rng, 6)
    params = {
        "w_in": dense_init(ks[0], dim, dim, bias=False, dtype=dtype),
        "a_src_in": jax.random.normal(ks[1], (num_heads, hd), dtype) * 0.1,
        "a_dst_in": jax.random.normal(ks[2], (num_heads, hd), dtype) * 0.1,
        "proj": dense_init(ks[3], dim * (2 if directed else 1), dim,
                           bias=False, dtype=dtype),
    }
    if directed:
        params["w_out"] = dense_init(ks[4], dim, dim, bias=False, dtype=dtype)
        params["a_src_out"] = jax.random.normal(ks[5], (num_heads, hd),
                                                dtype) * 0.1
        # independent copy — an aliased leaf would be donated twice
        params["a_dst_out"] = params["a_dst_in"] + 0.0
    return params


def _gat_attend(h: jnp.ndarray, adj: jnp.ndarray, a_src: jnp.ndarray,
                a_dst: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """Masked multi-head attention aggregation over in-edges of `adj`."""
    B, N, D = h.shape
    hd = D // num_heads
    hh = h.reshape(B, N, num_heads, hd)
    e_src = jnp.einsum("bnhd,hd->bnh", hh, a_src)   # score contribution of src
    e_dst = jnp.einsum("bnhd,hd->bnh", hh, a_dst)
    # logits[b, h, d, s] = leaky_relu(e_dst[d] + e_src[s])
    logits = jax.nn.leaky_relu(
        e_dst.transpose(0, 2, 1)[:, :, :, None] +
        e_src.transpose(0, 2, 1)[:, :, None, :], 0.2)
    neg = jnp.finfo(logits.dtype).min
    mask = adj[:, None, :, :] > 0
    logits = jnp.where(mask, logits, neg)
    alpha = jax.nn.softmax(logits, axis=-1)
    # rows with no in-edges get a uniform softmax over masked -inf -> nan-free
    alpha = jnp.where(jnp.any(mask, axis=-1, keepdims=True), alpha, 0.0)
    out = jnp.einsum("bhds,bshx->bdhx", alpha, hh)
    return out.reshape(B, N, D)


def gat_layer_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
                    node_mask: jnp.ndarray, *, num_heads: int,
                    directed: bool = True) -> jnp.ndarray:
    _TRACE_COUNTS["dense"] += 1
    h_in = dense_apply(params["w_in"], eps)
    agg_in = _gat_attend(h_in, adj, params["a_src_in"], params["a_dst_in"],
                         num_heads)
    if directed:
        h_out = dense_apply(params["w_out"], eps)
        agg_out = _gat_attend(h_out, jnp.swapaxes(adj, -1, -2),
                              params["a_src_out"], params["a_dst_out"],
                              num_heads)
        agg = jnp.concatenate([agg_in, agg_out], axis=-1)
    else:
        sym = jnp.maximum(adj, jnp.swapaxes(adj, -1, -2))
        agg = _gat_attend(h_in, sym, params["a_src_in"], params["a_dst_in"],
                          num_heads)
    h = dense_apply(params["proj"], agg)
    h = jax.nn.elu(h) + eps          # residual keeps training stable
    return h * node_mask[..., None]


def gat_init(rng, dim: int, num_layers: int, num_heads: int, *,
             directed: bool = True, dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, max(num_layers, 1))
    return {"layers": [gat_layer_init(keys[i], dim, num_heads,
                                      directed=directed, dtype=dtype)
                       for i in range(num_layers)]}


def gat_apply(params: dict, eps: jnp.ndarray, adj: jnp.ndarray,
              node_mask: jnp.ndarray, *, num_heads: int,
              directed: bool = True) -> jnp.ndarray:
    def layer_fn(layer, h):
        return gat_layer_apply(layer, h, adj, node_mask, num_heads=num_heads,
                               directed=directed)
    return _apply_stack(params, eps, layer_fn)


def _gat_attend_sparse(h: jnp.ndarray, edge_src: jnp.ndarray,
                       edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                       a_src: jnp.ndarray, a_dst: jnp.ndarray,
                       num_heads: int) -> jnp.ndarray:
    """Segment-softmax attention over in-edges: sparse twin of `_gat_attend`.

    h: [M, D]; edges are flat indices into the node buffer. The softmax per
    (dst, head) segment is max-shifted for stability; destinations with no
    in-edges get a zero output, matching the dense path's masked softmax.
    """
    M, D = h.shape
    hd = D // num_heads
    hh = h.reshape(M, num_heads, hd)
    e_src = jnp.einsum("mhd,hd->mh", hh, a_src)
    e_dst = jnp.einsum("mhd,hd->mh", hh, a_dst)
    logits = jax.nn.leaky_relu(e_dst[edge_dst] + e_src[edge_src], 0.2)
    neg = jnp.finfo(logits.dtype).min
    z = jnp.where(edge_mask[:, None] > 0, logits, neg)
    zmax = jax.ops.segment_max(z, edge_dst, num_segments=M)      # [M, H]
    zmax = jnp.maximum(zmax, neg)            # empty segments: -inf → finite
    num = jnp.exp(z - zmax[edge_dst]) * edge_mask[:, None]       # [E, H]
    den = jax.ops.segment_sum(num, edge_dst, num_segments=M)     # [M, H]
    alpha = num / jnp.maximum(den[edge_dst], 1e-30)
    out = jax.ops.segment_sum(alpha[:, :, None] * hh[edge_src], edge_dst,
                              num_segments=M)                    # [M, H, hd]
    return out.reshape(M, D)


def gat_layer_apply_sparse(params: dict, eps: jnp.ndarray,
                           edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                           edge_mask: jnp.ndarray, node_mask: jnp.ndarray,
                           *, num_heads: int,
                           directed: bool = True) -> jnp.ndarray:
    if not directed:
        # the symmetrized (max(adj, adjᵀ)) edge set can't be deduplicated
        # under jit with static shapes; the ablation stays on the dense path
        raise NotImplementedError(
            "undirected GAT is dense-only; use adjacency='dense' "
            "(see DESIGN.md §4)")
    _TRACE_COUNTS["sparse"] += 1
    h_in = dense_apply(params["w_in"], eps)
    agg_in = _gat_attend_sparse(h_in, edge_src, edge_dst, edge_mask,
                                params["a_src_in"], params["a_dst_in"],
                                num_heads)
    h_out = dense_apply(params["w_out"], eps)
    agg_out = _gat_attend_sparse(h_out, edge_dst, edge_src, edge_mask,
                                 params["a_src_out"], params["a_dst_out"],
                                 num_heads)
    agg = jnp.concatenate([agg_in, agg_out], axis=-1)
    h = dense_apply(params["proj"], agg)
    h = jax.nn.elu(h) + eps
    return h * node_mask[:, None]


def gat_apply_sparse(params: dict, eps: jnp.ndarray, edge_src: jnp.ndarray,
                     edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                     node_mask: jnp.ndarray, *, num_heads: int,
                     directed: bool = True) -> jnp.ndarray:
    def layer_fn(layer, h):
        return gat_layer_apply_sparse(layer, h, edge_src, edge_dst,
                                      edge_mask, node_mask,
                                      num_heads=num_heads, directed=directed)
    return _apply_stack(params, eps, layer_fn)
