"""Flywheel launcher: k measure→append→fine-tune→search rounds.

Builds (or reuses) a tile corpus store, trains the static round-0 model
on it, then runs `repro.flywheel.run_flywheel` against a held-out set of
target kernels — printing round-over-round deploy-and-observe regret
next to the static model's regret at the same total hardware budget.

  PYTHONPATH=src python -m repro.launch.flywheel \
      --store experiments/flywheel/store --ckpt-dir experiments/flywheel \
      --rounds 3 --budget-evals 48 --static-steps 300 --finetune-steps 120

The store directory accumulates one chain-verified delta shard set per
round (`delta-0000N.json` + npz shards); rerunning the command appends
further deltas to the same chain. `benchmarks/bench_flywheel.py` is the
gated version of this loop.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True,
                    help="tile corpus store directory (created if absent)")
    ap.add_argument("--ckpt-dir", required=True,
                    help="checkpoint root: static model under static/, "
                         "flywheel rounds under rounds/round-NN")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--budget-evals", type=int, default=48,
                    help="TOTAL hardware evals across all rounds (the "
                         "shared BudgetMeter)")
    ap.add_argument("--programs", type=int, default=10,
                    help="training programs when building a fresh store")
    ap.add_argument("--targets", type=int, default=6,
                    help="held-out kernels to tune")
    ap.add_argument("--max-configs", type=int, default=24,
                    help="candidate tiles enumerated per target kernel")
    ap.add_argument("--static-steps", type=int, default=300,
                    help="round-0 (static) model training steps")
    ap.add_argument("--finetune-steps", type=int, default=120)
    ap.add_argument("--warmup-steps", type=int, default=20)
    ap.add_argument("--mc-samples", type=int, default=8)
    ap.add_argument("--spread", default="kernel",
                    choices=["kernel", "global"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--max-nodes", type=int, default=48)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.model import CostModelConfig
    from repro.core.simulator import TPUSimulator
    from repro.data.store import StreamingCorpus, load_manifest, write_corpus
    from repro.data.synthetic import random_kernel
    from repro.data.tile_dataset import (build_tile_records,
                                         enumerate_tiles,
                                         fit_tile_normalizer)
    from repro.flywheel import FlywheelConfig, run_flywheel
    from repro.flywheel.loop import deploy_regret, static_plan
    from repro.flywheel.retrain import fine_tune
    from repro.search import LearnedEstimator
    from repro.training import checkpoint as ckpt_lib
    from repro.training.optim import adamw_init

    sim = TPUSimulator()
    if load_manifest(args.store) is None:
        from repro.data.fusion import apply_fusion, default_fusion
        from repro.data.synthetic import generate_corpus
        programs = generate_corpus(args.programs, seed=args.seed)
        kernels = [k for p in programs
                   for k in apply_fusion(p, default_fusion(p))]
        recs = build_tile_records(kernels, sim, seed=args.seed)
        write_corpus(args.store, "tile", recs)
        print(f"built store: {len(recs)} records -> {args.store}")
    corpus = StreamingCorpus.open(args.store)
    norm = fit_tile_normalizer(list(corpus))
    model_cfg = CostModelConfig(gnn="graphsage", reduction="lstm",
                                hidden_dim=args.hidden,
                                opcode_embed_dim=16,
                                max_nodes=args.max_nodes, dropout=0.1)

    import jax
    from repro.core.model import cost_model_init

    static_dir = os.path.join(args.ckpt_dir, "static")
    if ckpt_lib.latest_step(static_dir) is None:
        # from-scratch round-0 model: fine_tune's trainer plumbing with a
        # fresh-params "warm start" (zero-step checkpoint of random init)
        params0 = cost_model_init(jax.random.key(args.seed), model_cfg)
        seed_dir = os.path.join(args.ckpt_dir, "init")
        ckpt_lib.save_checkpoint(seed_dir, 0, {"params": params0,
                                               "opt": adamw_init(params0)})
        ft = fine_tune(corpus, norm, model_cfg, warm_start_dir=seed_dir,
                       steps=args.static_steps, ckpt_dir=static_dir,
                       lr=args.lr, warmup_steps=args.warmup_steps,
                       seed=args.seed)
        print(f"trained static model: {ft.steps} steps, "
              f"loss {ft.final_train_loss:.4f}")
    like = {"params": cost_model_init(jax.random.key(0), model_cfg)}
    state, step, _ = ckpt_lib.restore_checkpoint(static_dir, like)
    params = state["params"]
    print(f"static model: {static_dir} @ step {step}")

    targets = [random_kernel(12, seed=10_000 + args.seed + i)
               for i in range(args.targets)]
    fc = FlywheelConfig(rounds=args.rounds, budget_evals=args.budget_evals,
                        finetune_steps=args.finetune_steps,
                        warmup_steps=args.warmup_steps, lr=args.lr,
                        mc_samples=args.mc_samples, spread=args.spread,
                        seed=args.seed, max_configs=args.max_configs)
    res = run_flywheel(sim, args.store, targets, params, model_cfg, norm,
                       fc, ckpt_dir=os.path.join(args.ckpt_dir, "rounds"))

    static_est = LearnedEstimator.from_params(
        params, model_cfg, norm, max_nodes=model_cfg.max_nodes,
        cache_capacity=0)
    groups = [[k.with_tile(t)
               for t in enumerate_tiles(k, max_configs=args.max_configs)]
              for k in targets]
    scores0 = static_est.estimate_groups(groups)
    static_regret = deploy_regret(
        res.truth, scores0, static_plan(scores0, args.budget_evals))

    print(f"\nstatic model @ {args.budget_evals} evals: "
          f"regret {static_regret:.4f}")
    for r in res.rounds:
        print(f"round {r.round}: +{r.measured} evals "
              f"(+{r.delta_records} delta records) -> "
              f"regret {r.regret:.4f}")
    print(f"flywheel total evals charged: {res.evals_charged}")


if __name__ == "__main__":
    main()
