"""Cell lowering: build (step_fn, abstract args, shardings) for any
(architecture × input shape × mesh) and lower+compile it — shared by the
dry-run driver, the roofline pass, and the sharding tests."""
from __future__ import annotations

import math
import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import activation_mapping
from repro.models import lm
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.inputs import input_specs
from repro.sharding import partition
from repro.sharding.context import activation_sharding


@dataclass
class LoweredCell:
    arch: str
    shape: str
    mesh_name: str
    lowered: object
    compiled: object
    memory_analysis: object
    cost_analysis: dict
    collective_bytes: dict
    params_bytes: int


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """Returns (fn, args_abstract, in_shardings, out_shardings)."""
    p_abs = lm.init_abstract(cfg)
    p_specs = partition.param_specs(cfg, p_abs, mesh)
    batch_abs = input_specs(cfg, shape)
    b_specs = partition.batch_specs(batch_abs, mesh)

    if shape.kind == "train":
        opt_init, _ = lm.make_optimizer(cfg)
        o_abs = jax.eval_shape(opt_init, p_abs)
        o_specs = partition.opt_specs(p_specs, p_abs, o_abs)
        fn = lm.train_step_fn(cfg)
        args = (p_abs, o_abs, batch_abs)
        in_sh = (p_specs, o_specs, b_specs)
        out_sh = (p_specs, o_specs, None)
    elif shape.kind == "prefill":
        fn = lm.prefill_step_fn(cfg, capacity=shape.seq_len)
        cache_abs = lm.cache_abstract(cfg, shape.global_batch, shape.seq_len)
        c_specs = partition.cache_specs(cfg, cache_abs, mesh,
                                        batch_size=shape.global_batch)
        args = (p_abs, batch_abs)
        in_sh = (p_specs, b_specs)
        out_sh = (None, c_specs)
    elif shape.kind == "decode":
        fn = lm.decode_step_fn(cfg)
        cache_abs = lm.cache_abstract(cfg, shape.global_batch, shape.seq_len)
        c_specs = partition.cache_specs(cfg, cache_abs, mesh,
                                        batch_size=shape.global_batch)
        dp = partition.mesh_dp_axes(mesh)
        tok_spec = P(dp, None) if shape.global_batch > 1 else P(None, None)
        args = (p_abs, cache_abs,
                jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_specs, c_specs, tok_spec, P())
        out_sh = (None, c_specs)
    else:
        raise ValueError(shape.kind)
    return fn, args, in_sh, out_sh


_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?((?:\w+\[[^\]]*\]|\(.*?\)))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in (SPMD-partitioned,
    per-device) HLO. '-start' ops only (avoid double count with '-done')."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?\S+\s*=\s*(.+?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(3) == "-done":
            continue
        ty, op = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        out[op] = out.get(op, 0.0) + b
        count[op] = count.get(op, 0) + 1
    out["_counts"] = count
    return out


def lower_cell(arch: str, cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               mesh_name: str, *, compile_: bool = True) -> LoweredCell:
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
    # donate params+opt for train, the cache for decode: memory_analysis then
    # reflects in-place aliasing, which is what a real deployment runs.
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    with activation_sharding(activation_mapping(mesh)):
        jitted = jax.jit(fn,
                         in_shardings=_named(mesh, in_sh),
                         out_shardings=_named(mesh, out_sh)
                         if out_sh is not None else None,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
    compiled = None
    mem = None
    cost = {}
    coll = {}
    if compile_:
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        # jax < 0.5 returns a one-element list of dicts (per device);
        # newer jax returns the dict directly
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else None
        cost = dict(ca) if ca else {}
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_bytes_from_hlo(hlo)
    p_abs = args[0]
    params_bytes = int(sum(
        math.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(p_abs)))
    return LoweredCell(arch, shape.name, mesh_name, lowered, compiled, mem,
                       cost, coll, params_bytes)
