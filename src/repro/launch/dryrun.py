import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run driver.

For every (architecture × applicable input shape × mesh) cell:
lower + compile the step under the production mesh, print
memory_analysis() (proves the per-device footprint) and cost_analysis()
(FLOPs/bytes for §Roofline), and persist a JSON record under
experiments/dryrun/ that the roofline pass and EXPERIMENTS.md read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun               # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  # 2-pod pass
"""
import argparse
import json
import time
import traceback


def main() -> int:
    import jax
    from repro.launch.lowering import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.models import SHAPES, registry, shape_applicable

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, jax.device_count()
    archs = [args.arch] if args.arch else registry.list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            cfg = registry.get_config(arch)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                ok, why = shape_applicable(cfg, shape)
                path = os.path.join(args.out,
                                    f"{mesh_name}__{arch}__{shape_name}.json")
                if not ok:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "skipped",
                           "reason": why}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skip] {mesh_name} {arch} {shape_name}: {why}")
                    continue
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") == "ok":
                        print(f"[cached] {mesh_name} {arch} {shape_name}")
                        continue
                t0 = time.time()
                try:
                    cell = lower_cell(arch, cfg, shape, mesh, mesh_name)
                    mem = cell.memory_analysis
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "ok",
                        "devices": mesh.devices.size,
                        "compile_s": round(time.time() - t0, 1),
                        "params_bytes": cell.params_bytes,
                        "memory": {
                            k: int(getattr(mem, k))
                            for k in ("argument_size_in_bytes",
                                      "output_size_in_bytes",
                                      "temp_size_in_bytes",
                                      "alias_size_in_bytes",
                                      "peak_memory_in_bytes",
                                      "generated_code_size_in_bytes")
                            if hasattr(mem, k)
                        },
                        "cost": {k: float(v)
                                 for k, v in cell.cost_analysis.items()
                                 if isinstance(v, (int, float))},
                        "collectives": cell.collective_bytes,
                    }
                    print(f"[ok]   {mesh_name} {arch} {shape_name} "
                          f"compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops', 0):.3e}")
                    print(f"       memory_analysis: {rec['memory']}")
                except Exception as e:            # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append((mesh_name, arch, shape_name, e))
                    print(f"[FAIL] {mesh_name} {arch} {shape_name}: "
                          f"{type(e).__name__}: {str(e)[:400]}")
                    if args.fail_fast:
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        return 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"\ndry-run complete: {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
