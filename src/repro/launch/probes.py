import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
"""Roofline probe driver: scan-corrected FLOPs/bytes/collectives per cell.

Runs the unrolled probe lowering of repro.roofline.probes for every
(arch × applicable shape) on the single-pod production mesh and stores
experiments/probes/*.json for §Roofline.

  PYTHONPATH=src python -m repro.launch.probes [--arch A] [--shape S]
"""
import argparse
import time
import traceback


def main() -> int:
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.models import SHAPES, registry, shape_applicable
    from repro.roofline.probes import run_probes

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/probes")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert jax.device_count() == 512, jax.device_count()
    mesh = make_production_mesh(multi_pod=False)
    mesh_name = "pod16x16"
    archs = [args.arch] if args.arch else registry.list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        cfg = registry.get_config(arch)
        for shape_name in shapes:
            ok, why = shape_applicable(cfg, SHAPES[shape_name])
            if not ok:
                continue
            path = os.path.join(args.out,
                                f"{mesh_name}__{arch}__{shape_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[cached] {arch} {shape_name}")
                continue
            t0 = time.time()
            try:
                rec = run_probes(arch, shape_name, args.out, mesh, mesh_name)
                c = rec["corrected"]
                print(f"[ok] {arch} {shape_name} "
                      f"corr_flops={c['flops']:.3e}/dev "
                      f"coll={c['collective_total']:.3e}B/dev "
                      f"({time.time()-t0:.0f}s)")
            except Exception as e:                    # noqa: BLE001
                failures += 1
                print(f"[FAIL] {arch} {shape_name}: "
                      f"{type(e).__name__}: {str(e)[:300]}")
                traceback.print_exc()
    print(f"probes complete: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
