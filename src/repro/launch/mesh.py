"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips ("data","model").
    Multi-pod: 2×16×16 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = jax.device_count()
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)


def activation_mapping(mesh) -> dict:
    """The activation-sharding context used by all launchers."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return {
        "dp": dp,
        "axis_sizes": {a: int(mesh.shape[a]) for a in mesh.axis_names},
        "act_btd": P(dp, None, None),
        "moe_ecd": P("model", dp, None),
    }
