"""Serving launcher: batched prefill + decode loop for any architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
      --smoke --batch 4 --prompt-len 32 --decode-steps 16

Flags:
  --arch NAME         architecture from `repro.models.registry` (required)
  --smoke | --full    mutually exclusive size choice. `--smoke` (default)
                      runs the reduced config end-to-end on CPU; `--full`
                      initializes the full-size config — real parameter
                      memory, intended for accelerator hosts (the CPU
                      container covers full-size shapes via the dry-run's
                      lower+compile cells instead).
  --batch N           concurrent request streams          (default 4)
  --prompt-len N      prefill length in tokens            (default 32)
  --decode-steps N    autoregressive steps after prefill  (default 16)
  --temperature F     0 = greedy argmax, >0 = sampling    (default 0.0)
  --seed N            params/prompt/sampling seed         (default 0)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Batched prefill+decode serving loop for the LM zoo.")
    ap.add_argument("--arch", required=True)
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="size", action="store_const",
                      const="smoke",
                      help="reduced config, runs on CPU (default)")
    size.add_argument("--full", dest="size", action="store_const",
                      const="full",
                      help="full-size config (accelerator-scale memory)")
    ap.set_defaults(size="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.models import lm, registry

    cfg = registry.get_smoke_config(args.arch) if args.size == "smoke" \
        else registry.get_config(args.arch)
    capacity = args.prompt_len + args.decode_steps
    params = lm.init_params(jax.random.key(args.seed), cfg)
    prefill = jax.jit(lm.prefill_step_fn(cfg, capacity=capacity))
    decode = jax.jit(lm.decode_step_fn(cfg))

    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": tokens})
    print(f"prefill[{args.batch}x{args.prompt_len}] {time.time()-t0:.2f}s")

    out = []
    key = jax.random.key(args.seed + 1)
    t0 = time.time()
    for t in range(args.prompt_len, capacity):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, -1, :] / args.temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        nxt = nxt.astype(jnp.int32)
        out.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, cache, nxt,
                               jnp.asarray(t, jnp.int32))
    dt = time.time() - t0
    toks = args.decode_steps * args.batch
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("sample streams:")
    arr = np.stack(out, axis=1)
    for b in range(min(args.batch, 4)):
        print(f"  req{b}: {arr[b].tolist()}")


if __name__ == "__main__":
    main()
