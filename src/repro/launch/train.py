"""Training launcher.

Two sub-commands:

  cost-model — train the paper's learned performance model on a generated
    corpus (the production path: deterministic sharded sampling, atomic
    checkpoints, resume, optional int8-compressed DP).

      PYTHONPATH=src python -m repro.launch.train cost-model \
          --task tile --steps 2000 --ckpt-dir ckpts/tile

    With --from-store the corpus is streamed shard-by-shard from an
    on-disk store built by `python -m repro.launch.build_corpus`
    (docs/DATA.md) — no generation or oracle measurement at train time:

      PYTHONPATH=src python -m repro.launch.train cost-model \
          --task tile --from-store experiments/corpora/v1/tile

    The flywheel's incremental-retrain path (DESIGN.md §15) adds
    --deltas (train on the store's base+delta chained view) and
    --warm-start CKPT (fine-tune from another run's checkpoint with a
    short LR re-warmup):

      PYTHONPATH=src python -m repro.launch.train cost-model \
          --task tile --from-store experiments/corpora/v1/tile --deltas \
          --warm-start ckpts/tile --ckpt-dir ckpts/tile_ft \
          --steps 200 --warmup-steps 20

  lm — train one of the 10 assigned architectures (reduced config on CPU;
    full configs are exercised via the dry-run).

      PYTHONPATH=src python -m repro.launch.train lm --arch yi-9b \
          --steps 10 --smoke
"""
from __future__ import annotations

import argparse
import time


def train_cost_model(args) -> None:
    from repro.core.features import fit_normalizer
    from repro.core.model import CostModelConfig
    from repro.core.simulator import TPUSimulator
    from repro.data.corpus import filter_by_programs, split_programs
    from repro.data.fusion_dataset import build_fusion_dataset
    from repro.data.sampler import BalancedSampler, TileBatchSampler
    from repro.data.synthetic import generate_corpus
    from repro.data.tile_dataset import build_tile_dataset
    from repro.training.optim import AdamWConfig
    from repro.training.trainer import CostModelTrainer, TrainerConfig

    if args.num_hosts < 1:
        raise SystemExit(f"--num-hosts must be >= 1, got {args.num_hosts}")
    if not 0 <= args.host_id < args.num_hosts:
        raise SystemExit(f"--host-id must be in [0, {args.num_hosts}), "
                         f"got {args.host_id}")
    if args.dp < 0 or args.mp < 1:
        raise SystemExit(f"--dp must be >= 0 and --mp >= 1, "
                         f"got dp={args.dp} mp={args.mp}")

    if args.deltas and not args.from_store:
        raise SystemExit("--deltas only applies to a stored corpus; "
                         "pass --from-store DIR")
    if args.warm_start:
        from repro.training.checkpoint import latest_step
        if latest_step(args.warm_start) is None:
            raise SystemExit(f"--warm-start: no checkpoint found in "
                             f"{args.warm_start!r}")
        if args.warm_start == args.ckpt_dir:
            raise SystemExit(
                "--warm-start must point at a DIFFERENT run's checkpoint "
                "directory — resuming the same --ckpt-dir is the default "
                "behaviour (drop --warm-start), and fine-tuning in place "
                "would overwrite the checkpoint being fine-tuned from")

    want_kind = "tile" if args.task.startswith("tile") else "fusion"
    if args.from_store:
        from repro.data.store import StreamingCorpus
        corpus = StreamingCorpus.open(args.from_store)
        if corpus.kind != want_kind:
            raise SystemExit(f"--from-store points at a {corpus.kind!r} "
                             f"corpus but --task {args.task} needs "
                             f"{want_kind!r}")
        if args.deltas:
            corpus = corpus.with_deltas()
            print(f"chained {corpus.num_deltas} delta shard set(s) "
                  f"(chain {corpus.chain_hash[:12]}…)")
        split = split_programs(corpus.programs(), method=args.split,
                               seed=args.seed)
        recs = corpus.select_programs(split["train"])
        ident = (corpus.chain_hash if args.deltas
                 else corpus.manifest_hash)
        print(f"streaming {len(recs)}/{len(corpus)} records from "
              f"{args.from_store} (manifest {ident[:12]}…)")
    else:
        sim = TPUSimulator()
        programs = generate_corpus(args.programs, seed=args.seed)
        split = split_programs([p.program for p in programs],
                               method=args.split, seed=args.seed)
        if want_kind == "tile":
            ds = build_tile_dataset(programs, sim, max_configs_per_kernel=24)
        else:
            ds = build_fusion_dataset(programs, sim, configs_per_program=12)
        recs = filter_by_programs(ds.records, split["train"])
    mc = CostModelConfig(gnn=args.gnn, reduction=args.reduction,
                         hidden_dim=args.hidden, opcode_embed_dim=32,
                         max_nodes=args.max_nodes)
    if want_kind == "tile":
        from repro.data.tile_dataset import fit_tile_normalizer
        norm = fit_tile_normalizer(recs)
        sampler = TileBatchSampler(recs, norm, kernels_per_batch=4,
                                   configs_per_kernel=8,
                                   max_nodes=args.max_nodes,
                                   host_id=args.host_id,
                                   num_hosts=args.num_hosts)
    else:
        norm = fit_normalizer([r.kernel for r in recs])
        sampler = BalancedSampler(recs, norm, batch_size=32,
                                  max_nodes=args.max_nodes,
                                  host_id=args.host_id,
                                  num_hosts=args.num_hosts)
    tc = TrainerConfig(task=args.task, steps=args.steps,
                       ckpt_every=args.ckpt_every, log_every=args.log_every,
                       ckpt_dir=args.ckpt_dir,
                       metrics_path=args.metrics_path,
                       compress_grads=args.compress_grads,
                       dp=args.dp, mp=args.mp,
                       optim=AdamWConfig(lr=args.lr,
                                         warmup_steps=args.warmup_steps))
    trainer = CostModelTrainer(mc, tc, sampler)
    if args.warm_start:
        from_step = trainer.warm_start(args.warm_start,
                                       reset_opt_step=not args.keep_opt_step)
        print(f"warm-started from {args.warm_start} step {from_step} "
              f"(LR warmup {'continues' if args.keep_opt_step else 'restarts'}"
              f", {args.warmup_steps} warmup steps)")
    res = trainer.run(resume=not args.no_resume)
    print(f"done: step={res['step']} loss={res['loss']:.5f} "
          f"wall={res['wall']:.1f}s interrupted={res['interrupted']}")


def train_lm(args) -> None:
    import jax
    from repro.models import lm, registry
    from repro.models.config import ShapeSpec
    from repro.models.inputs import make_batch

    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    params = lm.init_params(jax.random.key(args.seed), cfg)
    opt_init, _ = lm.make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(lm.train_step_fn(cfg))
    print(f"arch={cfg.name} params={lm.param_count(params):,}")
    for i in range(args.steps):
        batch = make_batch(cfg, shape, seed=args.seed + i)
        t0 = time.time()
        params, opt, stats = step(params, opt, batch)
        print(f"step {i}: loss={float(stats['loss']):.4f} "
              f"({time.time()-t0:.2f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)

    cm = sub.add_parser("cost-model")
    cm.add_argument("--task", default="tile",
                    choices=["tile", "fusion", "tile_mse", "fusion_mse"])
    cm.add_argument("--steps", type=int, default=2000)
    cm.add_argument("--programs", type=int, default=48)
    cm.add_argument("--from-store", default="",
                    help="stream records from an on-disk corpus store "
                         "(one kind's directory, e.g. corpora/v1/tile) "
                         "instead of regenerating + re-measuring")
    cm.add_argument("--deltas", action="store_true",
                    help="with --from-store: train on the base+delta "
                         "chained view (StreamingCorpus.with_deltas) — "
                         "the flywheel's appended measurement shards "
                         "included, chain-verified")
    cm.add_argument("--warm-start", default="",
                    help="checkpoint directory of ANOTHER run to "
                         "fine-tune from: params + AdamW moments are "
                         "restored, this run still starts at step 0 "
                         "(DESIGN.md §15)")
    cm.add_argument("--warmup-steps", type=int, default=0,
                    help="LR warmup steps (AdamWConfig.warmup_steps); "
                         "pair with --warm-start for the short re-warmup "
                         "that protects a fine-tuned checkpoint")
    cm.add_argument("--keep-opt-step", action="store_true",
                    help="with --warm-start: keep the optimizer's step "
                         "counter (LR schedule continues) instead of "
                         "resetting it (warmup restarts)")
    cm.add_argument("--split", default="random",
                    choices=["random", "manual"])
    cm.add_argument("--gnn", default="graphsage")
    cm.add_argument("--reduction", default="transformer")
    cm.add_argument("--hidden", type=int, default=64)
    cm.add_argument("--max-nodes", type=int, default=48)
    cm.add_argument("--lr", type=float, default=2e-3)
    cm.add_argument("--seed", type=int, default=0)
    cm.add_argument("--ckpt-dir", default="ckpts/cost_model")
    cm.add_argument("--ckpt-every", type=int, default=500)
    cm.add_argument("--log-every", type=int, default=100)
    cm.add_argument("--metrics-path", default="")
    cm.add_argument("--compress-grads", action="store_true")
    cm.add_argument("--no-resume", action="store_true")
    cm.add_argument("--dp", type=int, default=0,
                    help="data-parallel mesh size (0 = legacy single-device "
                         "path; >=1 runs the mesh train step, DESIGN.md "
                         "§13)")
    cm.add_argument("--mp", type=int, default=1,
                    help="model mesh axis size (params replicated)")
    cm.add_argument("--num-hosts", type=int, default=1,
                    help="total training hosts; this host's sampler draws "
                         "from its disjoint record shard")
    cm.add_argument("--host-id", type=int, default=0,
                    help="this host's index in [0, --num-hosts)")

    lm_p = sub.add_parser("lm")
    lm_p.add_argument("--arch", required=True)
    lm_p.add_argument("--smoke", action="store_true")
    lm_p.add_argument("--steps", type=int, default=5)
    lm_p.add_argument("--seq", type=int, default=64)
    lm_p.add_argument("--batch", type=int, default=4)
    lm_p.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    if args.cmd == "cost-model":
        train_cost_model(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
