"""Cost-model prediction-service replay: throughput / hit-rate report.

Replays a deterministic tile-search query stream (overlapping candidate
subsets, several rounds per kernel — see `repro.serving.replay`) through
`CostModelService` and prints queries/sec, cache hit rate, coalescing and
flush behavior, per-bucket occupancy, and per-call latency percentiles.
With `--compare-direct` it also times the uncached per-request path
(`core.evaluate.predict_kernels`) on the same stream and reports the
speedup plus the max prediction delta between the two paths.

  PYTHONPATH=src python -m repro.launch.serve_costmodel \\
      --programs 8 --rounds 4 --compare-direct

Two additional modes expose the same service over a socket
(`repro.serving.server`, docs/SERVING.md §server):

  # serve: build the model once, answer predict requests until ^C
  PYTHONPATH=src python -m repro.launch.serve_costmodel \\
      --listen 127.0.0.1:7450 --snapshot /tmp/warm.npz

  # connect: replay the query stream against a running server
  PYTHONPATH=src python -m repro.launch.serve_costmodel \\
      --connect 127.0.0.1:7450

`--connect` never imports jax — the graphs travel as JSON and scoring
happens server-side — so replay clients are cheap to fan out.

Flags:
  --programs N        synthetic programs in the corpus        (default 8)
  --max-configs N     tile candidates per kernel              (default 16)
  --rounds N          search passes over each kernel          (default 4)
  --subset F          candidate fraction sampled per round    (default 0.75)
  --adjacency A       sparse | dense batching representation  (default sparse)
  --cache-capacity N  LRU prediction-cache entries            (default 65536)
  --node-budget N     sparse pack budget / coalescer flush    (default 8*max_nodes)
  --chunk N           dense chunk width                       (default 128)
  --hidden-dim N      model width (untrained params; serving  (default 48)
                      throughput does not depend on training)
  --precision P       f32 | int8 serving weights (int8 runs   (default f32)
                      `repro.quant.quantize_params` on the
                      init params, calibrated on the stream)
  --seed N            corpus/model seed                       (default 0)
  --compare-direct    also time uncached per-request scoring
  --listen H:P        serve over a socket instead of replaying locally
  --connect H:P       replay against a running --listen server (no jax)
  --max-queue N       --listen: admission queue bound         (default 64)
  --deadline-ms F     --listen: default per-request deadline  (default none)
  --snapshot PATH     --listen: warm-cache npz (restored at start,
                      written at shutdown)
"""
from __future__ import annotations

import argparse

import numpy as np


def _host_port(spec: str) -> tuple[str, int]:
    host, _, port = spec.rpartition(":")
    if not host or not port.isdigit():
        raise argparse.ArgumentTypeError(
            f"expected HOST:PORT, got {spec!r}")
    return host, int(port)


def _maybe_quantize(params, cfg, replay, args):
    """--precision int8: quantize the weights per-channel, calibrating on
    a slice of the replay stream; returns the (params, cfg) to serve."""
    if args.precision != "int8":
        return params, cfg
    from repro.quant import quantize_params

    calib = [g for req in replay.requests[:4] for g in req]
    qm = quantize_params(params, cfg, calib_graphs=calib,
                         normalizer=replay.normalizer)
    return qm.params, qm.serving_config(cfg)


def _serve(args) -> int:
    """--listen: stand up the model + socket server, block until ^C."""
    import jax

    from repro.core.evaluate import make_predict_fn
    from repro.core.model import CostModelConfig, cost_model_init
    from repro.serving import CostModelService
    from repro.serving.replay import build_tile_replay
    from repro.serving.server import CostModelServer

    replay = build_tile_replay(args.programs, max_configs=args.max_configs,
                               rounds=args.rounds, subset=args.subset,
                               seed=args.seed)
    max_nodes = max(g.num_nodes for r in replay.requests for g in r)
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=args.hidden_dim, opcode_embed_dim=16,
                          dropout=0.0, max_nodes=max_nodes,
                          adjacency=args.adjacency)
    params = cost_model_init(jax.random.key(args.seed), cfg)
    params, cfg = _maybe_quantize(params, cfg, replay, args)
    service = CostModelService(params, cfg, replay.normalizer,
                               cache_capacity=args.cache_capacity,
                               node_budget=args.node_budget,
                               chunk=args.chunk,
                               predict_fn=make_predict_fn(cfg))
    host, port = args.listen
    server = CostModelServer(service, host=host, port=port,
                             max_queue=args.max_queue,
                             default_deadline_ms=args.deadline_ms,
                             snapshot_path=args.snapshot)
    server.start()
    bound = server.address
    print(f"serving cost model on {bound[0]}:{bound[1]} "
          f"(max_queue={args.max_queue}, "
          f"restored {server.stats.restored_entries} warm entries); ^C stops")
    try:
        import threading
        threading.Event().wait()       # serve until interrupted
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
        print(f"stopped; served {server.stats.completed} requests "
              f"({server.stats.shed_overloaded} shed)")
    return 0


def _connect(args) -> int:
    """--connect: replay the query stream through a running server.

    Stays jax-free: graphs are built with numpy and scored remotely."""
    from repro.serving.client import CostModelClient
    from repro.serving.replay import build_tile_replay, run_replay

    replay = build_tile_replay(args.programs, max_configs=args.max_configs,
                               rounds=args.rounds, subset=args.subset,
                               seed=args.seed)
    host, port = args.connect
    with CostModelClient(host, port) as client:
        client.ping()
        _, dt = run_replay(
            lambda gs: client.predict_many(gs, deadline_ms=args.deadline_ms),
            replay.requests)
        stats = client.stats()
    print(f"replayed {replay.num_queries} queries "
          f"({len(replay.requests)} requests) in {dt:.2f}s -> "
          f"{replay.num_queries / dt:.0f} queries/s")
    svc = stats["service"]
    print(f"server: hit_rate={svc['hit_rate']:.1%} "
          f"flushes={svc['flushes']} "
          f"completed={stats['server']['completed']} "
          f"shed={stats['server']['shed_overloaded']}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Replay a tile-search query stream through the "
                    "cost-model prediction service.")
    ap.add_argument("--programs", type=int, default=8)
    ap.add_argument("--max-configs", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--subset", type=float, default=0.75)
    ap.add_argument("--adjacency", choices=("sparse", "dense"),
                    default="sparse")
    ap.add_argument("--cache-capacity", type=int, default=65536)
    ap.add_argument("--node-budget", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--hidden-dim", type=int, default=48)
    ap.add_argument("--precision", choices=("f32", "int8"), default="f32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-direct", action="store_true")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--listen", type=_host_port, metavar="HOST:PORT")
    mode.add_argument("--connect", type=_host_port, metavar="HOST:PORT")
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--snapshot", default=None)
    args = ap.parse_args()

    if args.listen:
        return _serve(args)
    if args.connect:
        return _connect(args)

    import jax

    from repro.core.evaluate import make_predict_fn, predict_kernels
    from repro.core.model import CostModelConfig, cost_model_init
    from repro.serving import CostModelService
    from repro.serving.replay import build_tile_replay, run_replay

    replay = build_tile_replay(args.programs, max_configs=args.max_configs,
                               rounds=args.rounds, subset=args.subset,
                               seed=args.seed)
    max_nodes = max(g.num_nodes for r in replay.requests for g in r)
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=args.hidden_dim, opcode_embed_dim=16,
                          dropout=0.0, max_nodes=max_nodes,
                          adjacency=args.adjacency)
    params = cost_model_init(jax.random.key(args.seed), cfg)
    params, cfg = _maybe_quantize(params, cfg, replay, args)
    predict_fn = make_predict_fn(cfg)
    print(f"replay: {replay.num_kernels} kernels, "
          f"{len(replay.requests)} requests, {replay.num_queries} queries "
          f"({replay.num_unique} unique graphs), adjacency={args.adjacency}, "
          f"precision={cfg.precision}")

    def make_service() -> CostModelService:
        return CostModelService(params, cfg, replay.normalizer,
                                cache_capacity=args.cache_capacity,
                                node_budget=args.node_budget,
                                chunk=args.chunk, predict_fn=predict_fn)

    # warm up jit on a throwaway service: one full pass traces every bucket
    # shape the stream can produce (compiles persist in the shared
    # predict_fn), so the timed passes below measure steady-state serving
    run_replay(make_service().predict_many, replay.requests)

    service = make_service()
    preds, dt = run_replay(service.predict_many, replay.requests)
    print(f"service: {replay.num_queries / dt:.0f} queries/s "
          f"({dt:.2f}s total)")
    print(service.stats().summary())

    if args.compare_direct:
        def direct(graphs):
            return predict_kernels(params, cfg, graphs, replay.normalizer,
                                   max_nodes=max_nodes, chunk=args.chunk,
                                   predict_fn=predict_fn,
                                   node_budget=args.node_budget)
        # the direct path's full-request packs can hit bucket shapes the
        # service warmup never produced; warm them before timing
        run_replay(direct, replay.requests)
        dpreds, ddt = run_replay(direct, replay.requests)
        err = max(float(np.max(np.abs(a - b)))
                  for a, b in zip(preds, dpreds))
        print(f"direct (uncached per-request): "
              f"{replay.num_queries / ddt:.0f} queries/s ({ddt:.2f}s)")
        print(f"speedup {ddt / dt:.2f}x, max prediction delta {err:.2e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
