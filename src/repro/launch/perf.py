import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
"""Perf-iteration driver (§Perf hillclimbing).

Lowers one (arch × shape) cell with config overrides and reports the
scan-corrected roofline terms, so each hypothesis→change→measure cycle is
one command:

  PYTHONPATH=src python -m repro.launch.perf --arch yi-34b \
      --shape prefill_32k --tag blockkv1024 --set block_kv=1024

Results append to experiments/perf/<arch>__<shape>.jsonl.
"""
import argparse
import dataclasses
import json
import time


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return k, True
    if v in ("false", "False"):
        return k, False
    return k, v


def main() -> int:
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.models import SHAPES, registry
    from repro.roofline.analysis import ROOFLINE_HW
    from repro.roofline.probes import measure_corrected

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    assert jax.device_count() == 512
    cfg = registry.get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    nested = {k: v for k, v in overrides.items() if "." in k}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    if flat:
        cfg = dataclasses.replace(cfg, **flat)
    for k, v in nested.items():          # e.g. --set ssm.chunk=128
        outer, inner = k.split(".", 1)
        sub = getattr(cfg, outer)
        cfg = dataclasses.replace(cfg,
                                  **{outer: dataclasses.replace(
                                      sub, **{inner: v})})
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=False)

    t0 = time.time()
    rec = measure_corrected(args.arch, cfg, shape, mesh, "pod16x16")
    c = rec["corrected"]
    terms = {
        "compute_s": c["flops"] / ROOFLINE_HW["peak_flops"],
        "memory_s": c["bytes"] / ROOFLINE_HW["hbm_bw"],
        "collective_s": c["collective_total"] / ROOFLINE_HW["ici_bw"],
    }
    dominant = max(terms, key=terms.get)
    out = {
        "tag": args.tag, "arch": args.arch, "shape": args.shape,
        "overrides": overrides, "corrected": c, **terms,
        "dominant": dominant, "wall_s": round(time.time() - t0, 1),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(out) + "\n")
    print(json.dumps({k: v for k, v in out.items() if k != "corrected"},
                     indent=1))
    print(f"terms: compute={terms['compute_s']:.4f}s "
          f"memory={terms['memory_s']:.4f}s "
          f"collective={terms['collective_s']:.4f}s -> {dominant}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
