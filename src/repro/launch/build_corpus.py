"""Corpus-builder CLI: fan corpus generation across worker processes into
a sharded on-disk store (repro.data.store; docs/DATA.md).

One *task* is one program — a synthetic family instance from
`data.synthetic.corpus_plan` or one jaxpr-imported architecture from the
model zoo. Each worker generates its programs, runs the fusion machinery
and the simulator oracle, and ships serialized records back; the parent
merges them **in task order** into one `CorpusWriter` per requested kind,
deduplicating by content hash. Because every per-task build is
partition-invariant (`build_tile_records` / `build_fusion_records` seed
from content, the simulator's noise is content-keyed), the resulting
manifest hash does not depend on ``--workers`` — and rebuilding an
unchanged spec is detected up front and skipped (a manifest-hash no-op;
``--force`` overrides).

  PYTHONPATH=src python -m repro.launch.build_corpus \\
      --out experiments/corpora/v1 --kind tile fusion \\
      --programs 48 --seed 0 --workers 4 \\
      --import-archs yi-9b mamba2-2.7b

Train from the result:

  PYTHONPATH=src python -m repro.launch.train cost-model \\
      --from-store experiments/corpora/v1/tile --task tile

This module must stay importable without jax: workers fork/spawn from it,
synthetic generation + the oracle are pure numpy, and only ``--import-archs``
tasks load jax (lazily, inside the worker). The default ``--mp-context
auto`` forks when that is safe (jax not yet loaded in the parent) and
spawns otherwise.
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import sys
import time

from repro.data.store import (
    CorpusWriter,
    StreamingCorpus,
    load_manifest,
    pack_record,
    spec_hash,
)
from repro.data.synthetic import corpus_plan

BUILDER_VERSION = 1
DEFAULT_TILE = {"max_configs_per_kernel": 24, "max_kernel_nodes": 64,
                "min_configs": 2}
DEFAULT_FUSION = {"configs_per_program": 12, "max_kernel_nodes": 64}


# ----------------------------------------------------------------------------
# Worker side
# ----------------------------------------------------------------------------
def _build_program(task: tuple, seed: int):
    """Materialize one task's pre-fusion program graph."""
    if task[0] == "synthetic":
        from repro.data.synthetic import generate_program
        _, family, idx = task
        return generate_program(family, idx, seed)
    if task[0] == "import":
        from repro.core.hlo_import import import_arch_program   # loads jax
        return import_arch_program(task[1])
    raise ValueError(f"unknown task {task!r}")


def _run_task(args: tuple) -> dict:
    """Build all requested kinds' records for one program; returns packed
    (JSON-able) records so pickling back to the merger is cheap and the
    parent never re-hashes kernels."""
    task, kinds, seed, tile_opts, fusion_opts = args
    from repro.core.simulator import TPUSimulator
    from repro.data.fusion import apply_fusion, default_fusion
    from repro.data.fusion_dataset import build_fusion_records
    from repro.data.tile_dataset import build_tile_records

    sim = TPUSimulator()
    program = _build_program(task, seed)
    out: dict = {"task": task, "program": program.program}
    if "tile" in kinds:
        kernels = apply_fusion(program, default_fusion(program))
        recs = build_tile_records(kernels, sim, seed=seed, **tile_opts)
        out["tile"] = [pack_record("tile", r) for r in recs]
    if "fusion" in kinds:
        recs = build_fusion_records(program, sim, seed=seed, **fusion_opts)
        out["fusion"] = [pack_record("fusion", r) for r in recs]
    return out


# ----------------------------------------------------------------------------
# Parent side
# ----------------------------------------------------------------------------
def _pick_context(requested: str) -> str:
    if requested != "auto":
        return requested
    methods = multiprocessing.get_all_start_methods()
    # fork is cheap (workers inherit numpy, skip re-import) but unsafe once
    # jax's runtime threads exist in the parent
    if "fork" in methods and "jax" not in sys.modules:
        return "fork"
    return "spawn"


def make_spec(kind: str, *, programs: int, seed: int,
              import_archs: tuple[str, ...] = (),
              shard_records: int = 128,
              tile_opts: dict | None = None,
              fusion_opts: dict | None = None) -> dict:
    """The deterministic identity of a build — what the manifest records
    and what the no-op rebuild check compares. Everything that can change
    the output bytes is in here (incl. shard_records: it changes the
    shard partitioning, hence the manifest). Import archs are sorted —
    the builder schedules them in the same sorted order, so CLI argument
    order cannot change the record order either."""
    spec = {"builder_version": BUILDER_VERSION, "kind": kind,
            "programs": int(programs), "seed": int(seed),
            "shard_records": int(shard_records),
            "import_archs": sorted(import_archs)}
    if kind == "tile":
        spec["tile"] = dict(DEFAULT_TILE, **(tile_opts or {}))
    else:
        spec["fusion"] = dict(DEFAULT_FUSION, **(fusion_opts or {}))
    return spec


def build_corpus(out_dir: str, *, kinds=("tile", "fusion"), programs: int = 48,
                 seed: int = 0, import_archs: tuple[str, ...] = (),
                 workers: int = 1, shard_records: int = 128,
                 tile_opts: dict | None = None,
                 fusion_opts: dict | None = None, force: bool = False,
                 mp_context: str = "auto", quiet: bool = False) -> dict:
    """Build one store per kind under `out_dir`/<kind>. Returns
    {kind: manifest}. Skips kinds whose stored spec already matches
    (manifest-hash no-op) unless `force`."""
    log = (lambda *a: None) if quiet else \
        (lambda *a: print(*a, file=sys.stderr))
    specs = {k: make_spec(k, programs=programs, seed=seed,
                          import_archs=tuple(import_archs),
                          shard_records=shard_records,
                          tile_opts=tile_opts, fusion_opts=fusion_opts)
             for k in kinds}
    manifests: dict[str, dict] = {}
    todo = []
    for kind in kinds:
        path = os.path.join(out_dir, kind)
        existing = load_manifest(path)
        if (existing is not None and not force
                and existing["spec_hash"] == spec_hash(specs[kind])):
            log(f"[build_corpus] {path}: spec unchanged "
                f"(hash {existing['manifest_hash'][:12]}…) — no-op")
            manifests[kind] = existing
        else:
            todo.append(kind)
    if not todo:
        return manifests

    tasks = [("synthetic", fam, idx) for fam, idx in corpus_plan(programs)]
    tasks += [("import", arch) for arch in sorted(import_archs)]
    job_args = [(t, tuple(todo), seed,
                 specs.get("tile", {}).get("tile", DEFAULT_TILE),
                 specs.get("fusion", {}).get("fusion", DEFAULT_FUSION))
                for t in tasks]
    writers = {k: CorpusWriter(os.path.join(out_dir, k), k, spec=specs[k],
                               shard_records=shard_records)
               for k in todo}
    t0 = time.perf_counter()
    try:
        if workers <= 1:
            results = map(_run_task, job_args)
            _merge(results, writers, len(tasks), log)
        else:
            ctx = multiprocessing.get_context(_pick_context(mp_context))
            with ctx.Pool(processes=workers) as pool:
                # imap (not imap_unordered): merge order == task order, so
                # the store is identical no matter how many workers ran
                _merge(pool.imap(_run_task, job_args), writers,
                       len(tasks), log)
        for kind in todo:
            manifests[kind] = writers[kind].finalize()
            s = manifests[kind]["stats"]
            log(f"[build_corpus] {out_dir}/{kind}: {s['records']} records "
                f"({s['samples']} samples, {s['duplicates_dropped']} dupes "
                f"dropped, {len(manifests[kind]['shards'])} shards) "
                f"in {time.perf_counter() - t0:.1f}s "
                f"hash={manifests[kind]['manifest_hash'][:12]}…")
    except BaseException:
        for w in writers.values():
            w.abort()
        raise
    return manifests


def _merge(results, writers: dict, n_tasks: int, log) -> None:
    for i, res in enumerate(results):
        for kind, w in writers.items():
            for packed in res.get(kind, ()):
                w.add_packed(packed)
        if (i + 1) % 10 == 0 or i + 1 == n_tasks:
            log(f"[build_corpus] merged {i + 1}/{n_tasks} programs")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.build_corpus",
        description="Build a sharded on-disk corpus store (docs/DATA.md).")
    ap.add_argument("--out", required=True,
                    help="store root; one subdir per kind is created")
    ap.add_argument("--kind", nargs="+", default=["tile", "fusion"],
                    choices=["tile", "fusion"])
    ap.add_argument("--programs", type=int, default=48,
                    help="synthetic programs (corpus_plan schedule)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--import-archs", nargs="*", default=[],
                    help="model-zoo architectures to import via jaxpr")
    ap.add_argument("--workers", type=int,
                    default=max(os.cpu_count() or 1, 1))
    ap.add_argument("--shard-records", type=int, default=128)
    ap.add_argument("--tile-configs", type=int,
                    default=DEFAULT_TILE["max_configs_per_kernel"])
    ap.add_argument("--fusion-configs", type=int,
                    default=DEFAULT_FUSION["configs_per_program"])
    ap.add_argument("--max-kernel-nodes", type=int, default=64)
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if the stored spec matches")
    ap.add_argument("--mp-context", default="auto",
                    choices=["auto", "fork", "spawn"])
    ap.add_argument("--verify", action="store_true",
                    help="re-open and checksum-verify the result")
    args = ap.parse_args(argv)

    manifests = build_corpus(
        args.out, kinds=tuple(args.kind), programs=args.programs,
        seed=args.seed, import_archs=tuple(args.import_archs),
        workers=args.workers, shard_records=args.shard_records,
        tile_opts={"max_configs_per_kernel": args.tile_configs,
                   "max_kernel_nodes": args.max_kernel_nodes},
        fusion_opts={"configs_per_program": args.fusion_configs,
                     "max_kernel_nodes": args.max_kernel_nodes},
        force=args.force, mp_context=args.mp_context)
    for kind, m in manifests.items():
        if args.verify:
            StreamingCorpus.open(os.path.join(args.out, kind), verify=True)
        print(f"{kind}: {m['stats']['records']} records "
              f"manifest_hash={m['manifest_hash']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
