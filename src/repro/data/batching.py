"""Size-bucketed graph packing for the sparse data path (DESIGN.md §4).

The dense batcher (`features.encode_batch`) pads every kernel to a fixed
[N, N] adjacency slot, so batch memory and aggregation FLOPs grow with
B·N² regardless of how small the graphs are. This module provides the
sparse alternative:

* `pack_graphs` — first-fit-decreasing bin packing of kernels into packs
  with a bounded total node count, so many small kernels share one device
  batch and big kernels don't force padding onto small ones.
* `BucketSpec` / `bucket_for` — the capacities of one packed batch
  (node/edge/graph/reduce), rounded up a power-of-two ladder so only a few
  distinct shapes ever reach jit: one compiled executable per bucket.
* `encode_packed` / `iter_packed_batches` — turn kernel lists into
  `features.SparseGraphBatch` pytrees using those capacities.

Everything is deterministic: same graphs in, same packs and bucket keys out.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from repro.core import features as F
from repro.core.features import FeatureNormalizer, SparseGraphBatch
from repro.core.graph import KernelGraph


def round_up_pow2(n: int, minimum: int = 1) -> int:
    """Smallest power of two ≥ max(n, minimum).

    >>> round_up_pow2(9)
    16
    >>> round_up_pow2(8, minimum=4)
    8
    >>> round_up_pow2(0)
    1
    """
    target = max(int(n), int(minimum), 1)
    cap = 1
    while cap < target:
        cap *= 2
    return cap


@dataclass(frozen=True)
class BucketSpec:
    """Static capacities of one packed batch; the jit cache key.

    Two packed batches with equal specs produce identically shaped pytrees,
    so a training/inference step compiles once per spec.
    """
    node_capacity: int
    edge_capacity: int
    graph_capacity: int
    reduce_capacity: int


def bucket_for(graphs: Sequence[KernelGraph], *, min_nodes: int = 32,
               min_edges: int = 32, min_graphs: int = 1,
               min_reduce: int = 8) -> BucketSpec:
    """Bucket key for a pack: every required capacity rounded up a
    power-of-two ladder. A graph exactly at a bucket edge stays in that
    bucket (round_up_pow2 is inclusive); one node more spills to the next.

    >>> from repro.data.synthetic import random_kernel
    >>> spec = bucket_for([random_kernel(33, seed=0)])
    >>> (spec.node_capacity, spec.graph_capacity, spec.reduce_capacity)
    (64, 1, 64)
    """
    n = sum(g.num_nodes for g in graphs)
    e = sum(len(g.unique_edges()) for g in graphs)
    r = max(g.num_nodes for g in graphs)
    return BucketSpec(
        node_capacity=round_up_pow2(n, min_nodes),
        edge_capacity=round_up_pow2(e, min_edges),
        graph_capacity=round_up_pow2(len(graphs), min_graphs),
        reduce_capacity=round_up_pow2(r, min_reduce),
    )


def pack_graphs(graphs: Sequence[KernelGraph], node_budget: int,
                *, max_graphs_per_pack: int | None = None,
                oversized: str = "error") -> list[list[int]]:
    """First-fit-decreasing packing: returns packs of indices into `graphs`
    with Σ nodes ≤ node_budget per pack.

    A single graph larger than the budget can neither share a pack nor
    respect the budget. `oversized` picks the policy:

    * ``"error"`` (default) — raise a ValueError naming the graph and the
      budget. Callers that can segment should catch this upstream by
      routing big graphs through `repro.data.segmentation` /
      `encode_segmented` instead.
    * ``"singleton"`` — give the graph its own oversized singleton pack
      and let the bucket ladder absorb it (the historical behavior;
      batched inference over trusted kernel corpora keeps using this).

    A graph exactly at the budget is not oversized — it packs normally.

    >>> from repro.data.synthetic import random_kernel
    >>> gs = [random_kernel(n, seed=n) for n in (5, 9, 3)]
    >>> pack_graphs(gs, node_budget=12)       # 9+3 share a pack, 5 spills
    [[1, 2], [0]]
    >>> pack_graphs(gs, node_budget=2, oversized="singleton")
    [[1], [0], [2]]
    >>> pack_graphs(gs, node_budget=2)
    Traceback (most recent call last):
        ...
    ValueError: graph 0 ('random_5_5', 5 nodes) exceeds node_budget=2; segment it (repro.data.segmentation) or pass oversized='singleton'
    """
    if oversized not in ("error", "singleton"):
        raise ValueError(f"unknown oversized policy {oversized!r}")
    if oversized == "error":
        for i, g in enumerate(graphs):
            if g.num_nodes > node_budget:
                raise ValueError(
                    f"graph {i} ({g.name!r}, {g.num_nodes} nodes) exceeds "
                    f"node_budget={node_budget}; segment it "
                    f"(repro.data.segmentation) or pass "
                    f"oversized='singleton'")
    order = sorted(range(len(graphs)),
                   key=lambda i: (-graphs[i].num_nodes, i))
    packs: list[list[int]] = []
    loads: list[int] = []
    for i in order:
        n = graphs[i].num_nodes
        placed = False
        for p, load in enumerate(loads):
            if load + n <= node_budget and (
                    max_graphs_per_pack is None
                    or len(packs[p]) < max_graphs_per_pack):
                packs[p].append(i)
                loads[p] += n
                placed = True
                break
        if not placed:
            packs.append([i])
            loads.append(n)
    for p in packs:
        p.sort()          # keep corpus order inside a pack
    return packs


def encode_packed(graphs: Sequence[KernelGraph],
                  normalizer: FeatureNormalizer | None = None,
                  *, include_static_perf: bool = True,
                  spec: BucketSpec | None = None) -> SparseGraphBatch:
    """Encode one pack of kernels into a SparseGraphBatch with bucketed
    capacities (slot g of the result is graphs[g])."""
    spec = spec or bucket_for(graphs)
    return F.encode_sparse_batch(
        graphs, normalizer, include_static_perf=include_static_perf,
        node_capacity=spec.node_capacity, edge_capacity=spec.edge_capacity,
        graph_capacity=spec.graph_capacity,
        reduce_capacity=spec.reduce_capacity)


def iter_packed_batches(graphs: Sequence[KernelGraph], node_budget: int,
                        normalizer: FeatureNormalizer | None = None,
                        *, include_static_perf: bool = True,
                        max_graphs_per_pack: int | None = None,
                        oversized: str = "singleton"
                        ) -> Iterator[tuple[SparseGraphBatch, list[int]]]:
    """Pack a kernel list and yield (batch, original_indices) pairs —
    `batch` slot g corresponds to graphs[original_indices[g]]. Used by
    batched inference to run an arbitrary corpus through a handful of
    compiled shapes. Kernels beyond `node_budget` default to oversized
    singleton packs (`oversized='singleton'`) — inference must score
    whatever corpus it is handed; pass `oversized='error'` to reject."""
    for pack in pack_graphs(graphs, node_budget,
                            max_graphs_per_pack=max_graphs_per_pack,
                            oversized=oversized):
        part = [graphs[i] for i in pack]
        yield encode_packed(part, normalizer,
                            include_static_perf=include_static_perf), pack


def encode_segmented(graphs: Sequence[KernelGraph], node_budget: int,
                     normalizer: FeatureNormalizer | None = None,
                     *, include_static_perf: bool = True
                     ) -> "F.SegmentedGraphBatch":
    """Encode whole-program graphs of *any* size into one
    `features.SegmentedGraphBatch` (DESIGN.md §12).

    Each graph is split by `segmentation.segment_graph` into blocks of at
    most `node_budget` nodes (owned + halo); all blocks of all graphs are
    packed into one inner `SparseGraphBatch` through the ordinary bucket
    ladder, and the outer arrays reassemble owned-node embeddings into
    whole-graph node order for the readout. Graphs that fit the budget
    take the identity path: their inner slots are bit-identical to
    `encode_packed(graphs)` on the same list.

    >>> from repro.data.synthetic import random_kernel
    >>> gs = [random_kernel(40, seed=0), random_kernel(7, seed=1)]
    >>> sb = encode_segmented(gs, node_budget=16)
    >>> sb.batch_size, int(sb.graph_mask.sum())
    (2, 2)
    >>> int(sb.node_mask.sum())          # outer buffer holds 40 + 7 nodes
    47
    """
    from repro.data.segmentation import segment_graph

    if not graphs:
        raise ValueError("empty graph list")
    segs = [segment_graph(g, node_budget) for g in graphs]
    parts = [s.graph for sg in segs for s in sg.segments]
    inner = encode_packed(parts, normalizer,
                          include_static_perf=include_static_perf)

    n_real = sum(g.num_nodes for g in graphs)
    M = round_up_pow2(n_real, 32)
    # outer graph capacity stays EXACT (like the sparse samplers): the
    # trainer's losses normalize by slot count, and slot g must be
    # graphs[g] for every caller
    G = len(graphs)
    R = round_up_pow2(max(g.num_nodes for g in graphs), 8)

    # inner nodes -> outer whole-graph slots (halo + padding -> dummy M)
    scatter = np.full((inner.num_nodes,), M, np.int32)
    node_mask = np.zeros((M,), np.float32)
    graph_ids = np.zeros((M,), np.int32)
    kf = np.zeros((G, F.KERNEL_FEATURE_DIM), np.float32)
    graph_mask = np.zeros((G,), np.float32)
    gather_idx = np.full((G, R), M, np.int32)
    gather_mask = np.zeros((G, R), np.float32)

    slot = 0          # inner graph slot (one per segment, in pack order)
    n_off = 0         # running node offset inside the inner flat buffer
    g_off = 0         # running node offset in the outer whole-graph buffer
    for gi, (g, sg) in enumerate(zip(graphs, segs)):
        for s in sg.segments:
            base = n_off                      # segment's inner node offset
            for loc, glob in zip(s.owned_local, s.owned_global):
                scatter[base + loc] = g_off + glob
            n_off += s.graph.num_nodes
            # whole-graph kernel feats for every segment slot, so the
            # kernel_feat_mode='node' broadcast sees the *program's*
            # features, not the block's (identity path: identical values)
            inner.kernel_feats[slot] = _whole_kernel_feats(
                g, normalizer, include_static_perf=include_static_perf)
            slot += 1
        n = g.num_nodes
        node_mask[g_off:g_off + n] = 1.0
        graph_ids[g_off:g_off + n] = gi
        kf[gi] = _whole_kernel_feats(
            g, normalizer, include_static_perf=include_static_perf)
        graph_mask[gi] = 1.0
        gather_idx[gi, :n] = np.arange(g_off, g_off + n, dtype=np.int32)
        gather_mask[gi, :n] = 1.0
        g_off += n
    return F.SegmentedGraphBatch(inner, scatter, node_mask, graph_ids,
                                 kf, graph_mask, gather_idx, gather_mask)


def _whole_kernel_feats(g: KernelGraph,
                        normalizer: FeatureNormalizer | None,
                        *, include_static_perf: bool) -> np.ndarray:
    kf = F.encode_structural(g).kernel_feats(
        g.tile_size, include_static_perf=include_static_perf)
    if normalizer is not None:
        kf = normalizer.transform_kernel(kf)
    return kf
