"""Size-bucketed graph packing for the sparse data path (DESIGN.md §4).

The dense batcher (`features.encode_batch`) pads every kernel to a fixed
[N, N] adjacency slot, so batch memory and aggregation FLOPs grow with
B·N² regardless of how small the graphs are. This module provides the
sparse alternative:

* `pack_graphs` — first-fit-decreasing bin packing of kernels into packs
  with a bounded total node count, so many small kernels share one device
  batch and big kernels don't force padding onto small ones.
* `BucketSpec` / `bucket_for` — the capacities of one packed batch
  (node/edge/graph/reduce), rounded up a power-of-two ladder so only a few
  distinct shapes ever reach jit: one compiled executable per bucket.
* `encode_packed` / `iter_packed_batches` — turn kernel lists into
  `features.SparseGraphBatch` pytrees using those capacities.

Everything is deterministic: same graphs in, same packs and bucket keys out.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

from repro.core import features as F
from repro.core.features import FeatureNormalizer, SparseGraphBatch
from repro.core.graph import KernelGraph


def round_up_pow2(n: int, minimum: int = 1) -> int:
    """Smallest power of two ≥ max(n, minimum).

    >>> round_up_pow2(9)
    16
    >>> round_up_pow2(8, minimum=4)
    8
    >>> round_up_pow2(0)
    1
    """
    target = max(int(n), int(minimum), 1)
    cap = 1
    while cap < target:
        cap *= 2
    return cap


@dataclass(frozen=True)
class BucketSpec:
    """Static capacities of one packed batch; the jit cache key.

    Two packed batches with equal specs produce identically shaped pytrees,
    so a training/inference step compiles once per spec.
    """
    node_capacity: int
    edge_capacity: int
    graph_capacity: int
    reduce_capacity: int


def bucket_for(graphs: Sequence[KernelGraph], *, min_nodes: int = 32,
               min_edges: int = 32, min_graphs: int = 1,
               min_reduce: int = 8) -> BucketSpec:
    """Bucket key for a pack: every required capacity rounded up a
    power-of-two ladder. A graph exactly at a bucket edge stays in that
    bucket (round_up_pow2 is inclusive); one node more spills to the next.

    >>> from repro.data.synthetic import random_kernel
    >>> spec = bucket_for([random_kernel(33, seed=0)])
    >>> (spec.node_capacity, spec.graph_capacity, spec.reduce_capacity)
    (64, 1, 64)
    """
    n = sum(g.num_nodes for g in graphs)
    e = sum(len(g.unique_edges()) for g in graphs)
    r = max(g.num_nodes for g in graphs)
    return BucketSpec(
        node_capacity=round_up_pow2(n, min_nodes),
        edge_capacity=round_up_pow2(e, min_edges),
        graph_capacity=round_up_pow2(len(graphs), min_graphs),
        reduce_capacity=round_up_pow2(r, min_reduce),
    )


def pack_graphs(graphs: Sequence[KernelGraph], node_budget: int,
                *, max_graphs_per_pack: int | None = None
                ) -> list[list[int]]:
    """First-fit-decreasing packing: returns packs of indices into `graphs`
    with Σ nodes ≤ node_budget per pack. A single graph larger than the
    budget gets its own (oversized) singleton pack rather than being
    dropped — the bucket ladder absorbs it.

    >>> from repro.data.synthetic import random_kernel
    >>> gs = [random_kernel(n, seed=n) for n in (5, 9, 3)]
    >>> pack_graphs(gs, node_budget=12)       # 9+3 share a pack, 5 spills
    [[1, 2], [0]]
    >>> pack_graphs(gs, node_budget=2)        # oversized -> singleton packs
    [[1], [0], [2]]
    """
    order = sorted(range(len(graphs)),
                   key=lambda i: (-graphs[i].num_nodes, i))
    packs: list[list[int]] = []
    loads: list[int] = []
    for i in order:
        n = graphs[i].num_nodes
        placed = False
        for p, load in enumerate(loads):
            if load + n <= node_budget and (
                    max_graphs_per_pack is None
                    or len(packs[p]) < max_graphs_per_pack):
                packs[p].append(i)
                loads[p] += n
                placed = True
                break
        if not placed:
            packs.append([i])
            loads.append(n)
    for p in packs:
        p.sort()          # keep corpus order inside a pack
    return packs


def encode_packed(graphs: Sequence[KernelGraph],
                  normalizer: FeatureNormalizer | None = None,
                  *, include_static_perf: bool = True,
                  spec: BucketSpec | None = None) -> SparseGraphBatch:
    """Encode one pack of kernels into a SparseGraphBatch with bucketed
    capacities (slot g of the result is graphs[g])."""
    spec = spec or bucket_for(graphs)
    return F.encode_sparse_batch(
        graphs, normalizer, include_static_perf=include_static_perf,
        node_capacity=spec.node_capacity, edge_capacity=spec.edge_capacity,
        graph_capacity=spec.graph_capacity,
        reduce_capacity=spec.reduce_capacity)


def iter_packed_batches(graphs: Sequence[KernelGraph], node_budget: int,
                        normalizer: FeatureNormalizer | None = None,
                        *, include_static_perf: bool = True,
                        max_graphs_per_pack: int | None = None
                        ) -> Iterator[tuple[SparseGraphBatch, list[int]]]:
    """Pack a kernel list and yield (batch, original_indices) pairs —
    `batch` slot g corresponds to graphs[original_indices[g]]. Used by
    batched inference to run an arbitrary corpus through a handful of
    compiled shapes."""
    for pack in pack_graphs(graphs, node_budget,
                            max_graphs_per_pack=max_graphs_per_pack):
        part = [graphs[i] for i in pack]
        yield encode_packed(part, normalizer,
                            include_static_perf=include_static_perf), pack
