"""Graph segmentation for whole-program graphs (DESIGN.md §12).

The bucketed sparse batcher (`repro.data.batching`) compiles one
executable per `BucketSpec`, so a single 10k+-node program graph
(TpuGraphs-scale, PAPERS.md) would mint a giant one-off bucket — and the
dense path is quadratic in padded node count. Segmentation turns graph
size back into a data-shape problem:

* `segment_graph` partitions a `KernelGraph` into contiguous topological
  blocks of bounded size. Every node is *owned* by exactly one segment;
  a segment additionally carries read-only **halo** copies of the
  out-of-segment producers its owned nodes consume, so every original
  edge appears in exactly one segment (the one owning its destination).
* Halo copies have their `inputs` stripped (they are roots of the
  segment subgraph) and `is_output` cleared — a 1-hop approximation:
  a halo node contributes its layer-local embedding as a neighbor, but
  does not itself aggregate its own neighborhood across the cut. A graph
  that fits `max_nodes` yields one identity segment (the original graph
  object), so the sub-bucket path is bit-identical to the unsegmented
  batcher (`tests/test_segmentation.py` pins this).
* `repro.data.batching.encode_segmented` packs the segments of many
  graphs through the ordinary bucketed batcher and emits a
  `features.SegmentedGraphBatch` whose `scatter_idx` reassembles owned
  per-node embeddings into whole-graph order before the readout
  (`core.model._cost_model_apply_segmented`).

Deterministic: same graph and budget in, same segments out.

>>> from repro.data.synthetic import random_kernel
>>> g = random_kernel(40, seed=0)
>>> seg = segment_graph(g, max_nodes=16)
>>> seg.num_segments > 1
True
>>> sorted(i for s in seg.segments for i in s.owned_global) == list(range(40))
True
>>> segment_graph(g, max_nodes=64).segments[0].graph is g   # identity path
True
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.graph import KernelGraph


@dataclass(frozen=True)
class GraphSegment:
    """One bounded-size block of a segmented `KernelGraph`.

    `graph` holds the segment subgraph: halo copies first (global order,
    inputs stripped), then the owned nodes (global order, inputs remapped
    to local indices). `owned_local[k]` is the local index of the node
    whose original index is `owned_global[k]`.
    """
    graph: KernelGraph
    owned_local: tuple[int, ...]
    owned_global: tuple[int, ...]
    halo_global: tuple[int, ...]

    @property
    def num_owned(self) -> int:
        return len(self.owned_global)


@dataclass(frozen=True)
class Segmentation:
    """All segments of one graph; owned sets partition `range(num_nodes)`."""
    graph: KernelGraph
    segments: tuple[GraphSegment, ...]

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def total_halo(self) -> int:
        return sum(len(s.halo_global) for s in self.segments)


def segment_graph(g: KernelGraph, max_nodes: int) -> Segmentation:
    """Partition `g` into contiguous topological blocks with
    `len(owned) + len(halo) <= max_nodes` per segment.

    The walk is greedy: nodes join the current block in topological order
    until the next node (plus the new halo producers it drags in) would
    overflow `max_nodes`, at which point the block closes and a new one
    starts. A graph already within budget returns a single identity
    segment that *is* the original graph object (no copies).

    Raises ValueError when one node's out-of-block fan-in alone exceeds
    the budget (such a node can never fit any segment).

    >>> from repro.data.synthetic import random_kernel
    >>> g = random_kernel(30, seed=1)
    >>> seg = segment_graph(g, max_nodes=12)
    >>> all(s.graph.num_nodes <= 12 for s in seg.segments)
    True
    """
    n = g.num_nodes
    if max_nodes < 1:
        raise ValueError(f"max_nodes must be >= 1, got {max_nodes}")
    if n <= max_nodes:
        ident = GraphSegment(graph=g,
                             owned_local=tuple(range(n)),
                             owned_global=tuple(range(n)),
                             halo_global=())
        return Segmentation(graph=g, segments=(ident,))

    blocks: list[tuple[int, int, list[int]]] = []   # (lo, hi, halo sorted)
    lo = 0
    halo: set[int] = set()
    i = 0
    while i < n:
        new = {j for j in g.nodes[i].inputs if j < lo} - halo
        if (i - lo + 1) + len(halo) + len(new) > max_nodes:
            if i == lo:
                raise ValueError(
                    f"graph {g.name!r}: node {i} ({g.nodes[i].op.name}) has "
                    f"{len(new)} out-of-block producers; cannot fit any "
                    f"segment of max_nodes={max_nodes}")
            blocks.append((lo, i, sorted(halo)))
            lo = i
            halo = set()
            continue      # re-admit node i against the fresh block
        halo |= new
        i += 1
    blocks.append((lo, n, sorted(halo)))

    segments = []
    for lo, hi, halo_sorted in blocks:
        local = {}                       # global index -> local index
        nodes = []
        for j in halo_sorted:
            local[j] = len(nodes)
            nodes.append(replace(g.nodes[j], inputs=(), is_output=False))
        owned_local = []
        for j in range(lo, hi):
            local[j] = len(nodes)
            owned_local.append(len(nodes))
            src = g.nodes[j]
            nodes.append(replace(src,
                                 inputs=tuple(local[k] for k in src.inputs)))
        sub = KernelGraph(nodes, program=g.program,
                          name=f"{g.name}#seg{lo}:{hi}",
                          tile_size=g.tile_size)
        segments.append(GraphSegment(
            graph=sub, owned_local=tuple(owned_local),
            owned_global=tuple(range(lo, hi)),
            halo_global=tuple(halo_sorted)))
    return Segmentation(graph=g, segments=tuple(segments))
