"""Synthetic tensor-program corpus.

The paper's dataset is 104 production XLA programs; we cannot ship those, so
the corpus here is (a) a parameterized family of generator templates shaped
like common workloads (MLP, CNN, attention, RNN cell, normalization stacks,
embedding/DLRM, elementwise soups) plus (b) programs imported from the 10
assigned LM architectures via `repro.core.hlo_import`.

Each generated program is a *pre-fusion* graph of primitive ops (one
`KernelGraph` whose nodes are single HLO-level ops). The fusion machinery in
`repro.data.fusion` partitions it into kernels.

Program names are `<family>_<idx>`; the family prefix drives the paper's
"manual split" (hold out whole families) and the balanced sampler ("draw
examples evenly from each model type").
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph, Node


class _Builder:
    """Incremental topological graph builder."""

    def __init__(self, program: str):
        self.nodes: list[Node] = []
        self.program = program

    def add(self, op, shape, inputs=(), dtype_bytes=4, **kw) -> int:
        self.nodes.append(Node(op, tuple(int(s) for s in shape),
                               dtype_bytes, tuple(inputs), **kw))
        return len(self.nodes) - 1

    def param(self, shape, dtype_bytes=4) -> int:
        return self.add(opset.PARAMETER, shape, (), dtype_bytes)

    def mark_outputs(self) -> None:
        """Any node with no consumer is a program output."""
        consumed = set()
        for n in self.nodes:
            consumed.update(n.inputs)
        for i, n in enumerate(self.nodes):
            if i not in consumed and n.op is not opset.PARAMETER:
                self.nodes[i] = Node(n.op, n.shape, n.dtype_bytes, n.inputs,
                                     True, n.contract_dim, n.filter_size,
                                     n.reduced_dims)

    def build(self) -> KernelGraph:
        self.mark_outputs()
        return KernelGraph(self.nodes, program=self.program,
                           name=self.program)


def _pow2(rng: np.random.Generator, lo: int, hi: int) -> int:
    los, his = int(np.log2(lo)), int(np.log2(hi))
    return int(2 ** rng.integers(los, his + 1))


def _dtype(rng: np.random.Generator) -> int:
    return int(rng.choice([2, 4], p=[0.6, 0.4]))


def _act(b: _Builder, rng, x: int, shape, dt) -> int:
    op = rng.choice([opset.MAX, opset.TANH, opset.LOGISTIC, opset.EXP])
    if op is opset.MAX:  # relu = max(x, 0-const)
        zero = b.add(opset.CONSTANT, (1,), (), dt)
        zb = b.add(opset.BROADCAST, shape, (zero,), dt)
        return b.add(opset.MAX, shape, (x, zb), dt)
    return b.add(op, shape, (x,), dt)


# ----------------------------------------------------------------------------
# Families
# ----------------------------------------------------------------------------
def mlp(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    batch = _pow2(rng, 16, 256)
    width = _pow2(rng, 128, 2048)
    dt = _dtype(rng)
    x = b.param((batch, width), dt)
    layers = int(rng.integers(2, 6))
    for _ in range(layers):
        out_w = _pow2(rng, 128, 2048)
        w = b.param((width, out_w), dt)
        y = b.add(opset.DOT, (batch, out_w), (x, w), dt, contract_dim=width)
        bias = b.param((out_w,), dt)
        bb = b.add(opset.BROADCAST, (batch, out_w), (bias,), dt)
        y = b.add(opset.ADD, (batch, out_w), (y, bb), dt)
        x = _act(b, rng, y, (batch, out_w), dt)
        width = out_w
    return b.build()


def cnn(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    nimg = _pow2(rng, 4, 32)
    hw_dim = _pow2(rng, 16, 64)
    ch = _pow2(rng, 8, 64)
    dt = _dtype(rng)
    x = b.param((nimg, hw_dim, hw_dim, ch), dt)
    layers = int(rng.integers(2, 5))
    for li in range(layers):
        out_ch = min(_pow2(rng, 16, 256), 256)
        k = int(rng.choice([1, 3, 5]))
        w = b.param((k, k, ch, out_ch), dt)
        y = b.add(opset.CONV, (nimg, hw_dim, hw_dim, out_ch), (x, w), dt,
                  contract_dim=ch, filter_size=(k, k))
        bias = b.param((out_ch,), dt)
        bb = b.add(opset.BROADCAST, (nimg, hw_dim, hw_dim, out_ch), (bias,), dt)
        y = b.add(opset.ADD, (nimg, hw_dim, hw_dim, out_ch), (y, bb), dt)
        x = _act(b, rng, y, (nimg, hw_dim, hw_dim, out_ch), dt)
        ch = out_ch
        if li % 2 == 1 and hw_dim > 8:
            hw_dim //= 2
            x = b.add(opset.REDUCE_MAX, (nimg, hw_dim, hw_dim, ch), (x,), dt,
                      reduced_dims=(2, 2))
    # global pool + classifier
    x = b.add(opset.REDUCE_SUM, (nimg, ch), (x,), dt,
              reduced_dims=(hw_dim, hw_dim))
    w = b.param((ch, 128), dt)
    b.add(opset.DOT, (nimg, 128), (x, w), dt, contract_dim=ch)
    return b.build()


def attention(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    batch = _pow2(rng, 2, 16)
    seq = _pow2(rng, 64, 512)
    d = _pow2(rng, 128, 512)
    dt = _dtype(rng)
    x = b.param((batch, seq, d), dt)
    for _ in range(int(rng.integers(1, 3))):
        wq = b.param((d, d), dt)
        wk = b.param((d, d), dt)
        wv = b.param((d, d), dt)
        q = b.add(opset.DOT, (batch, seq, d), (x, wq), dt, contract_dim=d)
        kk = b.add(opset.DOT, (batch, seq, d), (x, wk), dt, contract_dim=d)
        v = b.add(opset.DOT, (batch, seq, d), (x, wv), dt, contract_dim=d)
        scores = b.add(opset.DOT, (batch, seq, seq), (q, kk), dt,
                       contract_dim=d)
        mx = b.add(opset.REDUCE_MAX, (batch, seq), (scores,), dt,
                   reduced_dims=(seq,))
        mxb = b.add(opset.BROADCAST, (batch, seq, seq), (mx,), dt)
        sub = b.add(opset.SUB, (batch, seq, seq), (scores, mxb), dt)
        ex = b.add(opset.EXP, (batch, seq, seq), (sub,), dt)
        ssum = b.add(opset.REDUCE_SUM, (batch, seq), (ex,), dt,
                     reduced_dims=(seq,))
        ssb = b.add(opset.BROADCAST, (batch, seq, seq), (ssum,), dt)
        attn = b.add(opset.DIV, (batch, seq, seq), (ex, ssb), dt)
        ctx = b.add(opset.DOT, (batch, seq, d), (attn, v), dt,
                    contract_dim=seq)
        wo = b.param((d, d), dt)
        o = b.add(opset.DOT, (batch, seq, d), (ctx, wo), dt, contract_dim=d)
        x = b.add(opset.ADD, (batch, seq, d), (x, o), dt)
    return b.build()


def rnn_cell(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    batch = _pow2(rng, 16, 128)
    d = _pow2(rng, 128, 1024)
    dt = _dtype(rng)
    x = b.param((batch, d), dt)
    h = b.param((batch, d), dt)
    steps = int(rng.integers(1, 4))
    for _ in range(steps):
        wx = b.param((d, 4 * d), dt)
        wh = b.param((d, 4 * d), dt)
        gx = b.add(opset.DOT, (batch, 4 * d), (x, wx), dt, contract_dim=d)
        gh = b.add(opset.DOT, (batch, 4 * d), (h, wh), dt, contract_dim=d)
        g = b.add(opset.ADD, (batch, 4 * d), (gx, gh), dt)
        i = b.add(opset.SLICE, (batch, d), (g,), dt)
        f = b.add(opset.SLICE, (batch, d), (g,), dt)
        o = b.add(opset.SLICE, (batch, d), (g,), dt)
        c = b.add(opset.SLICE, (batch, d), (g,), dt)
        si = b.add(opset.LOGISTIC, (batch, d), (i,), dt)
        sf = b.add(opset.LOGISTIC, (batch, d), (f,), dt)
        so = b.add(opset.LOGISTIC, (batch, d), (o,), dt)
        tc = b.add(opset.TANH, (batch, d), (c,), dt)
        ig = b.add(opset.MUL, (batch, d), (si, tc), dt)
        fg = b.add(opset.MUL, (batch, d), (sf, h), dt)
        cnew = b.add(opset.ADD, (batch, d), (ig, fg), dt)
        tcn = b.add(opset.TANH, (batch, d), (cnew,), dt)
        h = b.add(opset.MUL, (batch, d), (so, tcn), dt)
    return b.build()


def norm_stack(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    batch = _pow2(rng, 16, 128)
    d = _pow2(rng, 256, 2048)
    dt = _dtype(rng)
    x = b.param((batch, d), dt)
    for _ in range(int(rng.integers(1, 4))):
        mu = b.add(opset.REDUCE_SUM, (batch,), (x,), dt, reduced_dims=(d,))
        mub = b.add(opset.BROADCAST, (batch, d), (mu,), dt)
        cen = b.add(opset.SUB, (batch, d), (x, mub), dt)
        sq = b.add(opset.MUL, (batch, d), (cen, cen), dt)
        var = b.add(opset.REDUCE_SUM, (batch,), (sq,), dt, reduced_dims=(d,))
        rs = b.add(opset.RSQRT, (batch,), (var,), dt)
        rsb = b.add(opset.BROADCAST, (batch, d), (rs,), dt)
        y = b.add(opset.MUL, (batch, d), (cen, rsb), dt)
        scale = b.param((d,), dt)
        sb = b.add(opset.BROADCAST, (batch, d), (scale,), dt)
        y = b.add(opset.MUL, (batch, d), (y, sb), dt)
        w = b.param((d, d), dt)
        x = b.add(opset.DOT, (batch, d), (y, w), dt, contract_dim=d)
    return b.build()


def embedding(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    batch = _pow2(rng, 64, 512)
    vocab = _pow2(rng, 1024, 65536)
    d = _pow2(rng, 32, 256)
    dt = _dtype(rng)
    table = b.param((vocab, d), dt)
    ids = b.param((batch, 16), 4)
    emb = b.add(opset.GATHER, (batch, 16, d), (table, ids), dt)
    pooled = b.add(opset.REDUCE_SUM, (batch, d), (emb,), dt,
                   reduced_dims=(16,))
    dense = b.param((batch, d), dt)
    cat = b.add(opset.CONCATENATE, (batch, 2 * d), (pooled, dense), dt)
    w = b.param((2 * d, d), dt)
    y = b.add(opset.DOT, (batch, d), (cat, w), dt, contract_dim=2 * d)
    y = _act(b, rng, y, (batch, d), dt)
    w2 = b.param((d, 1), dt)
    y = b.add(opset.DOT, (batch, 1), (y, w2), dt, contract_dim=d)
    b.add(opset.LOGISTIC, (batch, 1), (y,), dt)
    return b.build()


def elementwise_soup(rng: np.random.Generator, name: str) -> KernelGraph:
    b = _Builder(name)
    rank = int(rng.integers(1, 4))
    shape = tuple(_pow2(rng, 8, 256) for _ in range(rank))
    dt = _dtype(rng)
    live = [b.param(shape, dt) for _ in range(int(rng.integers(1, 4)))]
    n_ops = int(rng.integers(4, 24))
    for _ in range(n_ops):
        kind = rng.random()
        if kind < 0.5 and len(live) >= 2:
            a, c = rng.choice(len(live), 2, replace=False)
            op = rng.choice([opset.ADD, opset.MUL, opset.SUB, opset.MAX,
                             opset.DIV])
            live.append(b.add(op, shape, (live[a], live[c]), dt))
        elif kind < 0.85:
            a = int(rng.integers(len(live)))
            op = rng.choice([opset.EXP, opset.TANH, opset.NEG, opset.ABS,
                             opset.RSQRT, opset.LOGISTIC])
            live.append(b.add(op, shape, (live[a],), dt))
        else:
            a = int(rng.integers(len(live)))
            red = b.add(opset.REDUCE_SUM, shape[:-1] or (1,), (live[a],), dt,
                        reduced_dims=(shape[-1],))
            live.append(b.add(opset.BROADCAST, shape, (red,), dt))
    return b.build()


def conv_draw(rng: np.random.Generator, name: str) -> KernelGraph:
    """Conv + recurrent-ish mixing, subjectively unlike the rest (the paper's
    hardest holdout)."""
    b = _Builder(name)
    nimg = _pow2(rng, 2, 8)
    hw_dim = _pow2(rng, 8, 32)
    ch = _pow2(rng, 8, 32)
    dt = _dtype(rng)
    x = b.param((nimg, hw_dim, hw_dim, ch), dt)
    canvas = b.param((nimg, hw_dim, hw_dim, ch), dt)
    for _ in range(int(rng.integers(1, 3))):
        k = int(rng.choice([3, 5]))
        w = b.param((k, k, ch, ch), dt)
        y = b.add(opset.CONV, (nimg, hw_dim, hw_dim, ch), (x, w), dt,
                  contract_dim=ch, filter_size=(k, k))
        g = b.add(opset.LOGISTIC, (nimg, hw_dim, hw_dim, ch), (y,), dt)
        mix = b.add(opset.MUL, (nimg, hw_dim, hw_dim, ch), (g, canvas), dt)
        canvas = b.add(opset.ADD, (nimg, hw_dim, hw_dim, ch), (mix, y), dt)
        x = b.add(opset.TANH, (nimg, hw_dim, hw_dim, ch), (canvas,), dt)
    return b.build()


FAMILIES = {
    "mlp": mlp,
    "cnn": cnn,
    "attention": attention,
    "rnn": rnn_cell,
    "norm": norm_stack,
    "embedding": embedding,
    "soup": elementwise_soup,
    "convdraw": conv_draw,
}

# program-count weights loosely mirroring the paper's imbalance note
# (many ResNet/Inception-like variants, few DLRM/auto-completion-like ones)
FAMILY_WEIGHTS = {
    "mlp": 3, "cnn": 5, "attention": 4, "rnn": 3, "norm": 2,
    "embedding": 1, "soup": 1, "convdraw": 1,
}


def generate_program(family: str, idx: int, seed: int) -> KernelGraph:
    # zlib.crc32 — deterministic across processes (unlike builtin hash())
    fam_key = zlib.crc32(family.encode()) % (2 ** 31)
    rng = np.random.default_rng(np.random.SeedSequence([seed, idx, fam_key]))
    return FAMILIES[family](rng, f"{family}_{idx}")


def corpus_plan(num_programs: int) -> list[tuple[str, int]]:
    """The (family, idx) schedule `generate_corpus` materializes, without
    building any graph — the corpus-builder CLI fans exactly this plan
    across worker processes (repro.launch.build_corpus), so a sharded
    parallel build reproduces the in-process corpus program-for-program."""
    total_w = sum(FAMILY_WEIGHTS.values())
    plan: list[tuple[str, int]] = []
    idx = 0
    while len(plan) < num_programs:
        for fam, w in FAMILY_WEIGHTS.items():
            count = max(1, round(num_programs * w / total_w))
            for _ in range(count):
                if len(plan) >= num_programs:
                    break
                plan.append((fam, idx))
                idx += 1
    return plan[:num_programs]


def generate_corpus(num_programs: int = 104, seed: int = 0) -> list[KernelGraph]:
    """Generate a corpus of pre-fusion program graphs."""
    return [generate_program(fam, idx, seed)
            for fam, idx in corpus_plan(num_programs)]


def whole_model_graph(target_nodes: int, seed: int = 0, *,
                      arch_blocks: tuple = (),
                      name: str | None = None) -> KernelGraph:
    """A whole-program graph (TpuGraphs-scale; DESIGN.md §12): many model
    blocks stitched end-to-end until the graph reaches `target_nodes`.

    Blocks come from `arch_blocks` (names for
    `repro.core.hlo_import.import_arch_program`, cycled; silently skipped
    when an arch can't be imported) interleaved with the synthetic family
    generators. Consecutive blocks are bridged the way real programs chain
    layers: the previous block's root output is reduced to a scalar
    (`REDUCE_SUM` → shape ``(1,)``) and the next block's first `PARAMETER`
    is replaced by a `BROADCAST` of that scalar to the parameter's shape —
    one connected dataflow graph, still topologically ordered.

    Deterministic in (target_nodes, seed, arch_blocks). The result exceeds
    `target_nodes` by at most one block.

    >>> g = whole_model_graph(500, seed=0)
    >>> g.num_nodes >= 500
    True
    >>> max(abs(d - s) for s, d in g.unique_edges()) > 1   # cross-block edges
    True
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, target_nodes]))
    label = name or f"wholemodel_{target_nodes}_{seed}"
    fams = list(FAMILIES)
    nodes: list[Node] = []
    prev_out = None          # global index of the previous block's root
    bi = 0
    while len(nodes) < target_nodes:
        block = None
        if arch_blocks:
            arch = arch_blocks[bi % len(arch_blocks)]
            try:
                from repro.core.hlo_import import import_arch_program
                block = import_arch_program(arch)
            except Exception:
                block = None
        if block is None:
            fam = fams[int(rng.integers(len(fams)))]
            block = FAMILIES[fam](rng, f"{label}_blk{bi}")
        off = len(nodes)
        if prev_out is not None:
            # bridge: scalar summary of the previous block's output
            prev = nodes[prev_out]
            nodes.append(Node(opset.REDUCE_SUM, (1,), prev.dtype_bytes,
                              (prev_out,), reduced_dims=prev.shape))
            off += 1
        bridged = prev_out is None      # first block keeps all its params
        for i, n in enumerate(block.nodes):
            if not bridged and n.op is opset.PARAMETER:
                nodes.append(Node(opset.BROADCAST, n.shape, n.dtype_bytes,
                                  (off - 1,)))
                bridged = True
                continue
            nodes.append(Node(n.op, n.shape, n.dtype_bytes,
                              tuple(j + off for j in n.inputs), False,
                              n.contract_dim, n.filter_size, n.reduced_dims))
        # root of this block = its last non-parameter node
        for j in range(len(nodes) - 1, -1, -1):
            if nodes[j].op is not opset.PARAMETER:
                prev_out = j
                break
        bi += 1
    b = _Builder(label)
    b.nodes = nodes
    return b.build()


def whole_model_records(num_programs: int, target_nodes: int, seed: int = 0,
                        *, arch_blocks: tuple = (), simulator=None) -> list:
    """`FusionKernelRecord`s for whole-model graphs, runtime-labeled by the
    simulator — the training/serving payload for the giant-graph path
    (`benchmarks/bench_giant_graphs.py` streams these through the corpus
    store and the segmented sampler)."""
    from repro.core.simulator import TPUSimulator
    from repro.data.fusion_dataset import FusionKernelRecord

    sim = simulator or TPUSimulator()
    out = []
    for i in range(num_programs):
        g = whole_model_graph(target_nodes, seed + i,
                              arch_blocks=arch_blocks)
        out.append(FusionKernelRecord(kernel=g, runtime=sim.measure(g),
                                      program=g.program))
    return out


def random_kernel(num_nodes: int, seed: int = 0, *,
                  program: str = "random") -> KernelGraph:
    """A random topologically ordered DAG kernel of exactly `num_nodes`
    nodes — the mixed-size workload generator for the sparse-batching tests
    and `benchmarks/bench_batching.py`. Structure mimics fused HLO kernels:
    a few parameters feeding a soup of unary/binary elementwise ops with
    occasional dots."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, num_nodes]))
    b = _Builder(f"{program}_{num_nodes}_{seed}")
    shape = (_pow2(rng, 8, 64), _pow2(rng, 8, 64))
    dt = _dtype(rng)
    n_params = min(max(1, num_nodes // 8), num_nodes)
    for _ in range(n_params):
        b.param(shape, dt)
    unary = [opset.EXP, opset.TANH, opset.NEG, opset.ABS, opset.LOGISTIC]
    binary = [opset.ADD, opset.MUL, opset.SUB, opset.MAX]
    while len(b.nodes) < num_nodes:
        i = len(b.nodes)
        if i >= 2 and num_nodes - i >= 1 and rng.random() < 0.02:
            lhs, rhs = rng.integers(i, size=2)
            k = shape[1]
            b.add(opset.DOT, shape, (int(lhs), int(rhs)), dt, contract_dim=k)
        elif i >= 2 and rng.random() < 0.4:
            lhs, rhs = rng.integers(i, size=2)
            b.add(rng.choice(binary), shape, (int(lhs), int(rhs)), dt)
        else:
            src = int(rng.integers(i))
            b.add(rng.choice(unary), shape, (src,), dt)
    return b.build()
