"""Operator-fusion machinery (paper §2.2, fusion task).

A *fusion configuration* is a boolean decision per fusable edge of a
pre-fusion program graph. Fused edges merge producer/consumer into one
kernel (intermediate stays in scratchpad); groups are the connected
components of the fused-edge subgraph, subject to XLA-like validity rules:

  * non-fusible ops (sort, top-k, collectives) stay alone,
  * at most one contraction (dot/conv) per group — it roots the fusion;
    elementwise epilogues may fuse *after* it, producers may not fuse into
    its contraction input (loop structures differ),
  * groups are capped at `max_group` nodes (model input budget).

`apply_fusion` materializes each group as a `KernelGraph`: external inputs
become PARAMETER nodes, nodes consumed outside the group (or program
outputs) are marked `is_output`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph, Node


@dataclass(frozen=True)
class FusionDecision:
    """Decisions over `edges` (aligned with `fusable_edges(graph)`)."""
    fuse: tuple[bool, ...]

    def flip(self, i: int) -> "FusionDecision":
        f = list(self.fuse)
        f[i] = not f[i]
        return FusionDecision(tuple(f))


def fusable_edges(g: KernelGraph) -> list[tuple[int, int]]:
    """Edges (src, dst) that *may* be fused."""
    out = []
    for s, d in g.edges():
        ns, nd = g.nodes[s], g.nodes[d]
        if ns.op in (opset.PARAMETER, opset.CONSTANT):
            continue
        if not ns.op.fusible or not nd.op.fusible:
            continue
        # producers may not fuse INTO a contraction's input
        if nd.op.fusion_root_only:
            continue
        out.append((s, d))
    return out


class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, x: int) -> int:
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra


def _group_nodes(g: KernelGraph, decisions: FusionDecision,
                 max_group: int) -> list[list[int]]:
    edges = fusable_edges(g)
    assert len(edges) == len(decisions.fuse), \
        f"{len(edges)} fusable edges vs {len(decisions.fuse)} decisions"
    uf = _UnionFind(g.num_nodes)
    # per-root group size / contraction count, maintained incrementally —
    # the greedy validity re-checks are O(1) instead of a full node scan
    # per union (annealing calls this per candidate)
    size = [1] * g.num_nodes
    contractions = [1 if n.op.fusion_root_only else 0 for n in g.nodes]

    # greedy union in edge order, re-checking validity per union
    for (s, d), fuse in zip(edges, decisions.fuse):
        if not fuse:
            continue
        rs, rd = uf.find(s), uf.find(d)
        if rs == rd:
            continue
        if size[rs] + size[rd] > max_group:
            continue
        if contractions[rs] + contractions[rd] > 1:
            continue
        uf.union(s, d)                    # rs stays root
        size[rs] += size[rd]
        contractions[rs] += contractions[rd]

    groups: dict[int, list[int]] = {}
    for i in range(g.num_nodes):
        if g.nodes[i].op in (opset.PARAMETER, opset.CONSTANT):
            continue
        groups.setdefault(uf.find(i), []).append(i)
    return [sorted(v) for v in sorted(groups.values(), key=lambda v: v[0])]


def _consumer_sets(g: KernelGraph) -> dict[int, set[int]]:
    consumers: dict[int, set[int]] = {i: set() for i in range(g.num_nodes)}
    for d, n in enumerate(g.nodes):
        for s in n.inputs:
            consumers[s].add(d)
    return consumers


def _materialize_group(g: KernelGraph, nodes: list[int],
                       consumers: dict[int, set[int]],
                       name: str) -> KernelGraph:
    """Build the `KernelGraph` of one fused group: external inputs become
    PARAMETER nodes (deterministic order), nodes consumed outside the
    group (or program outputs) are marked `is_output`."""
    node_set = set(nodes)
    local: dict[int, int] = {}
    knodes: list[Node] = []
    ext_inputs: list[int] = []
    for i in nodes:
        for s in g.nodes[i].inputs:
            if s not in node_set and s not in ext_inputs:
                ext_inputs.append(s)
    for s in ext_inputs:
        src = g.nodes[s]
        local[s] = len(knodes)
        knodes.append(Node(opset.PARAMETER, src.shape, src.dtype_bytes))
    for i in nodes:
        n = g.nodes[i]
        is_out = n.is_output or any(c not in node_set
                                    for c in consumers[i])
        local[i] = len(knodes)
        knodes.append(Node(n.op, n.shape, n.dtype_bytes,
                           tuple(local[s] for s in n.inputs), is_out,
                           n.contract_dim, n.filter_size, n.reduced_dims))
    return KernelGraph(knodes, program=g.program, name=name)


def apply_fusion(g: KernelGraph, decisions: FusionDecision,
                 max_group: int = 48) -> list[KernelGraph]:
    """Materialize the fused kernels for a program under `decisions`."""
    consumers = _consumer_sets(g)
    return [_materialize_group(g, nodes, consumers, f"{g.name}/k{gi}")
            for gi, nodes in
            enumerate(_group_nodes(g, decisions, max_group))]


class FusionMaterializer:
    """`apply_fusion` with a per-program group memo, for search loops.

    Neighboring annealing candidates share almost all of their fused
    groups, yet `apply_fusion` rebuilds every kernel from scratch — so
    each candidate re-pays kernel construction AND content hashing
    (`canonical_hash` / `structural_digest` memos live on the graph
    object), which dominates model-guided search. This callable
    materializes each unique group (keyed by its node set) once and
    reuses the object; later candidates get the memoized digests for
    free, turning their prediction-cache lookups into dict hits.

    Kernels keep `apply_fusion`'s positional `.../k{i}` names (renames
    are digest-preserving copies), so measurements are byte-identical to
    the uncached path.

    >>> import numpy as np
    >>> from repro.data.synthetic import generate_program
    >>> prog = generate_program("norm", 0, seed=2)
    >>> mat = FusionMaterializer(prog)
    >>> ks = mat(default_fusion(prog))
    >>> [k.name for k in ks] == \\
    ...     [k.name for k in apply_fusion(prog, default_fusion(prog))]
    True
    >>> ks2 = mat(default_fusion(prog))      # same groups: shared objects
    >>> all(a is b for a, b in zip(ks, ks2))
    True
    """

    def __init__(self, g: KernelGraph, max_group: int = 48):
        self.g = g
        self.max_group = max_group
        self._consumers = _consumer_sets(g)
        self._memo: dict[tuple[int, ...], KernelGraph] = {}

    def __call__(self, decisions: FusionDecision) -> list[KernelGraph]:
        kernels = []
        for gi, nodes in enumerate(
                _group_nodes(self.g, decisions, self.max_group)):
            name = f"{self.g.name}/k{gi}"
            proto = self._memo.get(tuple(nodes))
            if proto is None:
                proto = _materialize_group(self.g, nodes, self._consumers,
                                           name)
                self._memo[tuple(nodes)] = proto
            if proto.name != name:       # digest-preserving rename
                renamed = KernelGraph(proto.nodes, proto.program, name,
                                      proto.tile_size)
                for memo in ("_node_digests", "_unique_edges",
                             "_canonical_hash"):
                    val = getattr(proto, memo, None)
                    if val is not None:
                        setattr(renamed, memo, val)
                proto = renamed
            kernels.append(proto)
        return kernels


def default_fusion(g: KernelGraph, max_group: int = 48) -> FusionDecision:
    """The compiler's greedy heuristic: fuse every edge whose producer is
    cheap to keep in scratch (elementwise/broadcast/reduce chains), don't
    fuse across expensive producers. This is the paper's 'default
    configuration' starting point."""
    edges = fusable_edges(g)
    fuse = []
    for s, d in edges:
        ns = g.nodes[s]
        cheap = ns.op.elementwise or ns.op.unit == "mem" or \
            ns.op.name.startswith("reduce")
        fuse.append(bool(cheap))
    return FusionDecision(tuple(fuse))


def random_fusion(g: KernelGraph, rng: np.random.Generator,
                  p: float | None = None) -> FusionDecision:
    """Random search move used to build the fusion dataset (paper §4)."""
    edges = fusable_edges(g)
    if p is None:
        p = float(rng.uniform(0.1, 0.9))
    return FusionDecision(tuple(bool(x) for x in rng.random(len(edges)) < p))


def no_fusion(g: KernelGraph) -> FusionDecision:
    return FusionDecision(tuple(False for _ in fusable_edges(g)))
