"""Operator-fusion machinery (paper §2.2, fusion task).

A *fusion configuration* is a boolean decision per fusable edge of a
pre-fusion program graph. Fused edges merge producer/consumer into one
kernel (intermediate stays in scratchpad); groups are the connected
components of the fused-edge subgraph, subject to XLA-like validity rules:

  * non-fusible ops (sort, top-k, collectives) stay alone,
  * at most one contraction (dot/conv) per group — it roots the fusion;
    elementwise epilogues may fuse *after* it, producers may not fuse into
    its contraction input (loop structures differ),
  * groups are capped at `max_group` nodes (model input budget).

`apply_fusion` materializes each group as a `KernelGraph`: external inputs
become PARAMETER nodes, nodes consumed outside the group (or program
outputs) are marked `is_output`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import opset
from repro.core.graph import KernelGraph, Node


@dataclass(frozen=True)
class FusionDecision:
    """Decisions over `edges` (aligned with `fusable_edges(graph)`)."""
    fuse: tuple[bool, ...]

    def flip(self, i: int) -> "FusionDecision":
        f = list(self.fuse)
        f[i] = not f[i]
        return FusionDecision(tuple(f))


def fusable_edges(g: KernelGraph) -> list[tuple[int, int]]:
    """Edges (src, dst) that *may* be fused."""
    out = []
    for s, d in g.edges():
        ns, nd = g.nodes[s], g.nodes[d]
        if ns.op in (opset.PARAMETER, opset.CONSTANT):
            continue
        if not ns.op.fusible or not nd.op.fusible:
            continue
        # producers may not fuse INTO a contraction's input
        if nd.op.fusion_root_only:
            continue
        out.append((s, d))
    return out


class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, x: int) -> int:
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra


def _group_nodes(g: KernelGraph, decisions: FusionDecision,
                 max_group: int) -> list[list[int]]:
    edges = fusable_edges(g)
    assert len(edges) == len(decisions.fuse), \
        f"{len(edges)} fusable edges vs {len(decisions.fuse)} decisions"
    uf = _UnionFind(g.num_nodes)

    def group_of(root: int) -> list[int]:
        return [i for i in range(g.num_nodes) if uf.find(i) == root]

    def contractions(nodes: list[int]) -> int:
        return sum(1 for i in nodes if g.nodes[i].op.fusion_root_only)

    # greedy union in edge order, re-checking validity per union
    for (s, d), fuse in zip(edges, decisions.fuse):
        if not fuse:
            continue
        rs, rd = uf.find(s), uf.find(d)
        if rs == rd:
            continue
        ga, gb = group_of(rs), group_of(rd)
        if len(ga) + len(gb) > max_group:
            continue
        if contractions(ga) + contractions(gb) > 1:
            continue
        uf.union(s, d)

    groups: dict[int, list[int]] = {}
    for i in range(g.num_nodes):
        if g.nodes[i].op in (opset.PARAMETER, opset.CONSTANT):
            continue
        groups.setdefault(uf.find(i), []).append(i)
    return [sorted(v) for v in sorted(groups.values(), key=lambda v: v[0])]


def apply_fusion(g: KernelGraph, decisions: FusionDecision,
                 max_group: int = 48) -> list[KernelGraph]:
    """Materialize the fused kernels for a program under `decisions`."""
    groups = _group_nodes(g, decisions, max_group)
    member = {}
    for gi, nodes in enumerate(groups):
        for i in nodes:
            member[i] = gi

    consumers: dict[int, set[int]] = {i: set() for i in range(g.num_nodes)}
    for d, n in enumerate(g.nodes):
        for s in n.inputs:
            consumers[s].add(d)

    kernels = []
    for gi, nodes in enumerate(groups):
        node_set = set(nodes)
        local: dict[int, int] = {}
        knodes: list[Node] = []
        # external inputs -> parameters, in deterministic order
        ext_inputs: list[int] = []
        for i in nodes:
            for s in g.nodes[i].inputs:
                if s not in node_set and s not in ext_inputs:
                    ext_inputs.append(s)
        for s in ext_inputs:
            src = g.nodes[s]
            local[s] = len(knodes)
            knodes.append(Node(opset.PARAMETER, src.shape, src.dtype_bytes))
        for i in nodes:
            n = g.nodes[i]
            is_out = n.is_output or any(c not in node_set
                                        for c in consumers[i])
            local[i] = len(knodes)
            knodes.append(Node(n.op, n.shape, n.dtype_bytes,
                               tuple(local[s] for s in n.inputs), is_out,
                               n.contract_dim, n.filter_size, n.reduced_dims))
        kernels.append(KernelGraph(knodes, program=g.program,
                                   name=f"{g.name}/k{gi}"))
    return kernels


def default_fusion(g: KernelGraph, max_group: int = 48) -> FusionDecision:
    """The compiler's greedy heuristic: fuse every edge whose producer is
    cheap to keep in scratch (elementwise/broadcast/reduce chains), don't
    fuse across expensive producers. This is the paper's 'default
    configuration' starting point."""
    edges = fusable_edges(g)
    fuse = []
    for s, d in edges:
        ns = g.nodes[s]
        cheap = ns.op.elementwise or ns.op.unit == "mem" or \
            ns.op.name.startswith("reduce")
        fuse.append(bool(cheap))
    return FusionDecision(tuple(fuse))


def random_fusion(g: KernelGraph, rng: np.random.Generator,
                  p: float | None = None) -> FusionDecision:
    """Random search move used to build the fusion dataset (paper §4)."""
    edges = fusable_edges(g)
    if p is None:
        p = float(rng.uniform(0.1, 0.9))
    return FusionDecision(tuple(bool(x) for x in rng.random(len(edges)) < p))


def no_fusion(g: KernelGraph) -> FusionDecision:
    return FusionDecision(tuple(False for _ in fusable_edges(g)))
