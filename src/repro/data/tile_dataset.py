"""Tile-size dataset (paper §4, 'Tile-Size Dataset').

For each kernel of each program (fused with the compiler-default heuristic),
enumerate valid tile sizes (per-dim powers of two within the root output
shape, filtered by VMEM fit) and measure each with the hardware oracle
(min of 3 runs). Samples are grouped per kernel — the rank loss only
compares within a group.
"""
from __future__ import annotations

import itertools
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import HardwareSpec, TPUSimulator, V5E, tile_fits_vmem
from repro.data.fusion import apply_fusion, default_fusion


def _dim_options(d: int) -> list[int]:
    opts = []
    t = 1
    while t < d:
        opts.append(t)
        t *= 2
    opts.append(int(d))
    # mimic XLA: prefer the last-dim options aligned to the vector lane width
    return opts


def enumerate_tiles(g: KernelGraph, max_configs: int = 128,
                    hw: HardwareSpec = V5E,
                    seed: int = 0) -> list[tuple[int, ...]]:
    """All valid tiles for the kernel's root output, subsampled
    deterministically if the cross-product explodes (paper: up to 500k
    options, measured as many as possible within a budget)."""
    shape = g.root.shape if g.root.shape else (1,)
    per_dim = [_dim_options(int(d)) for d in shape]
    total = int(np.prod([len(o) for o in per_dim]))
    combos: list[tuple[int, ...]]
    if total <= max_configs * 4:
        combos = list(itertools.product(*per_dim))
    else:
        rng = np.random.default_rng(seed)
        combos_set = set()
        # always include the extremes
        combos_set.add(tuple(o[-1] for o in per_dim))
        combos_set.add(tuple(o[0] for o in per_dim))
        tries = 0
        while len(combos_set) < max_configs * 2 and tries < max_configs * 20:
            combos_set.add(tuple(int(rng.choice(o)) for o in per_dim))
            tries += 1
        combos = sorted(combos_set)
    valid = [t for t in combos if tile_fits_vmem(g, t, hw)]
    if len(valid) > max_configs:
        rng = np.random.default_rng(seed + 1)
        idx = rng.choice(len(valid), max_configs, replace=False)
        valid = [valid[i] for i in sorted(idx)]
    return valid


@dataclass
class TileKernelRecord:
    """One kernel with its measured tile-size sweep."""
    kernel: KernelGraph
    tiles: list[tuple[int, ...]]
    runtimes: np.ndarray               # [num_tiles] seconds (min of 3 runs)
    program: str = ""
    kernel_id: int = -1


@dataclass
class TileDataset:
    records: list[TileKernelRecord] = field(default_factory=list)

    @property
    def num_samples(self) -> int:
        return sum(len(r.tiles) for r in self.records)

    def programs(self) -> list[str]:
        return sorted({r.program for r in self.records})

    def by_program(self) -> dict[str, list[TileKernelRecord]]:
        out: dict[str, list[TileKernelRecord]] = {}
        for r in self.records:
            out.setdefault(r.program, []).append(r)
        return out


def fit_tile_normalizer(records: list["TileKernelRecord"]):
    """Fit the feature normalizer over kernels *with representative tiles*.

    The tile sub-vector is a kernel feature: min/max statistics must span
    the actual tile range or every tile encodes to the same clipped value
    (and the model cannot rank). Samples the smallest / median / largest
    tile of every kernel.
    """
    from repro.core.features import fit_normalizer
    graphs = []
    for r in records:
        picks = {0, len(r.tiles) // 2, len(r.tiles) - 1}
        for i in picks:
            graphs.append(r.kernel.with_tile(r.tiles[i]))
    return fit_normalizer(graphs)


def build_tile_records(kernels: list[KernelGraph], sim: TPUSimulator,
                       *, max_configs_per_kernel: int = 48,
                       max_kernel_nodes: int = 64, min_configs: int = 2,
                       seed: int = 0) -> list[TileKernelRecord]:
    """Partition-invariant record builder for the corpus store.

    `build_tile_dataset` seeds each kernel's tile enumeration with a
    running record counter, which couples every record to all kernels
    before it — fine in one process, wrong when
    `repro.launch.build_corpus` splits the corpus across workers. Here
    the enumeration seed derives from (seed, kernel content hash), so any
    partitioning of `kernels` yields the same records, and the store's
    manifest hash is a pure function of the build spec.
    """
    records = []
    for k in kernels:
        if k.num_nodes > max_kernel_nodes:
            continue
        kseed = zlib.crc32(
            f"{seed}:{k.canonical_hash(order_sensitive=True)}".encode())
        tiles = enumerate_tiles(k, max_configs_per_kernel, sim.hw,
                                seed=int(kseed % (2 ** 31)))
        if len(tiles) < min_configs:
            continue
        runtimes = np.array([sim.measure(k.with_tile(t)) for t in tiles])
        records.append(TileKernelRecord(
            kernel=k, tiles=tiles, runtimes=runtimes, program=k.program))
    return records


def build_tile_dataset(programs: list[KernelGraph], sim: TPUSimulator,
                       *, max_configs_per_kernel: int = 48,
                       max_kernel_nodes: int = 64,
                       min_configs: int = 2,
                       extra_kernels: list[KernelGraph] | None = None,
                       ) -> TileDataset:
    """Fuse each program with the default heuristic, enumerate + measure."""
    ds = TileDataset()
    kid = 0
    all_kernels: list[KernelGraph] = []
    for prog in programs:
        all_kernels.extend(apply_fusion(prog, default_fusion(prog)))
    if extra_kernels:
        all_kernels.extend(extra_kernels)
    for k in all_kernels:
        if k.num_nodes > max_kernel_nodes:
            continue
        tiles = enumerate_tiles(k, max_configs_per_kernel, sim.hw, seed=kid)
        if len(tiles) < min_configs:
            continue
        runtimes = np.array([sim.measure(k.with_tile(t)) for t in tiles])
        ds.records.append(TileKernelRecord(
            kernel=k, tiles=tiles, runtimes=runtimes,
            program=k.program, kernel_id=kid))
        kid += 1
    return ds
