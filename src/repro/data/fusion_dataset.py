"""Fusion dataset (paper §4, 'Fusion Dataset').

For each program, run random-search fusion configuration generation (the
paper's data-collection strategy), decompose into kernels, measure each with
the hardware oracle, and de-duplicate structurally identical kernels.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator
from repro.data.corpus import kernel_hash
from repro.data.fusion import apply_fusion, default_fusion, random_fusion


@dataclass
class FusionKernelRecord:
    kernel: KernelGraph
    runtime: float                     # seconds, min of 3 runs
    program: str = ""


@dataclass
class FusionDataset:
    records: list[FusionKernelRecord] = field(default_factory=list)

    @property
    def num_samples(self) -> int:
        return len(self.records)

    def programs(self) -> list[str]:
        return sorted({r.program for r in self.records})

    def by_program(self) -> dict[str, list[FusionKernelRecord]]:
        out: dict[str, list[FusionKernelRecord]] = {}
        for r in self.records:
            out.setdefault(r.program, []).append(r)
        return out


def build_fusion_records(program: KernelGraph, sim: TPUSimulator,
                         *, configs_per_program: int = 24,
                         max_kernel_nodes: int = 64,
                         seed: int = 0) -> list[FusionKernelRecord]:
    """Partition-invariant record builder for the corpus store.

    `build_fusion_dataset` threads one rng and one dedup set through the
    whole program list, coupling every program's records to the ones
    before it. Here the rng is seeded from (seed, program name) and dedup
    is within-program only — `repro.launch.build_corpus` fans programs
    across workers and the corpus writer dedups across programs by
    content hash at merge time, so the result is independent of how the
    corpus was partitioned.
    """
    rng = np.random.default_rng(np.random.SeedSequence(
        [seed, zlib.crc32(program.program.encode()) % (2 ** 31)]))
    decisions = [default_fusion(program)]
    for _ in range(configs_per_program - 1):
        decisions.append(random_fusion(program, rng))
    records, seen = [], set()
    for dec in decisions:
        for k in apply_fusion(program, dec):
            if k.num_nodes > max_kernel_nodes:
                continue
            h = kernel_hash(k)
            if h in seen:
                continue
            seen.add(h)
            records.append(FusionKernelRecord(
                kernel=k, runtime=sim.measure(k), program=program.program))
    return records


def build_fusion_dataset(programs: list[KernelGraph], sim: TPUSimulator,
                         *, configs_per_program: int = 24,
                         max_kernel_nodes: int = 64,
                         seed: int = 0) -> FusionDataset:
    ds = FusionDataset()
    seen: set[str] = set()
    rng = np.random.default_rng(seed)
    for prog in programs:
        decisions = [default_fusion(prog)]
        for _ in range(configs_per_program - 1):
            decisions.append(random_fusion(prog, rng))
        for dec in decisions:
            for k in apply_fusion(prog, dec):
                if k.num_nodes > max_kernel_nodes:
                    continue
                h = kernel_hash(k)
                if h in seen:
                    continue
                seen.add(h)
                ds.records.append(FusionKernelRecord(
                    kernel=k, runtime=sim.measure(k), program=prog.program))
    return ds
