"""Balanced, deterministic, shard-aware batch sampling.

* Balanced: the paper draws examples evenly per program ("model type") to
  counter corpus imbalance; we sample programs uniformly, then kernels.
* Deterministic: the batch at step k is a pure function of (seed, step,
  host shard) — a preempted-and-restarted worker reproduces its exact batch
  stream, which the fault-tolerance tests rely on.
* Shard-aware: with H data-parallel workers, worker h draws from its own
  disjoint round-robin shard of the records (`shard_records`; a
  `StreamingCorpus`/`CorpusSubset` shards through its manifest-only
  `.shard(idx, num)` view, so no shard file is decoded for records other
  workers own) with an h-distinct RNG stream. `ShardPlanner` reassigns
  shards away from hosts flagged as stragglers (deterministically), so a
  slow host's work is taken over by backups without coordination.
* Mesh-ready: `GlobalBatchSampler` stacks the per-shard sub-batches of dp
  sampler views into one global batch with a leading device axis — sparse
  sub-batches are re-bucketed to one shared `BucketSpec` so a single
  compiled executable serves every device (DESIGN.md §13).

Both samplers encode each draw with `adjacency='dense'` (padded GraphBatch,
truncated at max_nodes) or `adjacency='sparse'` (packed SparseGraphBatch —
no per-graph padding or truncation; capacities pow2-bucketed so jit sees a
bounded set of shapes). See DESIGN.md §4.

Because `batch(step)` is pure, both samplers compose with
`repro.data.prefetch.Prefetcher` (encode-ahead on a background thread;
`TrainerConfig.prefetch` enables it) without changing the batch stream,
and every draw's structural encode is served by the `features.EncodeCache`
— a tile sweep re-encodes only the tile sub-vector (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.features import FeatureNormalizer, encode_batch
from repro.data import batching


@dataclass
class TileBatch:
    graphs: object           # GraphBatch | SparseGraphBatch
    targets: np.ndarray      # [B] seconds
    group_ids: np.ndarray    # [B] int32 — same kernel => same group
    valid: np.ndarray        # [B] float32


@dataclass
class FusionBatch:
    graphs: object           # GraphBatch | SparseGraphBatch
    targets: np.ndarray      # [B] seconds
    valid: np.ndarray        # [B] float32


def shard_records(records, idx: int, num: int):
    """Worker `idx`'s deterministic round-robin shard of `records`.

    Dispatches to the sequence's own manifest-only ``.shard(idx, num)``
    when it has one (`StreamingCorpus` / `CorpusSubset` — nothing decoded)
    and falls back to a strided slice for in-memory lists. Shards are
    disjoint and exhaustive: position-interleaving them reproduces the
    unsharded record stream.
    """
    if num < 1:
        raise ValueError(f"num shards must be >= 1, got {num}")
    if not 0 <= idx < num:
        raise ValueError(f"shard idx must be in [0, {num}), got {idx}")
    if num == 1:
        return records
    shard = getattr(records, "shard", None)
    if shard is not None:
        return shard(idx, num)
    return records[idx::num]


def _program_index(records) -> dict[str, list[int]]:
    """record index -> per-program draw lists. A `StreamingCorpus` (or any
    sequence exposing `record_programs`) is indexed from its manifest
    metadata alone — no shard is decoded until a batch actually draws
    from it, which is what keeps store-backed sampling shard-by-shard."""
    programs = getattr(records, "record_programs", None)
    if programs is None:
        programs = [r.program for r in records]
    by_program: dict[str, list[int]] = {}
    for i, p in enumerate(programs):
        by_program.setdefault(p, []).append(i)
    return by_program


def sparse_draw_spec(graphs) -> batching.BucketSpec:
    """The `BucketSpec` a sparse encode of this draw uses: pow2-bucketed
    node/edge/reduce capacities, graph capacity EXACT (the per-step draw
    count is fixed, so jit still sees one G): padded graph slots would
    dilute losses normalized by slot count (pairwise_rank_loss's n(n-1)/2)
    relative to an identical dense run."""
    return dataclasses.replace(batching.bucket_for(graphs),
                               graph_capacity=len(graphs))


def _encode(graphs, adjacency: str, max_nodes: int, normalizer, spec=None):
    """Encode a drawn graph list with the configured representation.

    dense     — `features.encode_batch`, one padded [N, N] slot per graph.
    sparse    — `batching.encode_packed`, the whole draw packed into one
                flat node/edge buffer with pow2-bucketed capacities, so
                only a few shapes reach jit (slot order == draw order, so
                targets/groups line up unchanged). `spec` overrides the
                draw's own bucket — `GlobalBatchSampler` passes the max
                bucket over its shards so all sub-batches share one shape.
    segmented — `batching.encode_segmented`, for whole-program graphs of
                any size: each graph split into ≤ max_nodes segments,
                owned-node embeddings reassembled before readout
                (DESIGN.md §12). Slot order == draw order here too.
    """
    if adjacency == "dense":
        return encode_batch(graphs, max_nodes, normalizer)
    if adjacency == "sparse":
        if spec is None:
            spec = sparse_draw_spec(graphs)
        return batching.encode_packed(graphs, normalizer, spec=spec)
    if adjacency == "segmented":
        return batching.encode_segmented(graphs, max_nodes, normalizer)
    raise ValueError(f"unknown adjacency {adjacency!r}")


class _ShardedSampler:
    """Shared worker-shard plumbing of both samplers.

    `host_id`/`num_hosts` select BOTH the RNG stream and the record shard:
    worker h of H draws only from `shard_records(records, h, H)` — the
    disjoint round-robin slice whose union over workers is the full record
    list. With `num_hosts == 1` the records are untouched (the historical
    single-worker behavior, bit-for-bit).
    """

    def _init_shard(self, records, *, seed: int, host_id: int,
                    num_hosts: int, what: str):
        if not records:
            raise ValueError(f"empty {what} dataset")
        self._all_records = records      # pre-shard; `with_host` re-slices
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.records = shard_records(records, host_id, num_hosts)
        if not len(self.records):
            raise ValueError(
                f"{what} shard {host_id}/{num_hosts} is empty "
                f"({len(records)} records total)")
        self._by_program = _program_index(self.records)
        self._programs = sorted(self._by_program)

    def with_host(self, host_id: int, num_hosts: int):
        """A copy of this sampler re-sharded as worker `host_id` of
        `num_hosts` over the SAME underlying records — how the mesh
        trainer derives its dp per-device sampler views."""
        import copy
        s = copy.copy(self)
        s._init_shard(self._all_records, seed=self.seed, host_id=host_id,
                      num_hosts=num_hosts, what=self._what)
        return s

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def batch(self, step: int):
        return self.encode_draw(self.draw(step))


class TileBatchSampler(_ShardedSampler):
    """Yields batches of (kernel, tile) samples grouped for the rank loss."""

    _what = "tile"

    def __init__(self, records, normalizer: FeatureNormalizer, *,
                 kernels_per_batch: int = 4, configs_per_kernel: int = 16,
                 max_nodes: int = 64, seed: int = 0, host_id: int = 0,
                 num_hosts: int = 1, adjacency: str = "dense"):
        self.normalizer = normalizer
        self.kernels_per_batch = kernels_per_batch
        self.configs_per_kernel = configs_per_kernel
        self.max_nodes = max_nodes
        self.adjacency = adjacency
        self._init_shard(records, seed=seed, host_id=host_id,
                         num_hosts=num_hosts, what=self._what)

    @property
    def batch_size(self) -> int:
        return self.kernels_per_batch * self.configs_per_kernel

    def draw(self, step: int) -> tuple:
        """The step's raw draw: (graphs, targets, group_ids, valid) before
        encoding — `batch` = `encode_draw(draw(step))`."""
        rng = self._rng(step)
        graphs, targets, groups, valid = [], [], [], []
        for ki in range(self.kernels_per_batch):
            prog = self._programs[int(rng.integers(len(self._programs)))]
            rec = self.records[int(rng.choice(self._by_program[prog]))]
            rec.kernel.structural_digest()   # memoize node digests + edge
            rec.kernel.unique_edges()        # set once: every with_tile
            #   draw below shares them, so the encode cache's key costs one
            #   top-level hash per variant and the sparse pack-sizing pass
            #   (bucket_for's edge counts) reuses one edge list
            n_cfg = len(rec.tiles)
            take = min(self.configs_per_kernel, n_cfg)
            idx = rng.choice(n_cfg, take, replace=False)
            for j in idx:
                graphs.append(rec.kernel.with_tile(rec.tiles[int(j)]))
                targets.append(float(rec.runtimes[int(j)]))
                groups.append(ki)
                valid.append(1.0)
            if take < self.configs_per_kernel:                # pad group
                # one shared graph object for every pad slot (valid=0.0):
                # it is encoded once, not re-encoded per slot
                pad_graph = rec.kernel.with_tile(rec.tiles[0])
                for _ in range(self.configs_per_kernel - take):
                    graphs.append(pad_graph)
                    targets.append(float(rec.runtimes[0]))
                    groups.append(ki)
                    valid.append(0.0)
        return (graphs, np.asarray(targets, np.float32),
                np.asarray(groups, np.int32), np.asarray(valid, np.float32))

    def encode_draw(self, draw: tuple, *, spec=None) -> TileBatch:
        graphs, targets, groups, valid = draw
        gb = _encode(graphs, self.adjacency, self.max_nodes, self.normalizer,
                     spec=spec)
        return TileBatch(gb, targets, groups, valid)


class BalancedSampler(_ShardedSampler):
    """Fusion-task sampler: batch of kernels balanced across programs."""

    _what = "fusion"

    def __init__(self, records, normalizer: FeatureNormalizer, *,
                 batch_size: int = 64, max_nodes: int = 64, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1,
                 adjacency: str = "dense"):
        self.normalizer = normalizer
        self.batch_size = batch_size
        self.max_nodes = max_nodes
        self.adjacency = adjacency
        self._init_shard(records, seed=seed, host_id=host_id,
                         num_hosts=num_hosts, what=self._what)

    def draw(self, step: int) -> tuple:
        """The step's raw draw: (graphs, targets, valid) before encoding."""
        rng = self._rng(step)
        graphs, targets = [], []
        for _ in range(self.batch_size):
            prog = self._programs[int(rng.integers(len(self._programs)))]
            rec = self.records[int(rng.choice(self._by_program[prog]))]
            graphs.append(rec.kernel)
            targets.append(rec.runtime)
        return (graphs, np.asarray(targets, np.float32),
                np.ones((len(graphs),), np.float32))

    def encode_draw(self, draw: tuple, *, spec=None) -> FusionBatch:
        graphs, targets, valid = draw
        gb = _encode(graphs, self.adjacency, self.max_nodes, self.normalizer,
                     spec=spec)
        return FusionBatch(gb, targets, valid)


class GlobalBatchSampler:
    """Stacks the per-shard sub-batches of `dp` sampler views into ONE
    global batch with a leading device axis — the input contract of the
    mesh train step (DESIGN.md §13).

    Every field of the delivered batch has shape ``[dp, ...]``; the mesh
    step shards that leading axis over the data mesh axis, so device d
    trains on shard-d's sub-batch. For ``adjacency='sparse'`` the dp draws
    are encoded against ONE shared `BucketSpec` (the per-field max of the
    shards' pow2 buckets), so a single compiled executable serves all
    devices; graph capacity is identical across shards by construction
    (fixed per-step draw counts).

    `batch(step)` stays a pure function of (seed, step, shard ids), so the
    wrapper composes with `repro.data.prefetch.Prefetcher` unchanged and a
    1-shard global stream is the base sampler's stream with a length-1
    leading axis — nothing else differs, which is what the dp=1
    bit-parity gate in benchmarks/bench_scaling.py checks end to end.
    """

    def __init__(self, samplers):
        if not samplers:
            raise ValueError("GlobalBatchSampler needs >= 1 sampler")
        kinds = {type(s) for s in samplers}
        if len(kinds) > 1:
            raise ValueError(f"mixed sampler types {kinds}")
        adjs = {s.adjacency for s in samplers}
        if len(adjs) > 1:
            raise ValueError(f"mixed adjacencies {adjs}")
        if samplers[0].adjacency == "segmented":
            raise ValueError("segmented batches are not mesh-shardable "
                             "(no uniform leading axis) — use adjacency="
                             "'dense' or 'sparse' for data-parallel "
                             "training")
        self.samplers = list(samplers)
        self.adjacency = samplers[0].adjacency

    @classmethod
    def for_mesh(cls, sampler, dp: int) -> "GlobalBatchSampler":
        """dp per-device views of `sampler`: its own host shard is
        subdivided dp ways (host h of H, device d → global worker
        ``h·dp + d`` of ``H·dp``), so multi-host × multi-device layouts
        compose and every record still belongs to exactly one worker."""
        return cls([sampler.with_host(sampler.host_id * dp + d,
                                      sampler.num_hosts * dp)
                    for d in range(dp)])

    @property
    def num_shards(self) -> int:
        return len(self.samplers)

    @property
    def batch_size(self) -> int:       # per-device sub-batch size
        return self.samplers[0].batch_size

    def batch(self, step: int):
        draws = [s.draw(step) for s in self.samplers]
        spec = None
        if self.adjacency == "sparse":
            specs = [sparse_draw_spec(d[0]) for d in draws]
            spec = batching.BucketSpec(
                node_capacity=max(s.node_capacity for s in specs),
                edge_capacity=max(s.edge_capacity for s in specs),
                graph_capacity=max(s.graph_capacity for s in specs),
                reduce_capacity=max(s.reduce_capacity for s in specs))
        parts = [s.encode_draw(d, spec=spec)
                 for s, d in zip(self.samplers, draws)]
        return _stack_batches(parts)


def _stack_batches(parts):
    """Stack equally-shaped sub-batches leaf-wise into a [dp, ...] batch.
    Works on the batch dataclasses directly (numpy, no jax import) so the
    Prefetcher worker thread can run it too."""
    b0 = parts[0]
    kw = {}
    for f in dataclasses.fields(b0):
        vals = [getattr(p, f.name) for p in parts]
        if dataclasses.is_dataclass(vals[0]):        # the graphs pytree
            g0 = vals[0]
            kw[f.name] = type(g0)(**{
                gf.name: np.stack([np.asarray(getattr(v, gf.name))
                                   for v in vals])
                for gf in dataclasses.fields(g0)})
        else:
            kw[f.name] = np.stack([np.asarray(v) for v in vals])
    return type(b0)(**kw)


class ShardPlanner:
    """Deterministic shard→host assignment with straggler takeover.

    Each step has `num_hosts` shards. Healthy path: shard i → host i. When
    hosts are flagged slow, their shards are deterministically reassigned to
    the healthy host with the fewest shards (ties broken by host id), so all
    data is still consumed exactly once per step.
    """

    def __init__(self, num_hosts: int):
        self.num_hosts = num_hosts

    def plan(self, step: int, slow_hosts: frozenset[int] = frozenset()
             ) -> dict[int, list[int]]:
        healthy = [h for h in range(self.num_hosts) if h not in slow_hosts]
        if not healthy:
            raise RuntimeError("no healthy hosts")
        assign: dict[int, list[int]] = {h: [] for h in healthy}
        for shard in range(self.num_hosts):
            if shard in slow_hosts:
                tgt = min(healthy, key=lambda h: (len(assign[h]), h))
            else:
                tgt = shard
            assign[tgt].append(shard)
        return assign
