"""Balanced, deterministic, shard-aware batch sampling.

* Balanced: the paper draws examples evenly per program ("model type") to
  counter corpus imbalance; we sample programs uniformly, then kernels.
* Deterministic: the batch at step k is a pure function of (seed, step,
  host shard) — a preempted-and-restarted worker reproduces its exact batch
  stream, which the fault-tolerance tests rely on.
* Shard-aware: with H data-parallel hosts, host h draws the h-th shard of
  each step's batch. `ShardPlanner` reassigns shards away from hosts flagged
  as stragglers (deterministically), so a slow host's work is taken over by
  backups without coordination.

Both samplers encode each draw with `adjacency='dense'` (padded GraphBatch,
truncated at max_nodes) or `adjacency='sparse'` (packed SparseGraphBatch —
no per-graph padding or truncation; capacities pow2-bucketed so jit sees a
bounded set of shapes). See DESIGN.md §4.

Because `batch(step)` is pure, both samplers compose with
`repro.data.prefetch.Prefetcher` (encode-ahead on a background thread;
`TrainerConfig.prefetch` enables it) without changing the batch stream,
and every draw's structural encode is served by the `features.EncodeCache`
— a tile sweep re-encodes only the tile sub-vector (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core import features as F
from repro.core.features import FeatureNormalizer, GraphBatch, encode_batch
from repro.data import batching


@dataclass
class TileBatch:
    graphs: object           # GraphBatch | SparseGraphBatch
    targets: np.ndarray      # [B] seconds
    group_ids: np.ndarray    # [B] int32 — same kernel => same group
    valid: np.ndarray        # [B] float32


@dataclass
class FusionBatch:
    graphs: object           # GraphBatch | SparseGraphBatch
    targets: np.ndarray      # [B] seconds
    valid: np.ndarray        # [B] float32


def _program_index(records) -> dict[str, list[int]]:
    """record index -> per-program draw lists. A `StreamingCorpus` (or any
    sequence exposing `record_programs`) is indexed from its manifest
    metadata alone — no shard is decoded until a batch actually draws
    from it, which is what keeps store-backed sampling shard-by-shard."""
    programs = getattr(records, "record_programs", None)
    if programs is None:
        programs = [r.program for r in records]
    by_program: dict[str, list[int]] = {}
    for i, p in enumerate(programs):
        by_program.setdefault(p, []).append(i)
    return by_program


def _encode(graphs, adjacency: str, max_nodes: int, normalizer):
    """Encode a drawn graph list with the configured representation.

    dense     — `features.encode_batch`, one padded [N, N] slot per graph.
    sparse    — `batching.encode_packed`, the whole draw packed into one
                flat node/edge buffer with pow2-bucketed capacities, so
                only a few shapes reach jit (slot order == draw order, so
                targets/groups line up unchanged).
    segmented — `batching.encode_segmented`, for whole-program graphs of
                any size: each graph split into ≤ max_nodes segments,
                owned-node embeddings reassembled before readout
                (DESIGN.md §12). Slot order == draw order here too.
    """
    if adjacency == "dense":
        return encode_batch(graphs, max_nodes, normalizer)
    if adjacency == "sparse":
        # graph capacity stays EXACT (the per-step draw count is fixed, so
        # jit still sees one G): padded graph slots would dilute losses
        # normalized by slot count (pairwise_rank_loss's n(n-1)/2) relative
        # to an identical dense run
        spec = dataclasses.replace(batching.bucket_for(graphs),
                                   graph_capacity=len(graphs))
        return batching.encode_packed(graphs, normalizer, spec=spec)
    if adjacency == "segmented":
        return batching.encode_segmented(graphs, max_nodes, normalizer)
    raise ValueError(f"unknown adjacency {adjacency!r}")


class TileBatchSampler:
    """Yields batches of (kernel, tile) samples grouped for the rank loss."""

    def __init__(self, records, normalizer: FeatureNormalizer, *,
                 kernels_per_batch: int = 4, configs_per_kernel: int = 16,
                 max_nodes: int = 64, seed: int = 0, host_id: int = 0,
                 num_hosts: int = 1, adjacency: str = "dense"):
        if not records:
            raise ValueError("empty tile dataset")
        self.records = records
        self.normalizer = normalizer
        self.kernels_per_batch = kernels_per_batch
        self.configs_per_kernel = configs_per_kernel
        self.max_nodes = max_nodes
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.adjacency = adjacency
        self._by_program = _program_index(records)
        self._programs = sorted(self._by_program)

    @property
    def batch_size(self) -> int:
        return self.kernels_per_batch * self.configs_per_kernel

    def batch(self, step: int) -> TileBatch:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        graphs, targets, groups, valid = [], [], [], []
        for ki in range(self.kernels_per_batch):
            prog = self._programs[int(rng.integers(len(self._programs)))]
            rec = self.records[int(rng.choice(self._by_program[prog]))]
            rec.kernel.structural_digest()   # memoize node digests + edge
            rec.kernel.unique_edges()        # set once: every with_tile
            #   draw below shares them, so the encode cache's key costs one
            #   top-level hash per variant and the sparse pack-sizing pass
            #   (bucket_for's edge counts) reuses one edge list
            n_cfg = len(rec.tiles)
            take = min(self.configs_per_kernel, n_cfg)
            idx = rng.choice(n_cfg, take, replace=False)
            for j in idx:
                graphs.append(rec.kernel.with_tile(rec.tiles[int(j)]))
                targets.append(float(rec.runtimes[int(j)]))
                groups.append(ki)
                valid.append(1.0)
            if take < self.configs_per_kernel:                # pad group
                # one shared graph object for every pad slot (valid=0.0):
                # it is encoded once, not re-encoded per slot
                pad_graph = rec.kernel.with_tile(rec.tiles[0])
                for _ in range(self.configs_per_kernel - take):
                    graphs.append(pad_graph)
                    targets.append(float(rec.runtimes[0]))
                    groups.append(ki)
                    valid.append(0.0)
        gb = _encode(graphs, self.adjacency, self.max_nodes, self.normalizer)
        return TileBatch(gb, np.asarray(targets, np.float32),
                         np.asarray(groups, np.int32),
                         np.asarray(valid, np.float32))


class BalancedSampler:
    """Fusion-task sampler: batch of kernels balanced across programs."""

    def __init__(self, records, normalizer: FeatureNormalizer, *,
                 batch_size: int = 64, max_nodes: int = 64, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1,
                 adjacency: str = "dense"):
        if not records:
            raise ValueError("empty fusion dataset")
        self.records = records
        self.normalizer = normalizer
        self.batch_size = batch_size
        self.max_nodes = max_nodes
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.adjacency = adjacency
        self._by_program = _program_index(records)
        self._programs = sorted(self._by_program)

    def batch(self, step: int) -> FusionBatch:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        graphs, targets = [], []
        for _ in range(self.batch_size):
            prog = self._programs[int(rng.integers(len(self._programs)))]
            rec = self.records[int(rng.choice(self._by_program[prog]))]
            graphs.append(rec.kernel)
            targets.append(rec.runtime)
        gb = _encode(graphs, self.adjacency, self.max_nodes, self.normalizer)
        return FusionBatch(gb, np.asarray(targets, np.float32),
                           np.ones((len(graphs),), np.float32))


class ShardPlanner:
    """Deterministic shard→host assignment with straggler takeover.

    Each step has `num_hosts` shards. Healthy path: shard i → host i. When
    hosts are flagged slow, their shards are deterministically reassigned to
    the healthy host with the fewest shards (ties broken by host id), so all
    data is still consumed exactly once per step.
    """

    def __init__(self, num_hosts: int):
        self.num_hosts = num_hosts

    def plan(self, step: int, slow_hosts: frozenset[int] = frozenset()
             ) -> dict[int, list[int]]:
        healthy = [h for h in range(self.num_hosts) if h not in slow_hosts]
        if not healthy:
            raise RuntimeError("no healthy hosts")
        assign: dict[int, list[int]] = {h: [] for h in healthy}
        for shard in range(self.num_hosts):
            if shard in slow_hosts:
                tgt = min(healthy, key=lambda h: (len(assign[h]), h))
            else:
                tgt = shard
            assign[tgt].append(shard)
        return assign
