"""Data substrate: program corpus generation, fusion machinery, tile/fusion
dataset construction, splits, balanced batch sampling, and the sharded
on-disk corpus store (docs/DATA.md).

Exports resolve lazily (PEP 562): importing `repro.data` (or any
submodule, e.g. `repro.data.store` inside a corpus-builder worker) does
NOT pull in the encoding/batching stack — `repro.core.features` registers
pytrees with jax at import time, and the builder fans work across
processes that never need jax. Touching a batching/sampling/prefetch name
triggers the real import on first use.
"""
import importlib

_EXPORTS = {
    # fusion machinery (numpy-only)
    "FusionDecision": "repro.data.fusion",
    "apply_fusion": "repro.data.fusion",
    "default_fusion": "repro.data.fusion",
    "fusable_edges": "repro.data.fusion",
    "random_fusion": "repro.data.fusion",
    # synthetic corpus (numpy-only)
    "FAMILIES": "repro.data.synthetic",
    "corpus_plan": "repro.data.synthetic",
    "generate_corpus": "repro.data.synthetic",
    "generate_program": "repro.data.synthetic",
    "random_kernel": "repro.data.synthetic",
    # datasets + splits (numpy-only)
    "enumerate_tiles": "repro.data.tile_dataset",
    "build_tile_dataset": "repro.data.tile_dataset",
    "build_tile_records": "repro.data.tile_dataset",
    "build_fusion_dataset": "repro.data.fusion_dataset",
    "build_fusion_records": "repro.data.fusion_dataset",
    "split_programs": "repro.data.corpus",
    "kernel_hash": "repro.data.corpus",
    # on-disk corpus store (numpy-only)
    "CorpusWriter": "repro.data.store",
    "StreamingCorpus": "repro.data.store",
    "load_manifest": "repro.data.store",
    "write_corpus": "repro.data.store",
    # encoding/batching/sampling stack (imports jax via core.features)
    "BucketSpec": "repro.data.batching",
    "bucket_for": "repro.data.batching",
    "encode_packed": "repro.data.batching",
    "iter_packed_batches": "repro.data.batching",
    "pack_graphs": "repro.data.batching",
    "Prefetcher": "repro.data.prefetch",
    "BalancedSampler": "repro.data.sampler",
    "TileBatchSampler": "repro.data.sampler",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value      # cache: next access skips __getattr__
        return value
    try:                             # `repro.data.sampler`-style access
        return importlib.import_module(f"{__name__}.{name}")
    except ModuleNotFoundError as e:
        if e.name != f"{__name__}.{name}":
            raise                    # real dependency failure inside the
                                     # submodule (e.g. jax missing)
        raise AttributeError(
            f"module 'repro.data' has no attribute {name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(__all__))
