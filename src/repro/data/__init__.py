"""Data substrate: program corpus generation, fusion machinery, tile/fusion
dataset construction, splits, and balanced batch sampling."""
from repro.data.fusion import (
    FusionDecision,
    apply_fusion,
    default_fusion,
    fusable_edges,
    random_fusion,
)
from repro.data.batching import (
    BucketSpec,
    bucket_for,
    encode_packed,
    iter_packed_batches,
    pack_graphs,
)
from repro.data.prefetch import Prefetcher
from repro.data.synthetic import FAMILIES, generate_corpus, generate_program,\
    random_kernel
from repro.data.tile_dataset import enumerate_tiles, build_tile_dataset
from repro.data.fusion_dataset import build_fusion_dataset
from repro.data.corpus import split_programs, kernel_hash
from repro.data.sampler import BalancedSampler, TileBatchSampler

__all__ = [
    "FusionDecision", "apply_fusion", "default_fusion", "fusable_edges",
    "random_fusion", "FAMILIES", "generate_corpus", "generate_program",
    "random_kernel",
    "enumerate_tiles", "build_tile_dataset", "build_fusion_dataset",
    "split_programs", "kernel_hash", "BalancedSampler", "TileBatchSampler",
    "BucketSpec", "bucket_for", "encode_packed", "iter_packed_batches",
    "pack_graphs", "Prefetcher",
]
