"""Corpus assembly: structural kernel hashing and train/val/test splits.

Two split strategies (paper §4):
  * random — programs partitioned randomly,
  * manual — whole program *families* held out of training, chosen for
    subjective dissimilarity (here: convdraw + embedding, the analogues of
    the paper's hardest holdouts).
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.core.graph import KernelGraph

MANUAL_TEST_FAMILIES = ("convdraw", "embedding")
MANUAL_VAL_FAMILIES = ("norm",)


def kernel_hash(g: KernelGraph) -> str:
    h = hashlib.sha1()
    for n in g.nodes:
        h.update(n.op.name.encode())
        h.update(repr((n.shape, n.dtype_bytes, n.inputs, n.is_output,
                       n.contract_dim, n.filter_size,
                       n.reduced_dims)).encode())
    h.update(repr(g.tile_size).encode())
    return h.hexdigest()


def family_of(program_name: str) -> str:
    return program_name.rsplit("_", 1)[0]


def split_programs(program_names: list[str], *, method: str = "random",
                   seed: int = 0, val_frac: float = 0.1,
                   test_frac: float = 0.1) -> dict[str, list[str]]:
    """Returns {'train': [...], 'val': [...], 'test': [...]} program names."""
    names = sorted(set(program_names))
    if method == "random":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(names))
        n_test = max(1, int(round(test_frac * len(names))))
        n_val = max(1, int(round(val_frac * len(names))))
        test = [names[i] for i in perm[:n_test]]
        val = [names[i] for i in perm[n_test:n_test + n_val]]
        train = [names[i] for i in perm[n_test + n_val:]]
        return {"train": sorted(train), "val": sorted(val),
                "test": sorted(test)}
    if method == "manual":
        test = [n for n in names if family_of(n) in MANUAL_TEST_FAMILIES]
        val = [n for n in names if family_of(n) in MANUAL_VAL_FAMILIES]
        train = [n for n in names
                 if n not in set(test) and n not in set(val)]
        return {"train": train, "val": val, "test": test}
    raise ValueError(f"unknown split method {method!r}")


def filter_by_programs(records, names: list[str]):
    name_set = set(names)
    return [r for r in records if r.program in name_set]
