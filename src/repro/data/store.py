"""Content-addressed, sharded on-disk corpus store (DESIGN.md §11,
docs/DATA.md).

Every trainer, benchmark and autotuner in this repo used to regenerate its
corpus (synthetic families + jaxpr-imported architectures, labeled by the
simulator oracle) in RAM on every run. This module makes a corpus a
*durable artifact*:

* `CorpusWriter` — streams records into numbered npz shards
  (``shard-00000.npz`` …) under one directory, deduplicating by the
  kernels' `canonical_hash` content address, then writes a
  ``manifest.json`` with per-shard sha256 checksums, a per-record
  program/family index, dedup stats and a deterministic `manifest_hash`
  over all of it. Same records in ⇒ byte-identical shards and manifest
  out (npz and JSON are both reproducible), so rebuilding an unchanged
  spec is a manifest-hash no-op.
* `StreamingCorpus` — a lazy, read-only sequence over a stored corpus.
  The manifest alone provides ``len``, `record_programs` and split
  metadata, so samplers index the corpus without touching a shard;
  record access decodes one shard at a time through a small LRU
  (``max_cached_shards``) — the full corpus is never materialized.
  Records round-trip exactly (float64 runtimes bit-for-bit), so the
  existing samplers and the `repro.data.prefetch.Prefetcher` produce
  byte-identical batch streams from a store and from the in-memory
  records it was written from, and `batch(step)` purity keeps the
  stream seek/resume-able.

A shard is a single ``.npz`` with two entries: ``records`` (the UTF-8
JSON record payloads — graphs via `KernelGraph.to_dict`, tile sweeps,
program labels, dedup keys) and ``runtimes`` (one concatenated float64
block, sliced per record on read — JSON never touches the label floats).

`python -m repro.launch.build_corpus` fans corpus *generation* across
worker processes into a store; `benchmarks/common.py` builds its world
once and reloads it from a store keyed by spec hash.

>>> import tempfile
>>> from repro.data.fusion_dataset import FusionKernelRecord
>>> from repro.data.store import StreamingCorpus, write_corpus
>>> from repro.data.synthetic import random_kernel
>>> recs = [FusionKernelRecord(random_kernel(8, seed=s), 1e-5 * (s + 1),
...                            program=f"mlp_{s}") for s in range(3)]
>>> d = tempfile.mkdtemp()
>>> m = write_corpus(d, "fusion", recs + recs[:1])   # one duplicate
>>> (m["stats"]["records"], m["stats"]["duplicates_dropped"])
(3, 1)
>>> c = StreamingCorpus.open(d)
>>> (len(c), c.record_programs)
(3, ['mlp_0', 'mlp_1', 'mlp_2'])
>>> c[1].runtime == recs[1].runtime                  # exact float64
True
>>> write_corpus(tempfile.mkdtemp(), "fusion",       # deterministic
...              recs)["manifest_hash"] == write_corpus(
...     tempfile.mkdtemp(), "fusion", recs)["manifest_hash"]
True
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
import shutil
from collections import OrderedDict
from typing import Iterable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.data.corpus import family_of
from repro.data.fusion_dataset import FusionKernelRecord
from repro.data.tile_dataset import TileKernelRecord

FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"
_SHARD_FMT = "shard-{:05d}.npz"
_DELTA_MANIFEST_FMT = "delta-{:05d}.json"
_DELTA_SHARD_FMT = "delta-{:05d}-{:05d}.npz"
_DELTA_MANIFEST_RE = re.compile(r"^delta-(\d{5})\.json$")

KINDS = ("tile", "fusion")


class CorpusFormatError(Exception):
    """Raised for malformed, truncated, or checksum-mismatched stores."""


# ----------------------------------------------------------------------------
# Hashing
# ----------------------------------------------------------------------------
def _canonical_json(obj) -> bytes:
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def spec_hash(spec: dict) -> str:
    """Short stable identity of a build spec (the cached-corpus key)."""
    return hashlib.sha256(_canonical_json(spec)).hexdigest()[:16]


def manifest_hash(manifest: dict) -> str:
    """Hash of everything in the manifest except the hash field itself —
    shard checksums, record index, spec, stats. Two builds of the same
    corpus agree on it; any content change flips it."""
    clean = {k: v for k, v in manifest.items() if k != "manifest_hash"}
    return hashlib.sha256(_canonical_json(clean)).hexdigest()


def record_key(record) -> str:
    """Content-addressed dedup key of one record.

    Fusion records: the kernel's ``canonical_hash(order_sensitive=True)``
    (structure + node order + tile — node order matters to the LSTM
    reduction, so order-insensitive dedup could merge records a model
    distinguishes). Tile records additionally fold in the tile sweep, so
    the same kernel measured under two different sweeps is two records.
    Labels (``program``/``name``) are deliberately excluded, exactly like
    the serving cache key.
    """
    base = record.kernel.canonical_hash(order_sensitive=True)
    tiles = getattr(record, "tiles", None)
    if tiles is None:
        return base
    h = hashlib.blake2b(digest_size=16)
    h.update(base.encode())
    h.update(repr([tuple(int(x) for x in t) for t in tiles]).encode())
    return h.hexdigest()


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


# ----------------------------------------------------------------------------
# Record <-> payload
# ----------------------------------------------------------------------------
def pack_record(kind: str, record) -> dict:
    """Serialize one dataset record to its transit form: the payload
    already encoded as canonical JSON text plus the dedup/index metadata
    and the float64 runtimes as a list.

    Encoding to JSON *here* (in the builder worker) rather than at shard-
    write time matters: the merging parent only joins strings, so on a
    host where it competes with its own workers for cores the merge stays
    off the critical path — and strings pickle across the process
    boundary much faster than nested dicts. Shard bytes are identical
    either way (canonical separators + sorted keys). Runtimes live in the
    shard's binary block, never as JSON text.
    """
    if kind == "tile":
        runtimes = np.asarray(record.runtimes, np.float64)
        payload = {"kernel": record.kernel.to_dict(),
                   "tiles": [list(map(int, t)) for t in record.tiles],
                   "program": record.program,
                   "kernel_id": int(record.kernel_id)}
    elif kind == "fusion":
        runtimes = np.asarray([record.runtime], np.float64)
        payload = {"kernel": record.kernel.to_dict(),
                   "program": record.program}
    else:
        raise ValueError(f"unknown corpus kind {kind!r}")
    payload["key"] = record_key(record)
    payload["samples"] = int(runtimes.shape[0])
    return {"json": json.dumps(payload, sort_keys=True,
                               separators=(",", ":")),
            "key": payload["key"], "program": payload["program"],
            "samples": payload["samples"], "runtimes": runtimes.tolist()}


def unpack_record(kind: str, payload: dict, runtimes: np.ndarray):
    """Inverse of `pack_record` (runtimes: float64 [payload['samples']])."""
    kernel = KernelGraph.from_dict(payload["kernel"])
    if kind == "tile":
        return TileKernelRecord(
            kernel=kernel,
            tiles=[tuple(t) for t in payload["tiles"]],
            runtimes=np.asarray(runtimes, np.float64),
            program=payload["program"],
            kernel_id=int(payload.get("kernel_id", -1)))
    return FusionKernelRecord(kernel=kernel,
                              runtime=float(runtimes[0]),
                              program=payload["program"])


# ----------------------------------------------------------------------------
# Writer
# ----------------------------------------------------------------------------
class CorpusWriter:
    """Streams records into a sharded store; atomic at the directory level.

    Shards and the manifest are written into a hidden ``.tmp-<pid>``
    sibling and moved over `out_dir` in one rename at `finalize()` — a
    killed build never leaves a half-written corpus behind. Records are
    deduplicated on their `record_key` as they arrive (first occurrence
    wins, insertion order preserved), so merging per-worker outputs in a
    fixed task order yields the same store no matter how the work was
    partitioned.
    """

    def __init__(self, out_dir: str, kind: str, *, spec: dict | None = None,
                 shard_records: int = 256, dedup: bool = True):
        if kind not in KINDS:
            raise ValueError(f"unknown corpus kind {kind!r}")
        if shard_records < 1:
            raise ValueError("shard_records must be >= 1")
        self.out_dir = out_dir
        self.kind = kind
        self.spec = spec or {}
        self.shard_records = int(shard_records)
        self.dedup = dedup
        self._tmp = out_dir.rstrip("/\\") + f".tmp-{os.getpid()}"
        if os.path.exists(self._tmp):
            shutil.rmtree(self._tmp)
        os.makedirs(self._tmp)
        self._seen: set[str] = set()
        self._buf: list[dict] = []          # packed records awaiting a shard
        self._shards: list[dict] = []
        self._index: list[dict] = []
        self._dropped = 0
        self._finalized = False

    # -- adding ------------------------------------------------------------
    def add(self, record) -> bool:
        """Add one dataset record; returns False if deduplicated away."""
        return self.add_packed(pack_record(self.kind, record))

    def add_packed(self, packed: dict) -> bool:
        """Add one `pack_record` output (the worker-transit form)."""
        if self.dedup:
            if packed["key"] in self._seen:
                self._dropped += 1
                return False
            self._seen.add(packed["key"])
        self._buf.append(packed)
        if len(self._buf) >= self.shard_records:
            self._flush_shard()
        return True

    def add_many(self, records: Iterable) -> int:
        return sum(self.add(r) for r in records)

    # -- shard + manifest emission -----------------------------------------
    def _flush_shard(self) -> None:
        if not self._buf:
            return
        runtimes = np.concatenate(
            [np.asarray(p["runtimes"], np.float64) for p in self._buf])
        fname = _SHARD_FMT.format(len(self._shards))
        path = os.path.join(self._tmp, fname)
        # payloads are pre-encoded canonical JSON objects (pack_record);
        # joining them IS the canonical dump of the payload list
        blob = ("[" + ",".join(p["json"] for p in self._buf)
                + "]").encode("utf-8")
        with open(path, "wb") as f:
            np.savez(f, records=np.frombuffer(blob, np.uint8),
                     runtimes=runtimes)
        self._shards.append({
            "file": fname, "sha256": _sha256_file(path),
            "records": len(self._buf),
            "samples": int(sum(p["samples"] for p in self._buf)),
        })
        self._index.extend({"program": p["program"], "key": p["key"],
                            "samples": p["samples"]} for p in self._buf)
        self._buf = []

    def finalize(self) -> dict:
        """Flush the tail shard, write the manifest, move into place.
        Returns the manifest dict."""
        if self._finalized:
            raise RuntimeError("CorpusWriter already finalized")
        self._flush_shard()
        families: dict[str, int] = {}
        programs: set[str] = set()
        for e in self._index:
            families[family_of(e["program"])] = \
                families.get(family_of(e["program"]), 0) + 1
            programs.add(e["program"])
        manifest = {
            "format_version": FORMAT_VERSION,
            "kind": self.kind,
            "spec": self.spec,
            "spec_hash": spec_hash(self.spec),
            "shards": self._shards,
            "index": self._index,
            "stats": {
                "records": len(self._index),
                "samples": int(sum(e["samples"] for e in self._index)),
                "duplicates_dropped": self._dropped,
                "families": dict(sorted(families.items())),
                "programs": sorted(programs),
            },
        }
        manifest["manifest_hash"] = manifest_hash(manifest)
        with open(os.path.join(self._tmp, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, sort_keys=True, indent=1)
        if os.path.exists(self.out_dir):
            if not _looks_like_store(self.out_dir):
                raise CorpusFormatError(
                    f"{self.out_dir} exists and is not a corpus store; "
                    "refusing to overwrite")
            shutil.rmtree(self.out_dir)
        os.makedirs(os.path.dirname(os.path.abspath(self.out_dir)),
                    exist_ok=True)
        os.replace(self._tmp, self.out_dir)
        self._finalized = True
        return manifest

    def abort(self) -> None:
        shutil.rmtree(self._tmp, ignore_errors=True)

    # -- delta shards (the data-flywheel append path, DESIGN.md §15) --------
    @classmethod
    def append_delta(cls, store_dir: str, records: Sequence, *,
                     shard_records: int = 256, note: str = "") -> dict | None:
        """Append `records` to a finalized store as one **delta shard set**
        without rewriting the base: ``delta-00000-00000.npz`` … files plus
        a chained ``delta-00000.json`` manifest.

        Chaining: each delta manifest records the base's `manifest_hash`
        plus ``prev_hash`` — the previous delta's `manifest_hash` (the base
        hash for the first delta). `load_delta_manifests` re-verifies the
        whole chain on read, so a delta written against a different base,
        an out-of-order replay, or a gap in the sequence all raise
        `CorpusFormatError` instead of silently merging.

        Records are deduplicated (first occurrence wins) against the base
        index, every prior delta, and within the batch — the same
        `record_key` content address the base writer uses — so re-measuring
        a kernel the corpus already holds is a no-op. Returns the delta
        manifest, or ``None`` when every record was a duplicate (nothing is
        written). Shard files land first and the manifest is renamed into
        place last, so a crash mid-append leaves at worst orphan ``.npz``
        files that the chain loader never sees (single writer assumed).
        """
        base = load_manifest(store_dir)
        if base is None:
            raise CorpusFormatError(
                f"no readable corpus manifest in {store_dir}; "
                "append_delta needs a finalized base store")
        deltas = load_delta_manifests(store_dir, base)
        kind = base["kind"]
        seen = {e["key"] for e in base["index"]}
        for d in deltas:
            seen.update(e["key"] for e in d["index"])
        packed, dropped = [], 0
        for r in records:
            p = pack_record(kind, r)
            if p["key"] in seen:
                dropped += 1
                continue
            seen.add(p["key"])
            packed.append(p)
        if not packed:
            return None
        seq = len(deltas)
        shards: list[dict] = []
        index: list[dict] = []
        for lo in range(0, len(packed), int(shard_records)):
            chunk = packed[lo:lo + int(shard_records)]
            fname = _DELTA_SHARD_FMT.format(seq, len(shards))
            path = os.path.join(store_dir, fname)
            tmp = path + f".tmp-{os.getpid()}"
            runtimes = np.concatenate(
                [np.asarray(p["runtimes"], np.float64) for p in chunk])
            blob = ("[" + ",".join(p["json"] for p in chunk)
                    + "]").encode("utf-8")
            with open(tmp, "wb") as f:
                np.savez(f, records=np.frombuffer(blob, np.uint8),
                         runtimes=runtimes)
            os.replace(tmp, path)
            shards.append({
                "file": fname, "sha256": _sha256_file(path),
                "records": len(chunk),
                "samples": int(sum(p["samples"] for p in chunk)),
            })
            index.extend({"program": p["program"], "key": p["key"],
                          "samples": p["samples"]} for p in chunk)
        manifest = {
            "format_version": FORMAT_VERSION,
            "kind": kind,
            "delta_seq": seq,
            "base_manifest_hash": base["manifest_hash"],
            "prev_hash": (deltas[-1]["manifest_hash"] if deltas
                          else base["manifest_hash"]),
            "shards": shards,
            "index": index,
            "note": note,
            "stats": {
                "records": len(index),
                "samples": int(sum(e["samples"] for e in index)),
                "duplicates_dropped": dropped,
                "programs": sorted({e["program"] for e in index}),
            },
        }
        manifest["manifest_hash"] = manifest_hash(manifest)
        fname = _DELTA_MANIFEST_FMT.format(seq)
        tmp = os.path.join(store_dir, fname + f".tmp-{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump(manifest, f, sort_keys=True, indent=1)
        os.replace(tmp, os.path.join(store_dir, fname))
        return manifest


def _looks_like_store(path: str) -> bool:
    if not os.path.isdir(path):
        return False
    entries = os.listdir(path)
    return (not entries or MANIFEST_NAME in entries
            or any(e.startswith("shard-") for e in entries))


def write_corpus(out_dir: str, kind: str, records: Sequence, *,
                 spec: dict | None = None, shard_records: int = 256,
                 dedup: bool = True) -> dict:
    """One-shot write of an in-memory record list. Returns the manifest."""
    w = CorpusWriter(out_dir, kind, spec=spec, shard_records=shard_records,
                     dedup=dedup)
    try:
        w.add_many(records)
        return w.finalize()
    except BaseException:
        w.abort()
        raise


def load_manifest(path: str) -> dict | None:
    """Read `path`'s manifest, or None if absent/unreadable/wrong version."""
    try:
        with open(os.path.join(path, MANIFEST_NAME)) as f:
            m = json.load(f)
        return m if m.get("format_version") == FORMAT_VERSION else None
    except (OSError, ValueError):
        return None


def load_delta_manifests(path: str, base: dict | None = None) -> list[dict]:
    """Ordered, chain-verified delta manifests of the store at `path`.

    Verifies the full chain: contiguous ``delta_seq`` from 0, every
    ``base_manifest_hash`` equal to the base's `manifest_hash`, every
    ``prev_hash`` equal to the predecessor's `manifest_hash`, and each
    manifest's own `manifest_hash` recomputing exactly. Any break raises
    `CorpusFormatError` — a tampered or half-copied chain never loads.
    Returns ``[]`` for a store with no deltas.
    """
    if base is None:
        base = load_manifest(path)
        if base is None:
            raise CorpusFormatError(f"no readable corpus manifest in {path}")
    seqs = sorted(int(m.group(1)) for m in
                  (_DELTA_MANIFEST_RE.match(e) for e in os.listdir(path))
                  if m is not None)
    if seqs != list(range(len(seqs))):
        raise CorpusFormatError(
            f"{path}: delta chain is not contiguous from 0: {seqs}")
    out: list[dict] = []
    prev = base["manifest_hash"]
    for seq in seqs:
        fname = _DELTA_MANIFEST_FMT.format(seq)
        try:
            with open(os.path.join(path, fname)) as f:
                m = json.load(f)
        except (OSError, ValueError) as e:
            raise CorpusFormatError(f"{path}/{fname}: unreadable delta "
                                    f"manifest ({e})") from e
        if m.get("format_version") != FORMAT_VERSION:
            raise CorpusFormatError(f"{path}/{fname}: format version "
                                    f"{m.get('format_version')!r}")
        if m.get("kind") != base["kind"]:
            raise CorpusFormatError(
                f"{path}/{fname}: delta kind {m.get('kind')!r} does not "
                f"match base kind {base['kind']!r}")
        if m.get("delta_seq") != seq:
            raise CorpusFormatError(f"{path}/{fname}: delta_seq "
                                    f"{m.get('delta_seq')!r} != {seq}")
        if m.get("base_manifest_hash") != base["manifest_hash"]:
            raise CorpusFormatError(
                f"{path}/{fname}: delta was written against base "
                f"{str(m.get('base_manifest_hash'))[:12]}…, store base is "
                f"{base['manifest_hash'][:12]}…")
        if m.get("prev_hash") != prev:
            raise CorpusFormatError(
                f"{path}/{fname}: broken delta chain (prev_hash "
                f"{str(m.get('prev_hash'))[:12]}… != {prev[:12]}…)")
        if manifest_hash(m) != m.get("manifest_hash"):
            raise CorpusFormatError(f"{path}/{fname}: manifest hash "
                                    "mismatch (tampered delta manifest)")
        prev = m["manifest_hash"]
        out.append(m)
    return out


# ----------------------------------------------------------------------------
# Reader
# ----------------------------------------------------------------------------
class StreamingCorpus(Sequence):
    """Lazy random-access + shard-streaming view of a stored corpus.

    Acts as a read-only sequence of dataset records
    (`TileKernelRecord` / `FusionKernelRecord`). ``len`` and
    `record_programs` come from the manifest alone; ``corpus[i]`` decodes
    the owning shard on demand (verifying its checksum) and keeps up to
    ``max_cached_shards`` decoded shards in an LRU, so both samplers can
    draw uniformly from a corpus much larger than RAM. Iteration walks
    shard by shard in record order.
    """

    def __init__(self, path: str, manifest: dict, *,
                 max_cached_shards: int = 4):
        if max_cached_shards < 1:
            raise ValueError("max_cached_shards must be >= 1")
        self.path = path
        self.manifest = manifest
        self.kind = manifest["kind"]
        self.max_cached_shards = int(max_cached_shards)
        self._cache: OrderedDict[int, list] = OrderedDict()
        # record i lives in shard s iff bounds[s] <= i < bounds[s+1]
        self._bounds = np.cumsum(
            [0] + [s["records"] for s in manifest["shards"]])
        if int(self._bounds[-1]) != len(manifest["index"]):
            raise CorpusFormatError(
                f"{path}: manifest index has {len(manifest['index'])} "
                f"records but shards declare {int(self._bounds[-1])}")

    @classmethod
    def open(cls, path: str, *, max_cached_shards: int = 4,
             verify: bool = False) -> "StreamingCorpus":
        manifest = load_manifest(path)
        if manifest is None:
            raise CorpusFormatError(f"no readable corpus manifest in {path}")
        c = cls(path, manifest, max_cached_shards=max_cached_shards)
        if verify:
            c.verify()
        return c

    # -- manifest-only metadata (no shard decode) --------------------------
    @property
    def record_programs(self) -> list[str]:
        """Program name of every record, in record order — lets the
        samplers build their per-program index without decoding shards."""
        return [e["program"] for e in self.manifest["index"]]

    @property
    def manifest_hash(self) -> str:
        return self.manifest["manifest_hash"]

    @property
    def spec(self) -> dict:
        return self.manifest["spec"]

    @property
    def num_samples(self) -> int:
        return int(self.manifest["stats"]["samples"])

    def programs(self) -> list[str]:
        return list(self.manifest["stats"]["programs"])

    # -- record access ------------------------------------------------------
    def __len__(self) -> int:
        return len(self.manifest["index"])

    def __getitem__(self, i: int):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        n = len(self)
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError(i)
        s = int(np.searchsorted(self._bounds, i, side="right")) - 1
        return self._shard_records(s)[i - int(self._bounds[s])]

    def __iter__(self):
        for s in range(len(self.manifest["shards"])):
            yield from self._shard_records(s)

    def iter_shards(self):
        """Yield each shard's decoded record list in order — the
        sequential-scan path (build pipelines, eval sweeps)."""
        for s in range(len(self.manifest["shards"])):
            yield self._shard_records(s)

    def _shard_records(self, s: int) -> list:
        hit = self._cache.get(s)
        if hit is not None:
            self._cache.move_to_end(s)
            return hit
        records = self._decode_shard(s)
        self._cache[s] = records
        while len(self._cache) > self.max_cached_shards:
            self._cache.popitem(last=False)
        return records

    def _decode_shard(self, s: int) -> list:
        entry = self.manifest["shards"][s]
        path = os.path.join(self.path, entry["file"])
        with open(path, "rb") as f:
            raw = f.read()
        digest = hashlib.sha256(raw).hexdigest()
        if digest != entry["sha256"]:
            raise CorpusFormatError(
                f"{path}: checksum mismatch (manifest {entry['sha256'][:12]}"
                f"…, file {digest[:12]}…)")
        with np.load(io.BytesIO(raw)) as z:
            payloads = json.loads(bytes(z["records"]).decode("utf-8"))
            runtimes = z["runtimes"]
        records, off = [], 0
        for p in payloads:
            n = int(p["samples"])
            records.append(unpack_record(self.kind, p,
                                         runtimes[off:off + n]))
            off += n
        if off != runtimes.shape[0] or len(records) != entry["records"]:
            raise CorpusFormatError(f"{path}: shard contents disagree with "
                                    "manifest record/sample counts")
        return records

    # -- splits -------------------------------------------------------------
    def select_programs(self, names) -> "CorpusSubset":
        """Streaming equivalent of `data.corpus.filter_by_programs`: a lazy
        view of the records whose program is in `names` (order preserved).
        Built from the manifest index alone — nothing is decoded."""
        name_set = set(names)
        idx = [i for i, e in enumerate(self.manifest["index"])
               if e["program"] in name_set]
        return CorpusSubset(self, idx)

    # -- worker sharding ----------------------------------------------------
    def shard(self, idx: int, num: int) -> "CorpusSubset":
        """Worker `idx`'s deterministic round-robin slice of the corpus
        (records ``idx, idx+num, idx+2·num, …``), as a lazy manifest-only
        view — the `ShardableDataset.shard(idx, num)` pattern that
        data-parallel training shards the stream with.

        Shards are **disjoint** and **exhaustive**: position-interleaving
        the `num` shards reproduces the unsharded record stream
        byte-identically (``full[i] == shard(i % num, num)[i // num]``).
        ``shard(0, 1)`` is the identity view; every shard shares the
        parent's decoded-shard LRU, so co-located workers don't decode a
        file twice. Nothing is decoded by this call itself.

        >>> import tempfile
        >>> from repro.data.fusion_dataset import FusionKernelRecord
        >>> from repro.data.synthetic import random_kernel
        >>> recs = [FusionKernelRecord(random_kernel(6, seed=s), 1e-5,
        ...                            program=f"p{s}") for s in range(5)]
        >>> d = tempfile.mkdtemp()
        >>> _ = write_corpus(d, "fusion", recs)
        >>> c = StreamingCorpus.open(d)
        >>> [len(c.shard(i, 2)) for i in (0, 1)]
        [3, 2]
        >>> (c.shard(0, 2).record_programs, c.shard(1, 2).record_programs)
        (['p0', 'p2', 'p4'], ['p1', 'p3'])
        """
        _check_shard(idx, num)
        return CorpusSubset(self, range(idx, len(self), num))

    # -- delta shards --------------------------------------------------------
    def delta_manifests(self) -> list[dict]:
        """Chain-verified delta manifests appended to this store (may be
        empty). See `load_delta_manifests` for the verification rules."""
        return load_delta_manifests(self.path, self.manifest)

    def with_deltas(self, *, max_cached_shards: int | None = None
                    ) -> "ChainedCorpus":
        """Base+delta view of this store: the base records followed by
        every delta's records in chain order. Because `append_delta`
        dedups each delta against the base and all prior deltas with the
        same first-wins `record_key` rule the base writer uses, this
        stream is byte-identical to a from-scratch ``write_corpus(...,
        dedup=True)`` rebuild over the concatenated raw record streams
        (provided the base itself was written with ``dedup=True``) —
        the parity `benchmarks/bench_flywheel.py` gates on.

        >>> import tempfile
        >>> from repro.data.fusion_dataset import FusionKernelRecord
        >>> from repro.data.synthetic import random_kernel
        >>> recs = [FusionKernelRecord(random_kernel(6, seed=s), 1e-5,
        ...                            program=f"p{s}") for s in range(4)]
        >>> d = tempfile.mkdtemp()
        >>> _ = write_corpus(d, "fusion", recs[:2])
        >>> m = CorpusWriter.append_delta(d, recs[1:])   # recs[1] is a dup
        >>> (m["delta_seq"], m["stats"]["records"],
        ...  m["stats"]["duplicates_dropped"])
        (0, 2, 1)
        >>> CorpusWriter.append_delta(d, recs[:2]) is None   # all dups
        True
        >>> c = StreamingCorpus.open(d).with_deltas()
        >>> (len(c), c.record_programs)
        (4, ['p0', 'p1', 'p2', 'p3'])
        """
        mcs = (self.max_cached_shards if max_cached_shards is None
               else max_cached_shards)
        parts = [StreamingCorpus(self.path, m, max_cached_shards=mcs)
                 for m in self.delta_manifests()]
        return ChainedCorpus(self, parts)

    # -- integrity ----------------------------------------------------------
    def verify(self) -> None:
        """Recompute every shard checksum; raises CorpusFormatError on any
        mismatch or missing shard file."""
        for entry in self.manifest["shards"]:
            path = os.path.join(self.path, entry["file"])
            if not os.path.exists(path):
                raise CorpusFormatError(f"missing shard {path}")
            if _sha256_file(path) != entry["sha256"]:
                raise CorpusFormatError(f"{path}: checksum mismatch")
        if manifest_hash(self.manifest) != self.manifest["manifest_hash"]:
            raise CorpusFormatError(f"{self.path}: manifest hash mismatch")


def _check_shard(idx: int, num: int) -> None:
    if num < 1:
        raise ValueError(f"num shards must be >= 1, got {num}")
    if not 0 <= idx < num:
        raise ValueError(f"shard idx must be in [0, {num}), got {idx}")


class ChainedCorpus(Sequence):
    """Read-only base+deltas record stream (`StreamingCorpus.with_deltas`).

    A sequence of dataset records: all base records first, then each
    delta's records in chain order — exactly the first-wins dedup order a
    from-scratch rebuild would produce. Exposes the same manifest-only
    surface the samplers and `CorpusSubset` rely on (``record_programs``,
    ``manifest["index"]``, `select_programs`, `shard`), so everything
    downstream of a `StreamingCorpus` — `TileBatchSampler`,
    `BalancedSampler`, worker sharding, `launch/train.py --from-store`
    — consumes a chained view unchanged.
    """

    def __init__(self, base: StreamingCorpus,
                 deltas: Sequence[StreamingCorpus]):
        self.base = base
        self.deltas = list(deltas)
        self.parts: list[StreamingCorpus] = [base, *self.deltas]
        self.kind = base.kind
        self.path = base.path
        self._bounds = np.cumsum([0] + [len(p) for p in self.parts])
        index = [e for p in self.parts for e in p.manifest["index"]]
        self.manifest = {
            "kind": self.kind,
            "index": index,
            "stats": {
                "records": len(index),
                "samples": int(sum(e["samples"] for e in index)),
                "programs": sorted({e["program"] for e in index}),
            },
        }

    @property
    def num_deltas(self) -> int:
        return len(self.deltas)

    @property
    def chain_hash(self) -> str:
        """Deterministic identity of the full base+delta chain (changes
        whenever a delta is appended — the retrain trigger key)."""
        h = hashlib.sha256()
        for p in self.parts:
            h.update(p.manifest["manifest_hash"].encode())
        return h.hexdigest()

    @property
    def record_programs(self) -> list[str]:
        return [e["program"] for e in self.manifest["index"]]

    def programs(self) -> list[str]:
        return list(self.manifest["stats"]["programs"])

    def __len__(self) -> int:
        return int(self._bounds[-1])

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        n = len(self)
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError(i)
        s = int(np.searchsorted(self._bounds, i, side="right")) - 1
        return self.parts[s][i - int(self._bounds[s])]

    def __iter__(self):
        for p in self.parts:
            yield from p

    def select_programs(self, names) -> "CorpusSubset":
        name_set = set(names)
        idx = [i for i, e in enumerate(self.manifest["index"])
               if e["program"] in name_set]
        return CorpusSubset(self, idx)

    def shard(self, idx: int, num: int) -> "CorpusSubset":
        _check_shard(idx, num)
        return CorpusSubset(self, range(idx, len(self), num))

    def verify(self) -> None:
        """Checksum-verify the base and every delta shard (and re-verify
        the manifest chain, since construction already walked it)."""
        for p in self.parts:
            p.verify()


class CorpusSubset(Sequence):
    """Lazy index-mapped view over a `StreamingCorpus` (a train/val/test
    split or a worker shard). Shares the parent's shard LRU; exposes
    `record_programs` so the samplers index it without decoding anything."""

    def __init__(self, corpus: StreamingCorpus, indices: Sequence[int]):
        self._corpus = corpus
        self._indices = list(indices)

    @property
    def record_programs(self) -> list[str]:
        index = self._corpus.manifest["index"]
        return [index[i]["program"] for i in self._indices]

    def shard(self, idx: int, num: int) -> "CorpusSubset":
        """Round-robin sub-shard of this view (see `StreamingCorpus.shard`)
        — composes with `select_programs`, so a worker can shard its train
        split without materializing either."""
        _check_shard(idx, num)
        return CorpusSubset(self._corpus, self._indices[idx::num])

    def __len__(self) -> int:
        return len(self._indices)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        return self._corpus[self._indices[i]]
