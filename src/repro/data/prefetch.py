"""Asynchronous input pipeline: encode batches ahead of the train step.

Host-side feature encoding and the jitted device step are serialized in a
naive training loop — the accelerator idles while Python encodes the next
batch. `Prefetcher` wraps any sampler exposing ``batch(step) -> batch``
(both `repro.data.sampler` samplers qualify — including over a
`repro.data.store.StreamingCorpus` or one of its `.shard(idx, num)` worker
views, where the worker thread also absorbs shard decode latency, and the
mesh trainer's `GlobalBatchSampler`, whose [dp, ...] global batches are
plain numpy pytrees like any other) and runs it on a background thread,
keeping a
bounded queue of ready batches so encoding of step k+1 overlaps the
device work of step k.

Guarantees (DESIGN.md §9):

* **Deterministic** — the worker calls the wrapped sampler with exactly the
  step sequence the consumer asks for, so the delivered stream is
  byte-identical to calling ``sampler.batch(step)`` synchronously. Both
  samplers are pure functions of (seed, step, host), so this also holds
  across restarts.
* **Random access degrades gracefully** — the queue is filled for the
  sequential ``start_step, start_step+1, ...`` pattern the trainer uses; a
  seek (``batch(s)`` for any other step, e.g. after checkpoint resume)
  deterministically restarts the worker at ``s``.
* **Clean shutdown** — ``close()`` (or the context manager / GC finalizer)
  stops the worker promptly even if it is blocked on a full queue; worker
  exceptions surface on the consumer's next ``batch()`` call.
* **Optional device transfer overlap** — ``device_put=True`` moves the
  encoded graph pytree to the default device from the worker thread, so
  host→device copies also overlap the previous step.

>>> class Doubler:
...     def batch(self, step):
...         return step * 2
>>> with Prefetcher(Doubler(), depth=2) as p:
...     [p.batch(s) for s in (0, 1, 2)]   # sequential: served from queue
[0, 2, 4]
>>> p = Prefetcher(Doubler(), depth=2, start_step=5)
>>> p.batch(5), p.batch(0), p.batch(1)    # seek restarts deterministically
(10, 0, 2)
>>> p.close()
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import weakref

_PUT_POLL_S = 0.05       # how often a blocked worker re-checks the stop flag


class _WorkerError:
    """Wrapper marking an exception raised inside the worker thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


def _device_put_batch(batch):
    """Move the batch's graph pytree to device; other fields (targets,
    masks) stay host-side — the trainer converts them per step."""
    import jax
    if dataclasses.is_dataclass(batch) and hasattr(batch, "graphs"):
        return dataclasses.replace(batch,
                                   graphs=jax.device_put(batch.graphs))
    return jax.device_put(batch)


def _worker_loop(sampler, device_put: bool, q: queue.Queue,
                 stop: threading.Event, step: int) -> None:
    """Worker body (module-level so the thread never references the
    Prefetcher — otherwise a live worker would pin the wrapper and its GC
    finalizer could never run)."""
    while not stop.is_set():
        try:
            batch = sampler.batch(step)
            if device_put:
                batch = _device_put_batch(batch)
            item = (step, batch)
        except BaseException as exc:                      # noqa: BLE001
            item = (step, _WorkerError(exc))
        while not stop.is_set():
            try:
                q.put(item, timeout=_PUT_POLL_S)
                break
            except queue.Full:
                continue
        if isinstance(item[1], _WorkerError):
            return
        step += 1


def _shutdown(state: dict) -> None:
    """Stop a worker (shared by close() and the GC finalizer, so it must
    not reference the Prefetcher): set the stop flag, drain the queue to
    unblock a full `put`, join."""
    stop, q, thread = state["stop"], state["queue"], state["thread"]
    state["stop"] = state["queue"] = state["thread"] = None
    if stop is None:
        return
    stop.set()
    if q is not None:
        try:
            while True:
                q.get_nowait()
        except queue.Empty:
            pass
    if thread is not None and thread.is_alive():
        thread.join(timeout=5.0)


class Prefetcher:
    """Background-thread prefetch wrapper around ``sampler.batch(step)``.

    ``depth`` bounds how many encoded batches may be queued ahead (the
    host-memory budget). The wrapper is itself a sampler (same ``batch``
    contract), so it drops into `CostModelTrainer` unchanged — the trainer
    enables it via ``TrainerConfig.prefetch``.
    """

    def __init__(self, sampler, *, depth: int = 2, start_step: int = 0,
                 device_put: bool = False):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.sampler = sampler
        self.depth = int(depth)
        self.device_put = bool(device_put)
        # worker state lives in a dict shared with the finalizer so neither
        # holds a reference back to `self` (which would defeat GC cleanup)
        self._state: dict = {"stop": None, "queue": None, "thread": None}
        self._next_step: int | None = None
        self._finalizer = weakref.finalize(self, _shutdown, self._state)
        self._restart(start_step)

    def _restart(self, step: int) -> None:
        _shutdown(self._state)
        q = queue.Queue(maxsize=self.depth)
        stop = threading.Event()
        thread = threading.Thread(
            target=_worker_loop,
            args=(self.sampler, self.device_put, q, stop, step),
            name=f"prefetch-{step}", daemon=True)
        self._state.update(stop=stop, queue=q, thread=thread)
        self._next_step = step
        thread.start()

    # --- consumer API ------------------------------------------------------
    def batch(self, step: int):
        """The wrapped sampler's batch for `step` — from the queue when the
        access is sequential, via a deterministic worker restart when not."""
        if self._state["queue"] is None or step != self._next_step:
            self._restart(step)
        got_step, payload = self._state["queue"].get()
        assert got_step == step, f"prefetch stream skew: {got_step} != {step}"
        if isinstance(payload, _WorkerError):
            _shutdown(self._state)     # worker exited; next call restarts
            self._next_step = None
            raise payload.exc
        self._next_step = step + 1
        return payload

    def close(self) -> None:
        """Stop the worker and release the queue. Idempotent."""
        _shutdown(self._state)
        self._next_step = None

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
