"""Minimal neural-network library used by both the learned cost model and
the LM model zoo.

No flax/haiku in this environment, so modules are (init, apply) function
pairs over plain pytrees of jnp arrays. Parameter trees are nested dicts;
every leaf is a jnp.ndarray. All apply functions are pure.
"""
from repro.nn.core import (
    Initializer,
    dense_init,
    dense_apply,
    embedding_init,
    embedding_apply,
    layernorm_init,
    layernorm_apply,
    rmsnorm_init,
    rmsnorm_apply,
    mlp_init,
    mlp_apply,
    dropout,
    glorot,
    normal_init,
    zeros_init,
    ones_init,
    l2_normalize,
)
from repro.nn.lstm import lstm_init, lstm_apply, lstm_cell
from repro.nn.transformer import (
    encoder_init,
    encoder_apply,
    mha_init,
    mha_apply,
)

__all__ = [
    "Initializer",
    "dense_init",
    "dense_apply",
    "embedding_init",
    "embedding_apply",
    "layernorm_init",
    "layernorm_apply",
    "rmsnorm_init",
    "rmsnorm_apply",
    "mlp_init",
    "mlp_apply",
    "dropout",
    "glorot",
    "normal_init",
    "zeros_init",
    "ones_init",
    "l2_normalize",
    "lstm_init",
    "lstm_apply",
    "lstm_cell",
    "encoder_init",
    "encoder_apply",
    "mha_init",
    "mha_apply",
]
