"""Core building blocks: dense, embedding, norms, MLP, dropout, inits."""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple], jnp.ndarray]


# ----------------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------------
def glorot(rng: jax.Array, shape: tuple, dtype=jnp.float32) -> jnp.ndarray:
    """Glorot/Xavier uniform over the last two dims (or fan of whole shape)."""
    if len(shape) >= 2:
        fan_in, fan_out = shape[-2], shape[-1]
    else:
        fan_in = fan_out = shape[-1]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, dtype, -limit, limit)


def normal_init(stddev: float = 0.02):
    def init(rng, shape, dtype=jnp.float32):
        return jax.random.normal(rng, shape, dtype) * stddev

    return init


def zeros_init(rng, shape, dtype=jnp.float32):
    del rng
    return jnp.zeros(shape, dtype)


def ones_init(rng, shape, dtype=jnp.float32):
    del rng
    return jnp.ones(shape, dtype)


def l2_normalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-6) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.sum(x * x, axis=axis, keepdims=True) + eps)


# ----------------------------------------------------------------------------
# Dense
# ----------------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, *, bias: bool = True,
               w_init: Initializer = glorot, dtype=jnp.float32) -> dict:
    kw, kb = jax.random.split(rng)
    params = {"w": w_init(kw, (in_dim, out_dim), dtype)}
    if bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
    del kb
    return params


def dense_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ----------------------------------------------------------------------------
# Embedding
# ----------------------------------------------------------------------------
def embedding_init(rng, vocab: int, dim: int, *, stddev: float = 0.02,
                   dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(rng, (vocab, dim), dtype) * stddev}


def embedding_apply(params: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], ids, axis=0)


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------
def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # Compute statistics in f32 for stability regardless of activation dtype.
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)
    return y * params["scale"].astype(x.dtype)


# ----------------------------------------------------------------------------
# MLP stack (used heavily by the cost model: f1, f2^k, f3^k, heads)
# ----------------------------------------------------------------------------
def mlp_init(rng, dims: Sequence[int], *, bias: bool = False,
             w_init: Initializer = glorot, dtype=jnp.float32) -> dict:
    """A stack of Dense layers: dims = [in, h1, ..., out]."""
    layers = []
    keys = jax.random.split(rng, max(len(dims) - 1, 1))
    for i in range(len(dims) - 1):
        layers.append(
            dense_init(keys[i], dims[i], dims[i + 1], bias=bias,
                       w_init=w_init, dtype=dtype))
    return {"layers": layers}


def mlp_apply(params: dict, x: jnp.ndarray, *,
              act: Callable = jax.nn.relu, final_act: bool = False) -> jnp.ndarray:
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = dense_apply(layer, x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ----------------------------------------------------------------------------
# Dropout (explicit rng, identity when deterministic)
# ----------------------------------------------------------------------------
def dropout(rng: jax.Array | None, x: jnp.ndarray, rate: float,
            deterministic: bool) -> jnp.ndarray:
    if deterministic or rate <= 0.0 or rng is None:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(rng, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)
