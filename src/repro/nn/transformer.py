"""Transformer encoder used as a kernel-embedding reduction (paper §3.2).

Pre-norm encoder blocks with masked multi-head self-attention over node
embeddings. This is the *cost-model* transformer; the LM zoo has its own
decoder implementation under repro.models (different enough — rotary, GQA,
KV caches — that sharing would hurt clarity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import (
    dense_apply,
    dense_init,
    dropout,
    layernorm_apply,
    layernorm_init,
)


def mha_init(rng, dim: int, num_heads: int, dtype=jnp.float32) -> dict:
    assert dim % num_heads == 0, (dim, num_heads)
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "q": dense_init(kq, dim, dim, bias=False, dtype=dtype),
        "k": dense_init(kk, dim, dim, bias=False, dtype=dtype),
        "v": dense_init(kv, dim, dim, bias=False, dtype=dtype),
        "o": dense_init(ko, dim, dim, bias=False, dtype=dtype),
    }


def mha_apply(params: dict, x: jnp.ndarray, mask: jnp.ndarray | None,
              num_heads: int) -> jnp.ndarray:
    """x: [B, N, D]; mask: [B, N] validity (1=real node)."""
    B, N, D = x.shape
    H = num_heads
    hd = D // H
    q = dense_apply(params["q"], x).reshape(B, N, H, hd)
    k = dense_apply(params["k"], x).reshape(B, N, H, hd)
    v = dense_apply(params["v"], x).reshape(B, N, H, hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    if mask is not None:
        neg = jnp.finfo(logits.dtype).min
        logits = jnp.where(mask[:, None, None, :] > 0, logits, neg)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(B, N, D)
    return dense_apply(params["o"], out)


def encoder_init(rng, dim: int, num_heads: int, num_layers: int,
                 mlp_factor: int = 4, dtype=jnp.float32) -> dict:
    blocks = []
    keys = jax.random.split(rng, max(num_layers, 1))
    for i in range(num_layers):
        ka, k1, k2 = jax.random.split(keys[i], 3)
        blocks.append({
            "ln1": layernorm_init(dim, dtype),
            "attn": mha_init(ka, dim, num_heads, dtype),
            "ln2": layernorm_init(dim, dtype),
            "fc1": dense_init(k1, dim, mlp_factor * dim, bias=True, dtype=dtype),
            "fc2": dense_init(k2, mlp_factor * dim, dim, bias=True, dtype=dtype),
        })
    return {"blocks": blocks, "ln_f": layernorm_init(dim, dtype)}


def encoder_apply(params: dict, x: jnp.ndarray, mask: jnp.ndarray | None,
                  num_heads: int, *, rng=None, dropout_rate: float = 0.0,
                  deterministic: bool = True) -> jnp.ndarray:
    """Returns per-node encodings [B, N, D] (reduction handled by caller)."""
    for i, blk in enumerate(params["blocks"]):
        sub = None if rng is None else jax.random.fold_in(rng, i)
        h = mha_apply(blk["attn"], layernorm_apply(blk["ln1"], x), mask,
                      num_heads)
        h = dropout(sub, h, dropout_rate, deterministic)
        x = x + h
        h = dense_apply(blk["fc1"], layernorm_apply(blk["ln2"], x))
        h = jax.nn.gelu(h)
        h = dense_apply(blk["fc2"], h)
        x = x + h
    return layernorm_apply(params["ln_f"], x)
