"""LSTM used for the paper's sequence reduction (topo-sorted node embeddings).

Standard LSTM cell, scanned with jax.lax.scan; supports a validity mask so
padded nodes do not update the state (crucial for padded kernel graphs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import glorot


def lstm_init(rng, in_dim: int, hidden: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "wx": glorot(k1, (in_dim, 4 * hidden), dtype),
        "wh": glorot(k2, (hidden, 4 * hidden), dtype),
        "b": jnp.zeros((4 * hidden,), dtype),
    }


def lstm_cell(params: dict, carry, x: jnp.ndarray):
    """One step. carry = (h, c); x: [B, in_dim]."""
    h, c = carry
    gates = x @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f + 1.0)  # forget-gate bias init trick
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return (h_new, c_new)


def lstm_apply(params: dict, xs: jnp.ndarray,
               mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Run over sequence axis 1. xs: [B, T, in_dim]; mask: [B, T] (1=valid).

    Returns the final hidden state [B, hidden], where masked (padded) steps
    leave the state unchanged, so the "final" state is the state after the
    last *valid* element even with right-padding.
    """
    B, T, _ = xs.shape
    hidden = params["wh"].shape[0]
    h0 = jnp.zeros((B, hidden), xs.dtype)
    c0 = jnp.zeros((B, hidden), xs.dtype)

    def step(carry, inp):
        x_t, m_t = inp
        h, c = carry
        h_new, c_new = lstm_cell(params, (h, c), x_t)
        if m_t is not None:
            m = m_t[:, None]
            h_new = m * h_new + (1 - m) * h
            c_new = m * c_new + (1 - m) * c
        return (h_new, c_new), None

    xs_t = jnp.swapaxes(xs, 0, 1)  # [T, B, D]
    if mask is not None:
        mask_t = jnp.swapaxes(mask.astype(xs.dtype), 0, 1)  # [T, B]
    else:
        mask_t = jnp.ones((T, B), xs.dtype)
    (h, _), _ = jax.lax.scan(step, (h0, c0), (xs_t, mask_t))
    return h
