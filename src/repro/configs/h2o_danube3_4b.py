"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
llama+mistral mix with sliding-window attention (window 4096)
[arXiv:2401.16818]. SWA => bounded decode cache => long_500k applicable."""
from repro.models.config import ModelConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        d_model=3840, vocab_size=32000,
        num_heads=32, num_kv_heads=8, d_ff=10240,
        sliding_window=4096,
        stacks=(Stack(("swa+mlp",), 24),),
        rope_theta=1e4,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense",
        d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=2, d_ff=128,
        sliding_window=16,
        stacks=(Stack(("swa+mlp",), 2),),
        microbatch=2, block_kv=32, dtype="float32",
    )
