"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) vocab=49155,
MoE 40 experts top-8 with d_ff=512 per expert
[hf:ibm-granite family]. (The assignment's structured spec says 40 experts;
its free-text note says 32 — we follow the structured spec.) Full attention
=> long_500k skipped."""
from repro.models.config import ModelConfig, MoEConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        d_model=1536, vocab_size=49155,
        num_heads=24, num_kv_heads=8, head_dim=64, d_ff=512,
        stacks=(Stack(("attn+moe",), 32),),
        moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
        tie_embeddings=True,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke", family="moe",
        d_model=32, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=32,
        stacks=(Stack(("attn+moe",), 2),),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
        tie_embeddings=True,
        microbatch=2, block_kv=16, dtype="float32",
    )
