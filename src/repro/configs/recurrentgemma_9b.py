"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. RG-LRU + local attention (window 2048) in a 2:1 pattern
[arXiv:2402.19427]. 38 = 12×(lru,lru,attn) + 2×lru. Bounded state + window
=> long_500k applicable."""
from repro.models.config import ModelConfig, RGLRUConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        d_model=4096, vocab_size=256000,
        num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
        sliding_window=2048,
        stacks=(
            Stack(("rglru+mlp", "rglru+mlp", "swa+mlp"), 12),
            Stack(("rglru+mlp", "rglru+mlp"), 1),
        ),
        rglru=RGLRUConfig(lru_width=4096, conv_width=4, c_exponent=8.0,
                          local_window=2048),
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        d_model=32, vocab_size=256,
        num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
        sliding_window=16,
        stacks=(
            Stack(("rglru+mlp", "rglru+mlp", "swa+mlp"), 1),
            Stack(("rglru+mlp",), 1),
        ),
        rglru=RGLRUConfig(lru_width=32, conv_width=4),
        microbatch=2, block_kv=16, dtype="float32",
    )
