"""mamba2-2.7b [ssm]: 64L d=2560, attention-free, vocab 50280, ssm_state=128.
SSD (state-space duality) [arXiv:2405.21060]. Constant-memory decode state
=> long_500k applicable."""
from repro.models.config import ModelConfig, SSMConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        d_model=2560, vocab_size=50280,
        d_ff=0,
        stacks=(Stack(("ssd",), 64),),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                      chunk=256),
        tie_embeddings=True,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm",
        d_model=32, vocab_size=256,
        d_ff=0,
        stacks=(Stack(("ssd",), 2),),
        ssm=SSMConfig(d_state=16, head_dim=8, expand=2, conv_width=4,
                      chunk=16),
        tie_embeddings=True,
        microbatch=2, dtype="float32",
    )
