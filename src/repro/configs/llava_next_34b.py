"""llava-next-34b [vlm]: yi-34b backbone (60L d=7168 56H GQA kv=8
d_ff=20480 vocab=64000) with anyres image tiling
[hf:llava-hf/llava-v1.6 family]. Per the assignment, the vision tower +
anyres projector are a STUB: input_specs() provides precomputed patch
embeddings [B, 1152, d_model] prefixed to the text tokens (1152 = 2 anyres
tiles × 576 patches). Full attention => long_500k skipped."""
from repro.models.config import ModelConfig, Stack

NUM_PATCH_TOKENS = 1152


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        d_model=7168, vocab_size=64000,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
        stacks=(Stack(("attn+mlp",), 60),),
        num_patch_tokens=NUM_PATCH_TOKENS,
        rope_theta=5e6,
        microbatch=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke", family="vlm",
        d_model=64, vocab_size=256,
        num_heads=6, num_kv_heads=2, head_dim=16, d_ff=128,
        stacks=(Stack(("attn+mlp",), 2),),
        num_patch_tokens=16,
        microbatch=2, block_kv=32, dtype="float32",
    )
