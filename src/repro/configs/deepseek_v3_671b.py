"""deepseek-v3-671b [moe]: 61L d=7168 128H d_ff(expert)=2048 vocab=129280.
MLA (q_lora 1536, kv_lora 512, nope 128 + rope 64, v 128), 1 shared + 256
routed experts top-8 with sigmoid+bias aux-free routing, first 3 layers
dense (d_ff 18432, per the DeepSeek-V3 report; the assignment line only
fixes the expert d_ff=2048) [arXiv:2412.19437].

MTP (multi-token prediction) omitted — it is a training-objective add-on
orthogonal to this paper's runtime-modeling study (noted in DESIGN.md).
Optimizer: Adafactor (factored 2nd moment) — Adam m+v at 671B does not fit
the 256-chip HBM budget; see EXPERIMENTS.md §Dry-run.
Full (latent) attention => long_500k skipped."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        d_model=7168, vocab_size=129280,
        num_heads=128, d_ff=18432,
        stacks=(
            Stack(("mla+mlp",), 3),
            Stack(("mla+moe",), 58),
        ),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                      num_shared_experts=1, d_ff_shared=2048,
                      router_scale=True),
        optimizer="adafactor",
        # microbatch must be a multiple of the dp axis (16) or the batch
        # replicates per microbatch — found by the §Perf roofline loop
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        d_model=64, vocab_size=256,
        num_heads=4, d_ff=128,
        stacks=(
            Stack(("mla+mlp",), 1),
            Stack(("mla+moe",), 1),
        ),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1, d_ff_shared=32,
                      router_scale=True),
        optimizer="adafactor",
        microbatch=2, block_kv=16, dtype="float32",
    )
