"""Architecture configs — one module per assigned architecture plus the
paper's own cost-model config. Access via repro.models.registry."""
