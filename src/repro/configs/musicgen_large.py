"""musicgen-large [audio]: 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.
Decoder-only transformer over EnCodec tokens [arXiv:2306.05284]. Per the
assignment, the EnCodec frontend is a STUB: input_specs() provides
precomputed frame embeddings [B,S,d_model] for train/prefill; decode
consumes codebook token ids. Full attention => long_500k skipped."""
from repro.models.config import ModelConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        d_model=2048, vocab_size=2048,
        num_heads=32, num_kv_heads=32, d_ff=8192,
        stacks=(Stack(("attn+mlp",), 48),),
        embed_inputs=True,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio",
        d_model=64, vocab_size=64,
        num_heads=4, num_kv_heads=4, d_ff=128,
        stacks=(Stack(("attn+mlp",), 2),),
        embed_inputs=True,
        microbatch=2, block_kv=32, dtype="float32",
    )
