"""yi-34b [dense]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
llama-arch GQA [arXiv:2403.04652]. Full attention => long_500k skipped.
56 heads on 16-way TP is GSPMD-padded to 64 (see DESIGN.md §6)."""
from repro.models.config import ModelConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        d_model=7168, vocab_size=64000,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
        stacks=(Stack(("attn+mlp",), 60),),
        rope_theta=5e6,
        microbatch=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense",
        d_model=64, vocab_size=256,
        num_heads=6, num_kv_heads=2, head_dim=16, d_ff=128,
        stacks=(Stack(("attn+mlp",), 2),),
        microbatch=2, block_kv=32, dtype="float32",
    )
