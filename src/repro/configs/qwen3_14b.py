"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
qk-norm on per-head q/k [hf:Qwen/Qwen3-8B]. Full attention => long_500k
skipped."""
from repro.models.config import ModelConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        d_model=5120, vocab_size=151936,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=17408,
        qk_norm=True,
        stacks=(Stack(("attn+mlp",), 40),),
        rope_theta=1e6,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        qk_norm=True,
        stacks=(Stack(("attn+mlp",), 2),),
        microbatch=2, block_kv=32, dtype="float32",
    )
