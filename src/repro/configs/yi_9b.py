"""yi-9b [dense]: 48L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
llama-arch GQA [arXiv:2403.04652]. Full attention => long_500k skipped."""
from repro.models.config import ModelConfig, Stack


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family="dense",
        d_model=4096, vocab_size=64000,
        num_heads=32, num_kv_heads=4, d_ff=11008,
        stacks=(Stack(("attn+mlp",), 48),),
        rope_theta=5e6,
        microbatch=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", family="dense",
        d_model=64, vocab_size=256,
        num_heads=4, num_kv_heads=2, d_ff=128,
        stacks=(Stack(("attn+mlp",), 2),),
        microbatch=2, block_kv=32, dtype="float32",
    )
