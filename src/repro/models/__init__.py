"""Model zoo: the 10 assigned architectures as selectable configs."""
from repro.models.config import ModelConfig, SHAPES, ShapeSpec, Stack, \
    shape_applicable
from repro.models.registry import ARCHS, get_config, get_smoke_config, \
    list_archs

__all__ = [
    "ModelConfig", "SHAPES", "ShapeSpec", "Stack", "shape_applicable",
    "ARCHS", "get_config", "get_smoke_config", "list_archs",
]
