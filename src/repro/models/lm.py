"""Generic LM assembled from config stacks — covers all 10 architectures.

Layer stacks are scanned (`jax.lax.scan` over stacked per-layer params) with
optional remat, so the 671B-layer-count HLO stays compact for the dry-run.
Training uses microbatched gradient accumulation (global_batch =
microbatch × n_micro) — full-batch 256×4096 logits would never fit.

Entry points:
  init_params / init_abstract           — real or ShapeDtypeStruct params
  train_step_fn(cfg)                    — (params, opt, batch) -> ...
  prefill_step_fn(cfg, capacity)        — (params, batch) -> (logits, cache)
  decode_step_fn(cfg)                   — (params, cache, tokens, pos) -> ...
  init_cache / cache_abstract           — decode caches per layer stack
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.context import constrain, constrain_batch_tree
from repro.training.optim import AdamWConfig, adamw_init, adamw_update
from repro.training.adafactor import adafactor_init, adafactor_update


def _parse(elem: str) -> tuple[str, str]:
    if "+" in elem:
        m, f = elem.split("+", 1)
    else:
        m, f = elem, "none"
    return m, f


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------------------
# Block init / apply
# ----------------------------------------------------------------------------
def block_init(rng, cfg: ModelConfig, elem: str) -> dict:
    mixer, ffn = _parse(elem)
    k1, k2 = jax.random.split(rng)
    p: dict[str, Any] = {"norm1": L._norm_init(cfg.d_model)}
    if mixer in ("attn", "swa"):
        p["mixer"] = L.attn_init(k1, cfg)
    elif mixer == "mla":
        p["mixer"] = L.mla_init(k1, cfg)
    elif mixer == "ssd":
        p["mixer"] = L.ssd_init(k1, cfg)
    elif mixer == "rglru":
        p["mixer"] = L.rglru_init(k1, cfg)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn != "none":
        p["norm2"] = L._norm_init(cfg.d_model)
        p["ffn"] = L.mlp_init(k2, cfg) if ffn == "mlp" else L.moe_init(k2, cfg)
    return p


def _mixer_window(cfg: ModelConfig, mixer: str) -> int | None:
    return cfg.sliding_window if mixer == "swa" else None


def block_apply_train(params: dict, cfg: ModelConfig, elem: str,
                      x: jnp.ndarray) -> jnp.ndarray:
    mixer, ffn = _parse(elem)
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        h = L.attn_apply_train(params["mixer"], cfg, h,
                               window=_mixer_window(cfg, mixer))
    elif mixer == "mla":
        h = L.mla_apply_train(params["mixer"], cfg, h)
    elif mixer == "ssd":
        h = L.ssd_apply_train(params["mixer"], cfg, h)
    elif mixer == "rglru":
        h = L.rglru_apply_train(params["mixer"], cfg, h)
    x = x + h
    if ffn != "none":
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = L.mlp_apply(params["ffn"], h) if ffn == "mlp" \
            else L.moe_apply(params["ffn"], cfg, h)
        x = x + h
    return constrain(x, "act_btd")


def block_cache_init(cfg: ModelConfig, elem: str, batch: int,
                     capacity: int) -> dict:
    mixer, _ = _parse(elem)
    if mixer in ("attn", "swa"):
        return L.attn_cache_init(cfg, batch, capacity,
                                 window=_mixer_window(cfg, mixer))
    if mixer == "mla":
        return L.mla_cache_init(cfg, batch, capacity)
    if mixer == "ssd":
        return L.ssd_cache_init(cfg, batch)
    if mixer == "rglru":
        return L.rglru_cache_init(cfg, batch)
    raise ValueError(mixer)


def block_apply_decode(params: dict, cfg: ModelConfig, elem: str,
                       x: jnp.ndarray, cache: dict, pos) -> tuple:
    mixer, ffn = _parse(elem)
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        h, new_cache = L.attn_apply_decode(params["mixer"], cfg, h, cache,
                                           pos,
                                           window=_mixer_window(cfg, mixer))
    elif mixer == "mla":
        h, new_cache = L.mla_apply_decode(params["mixer"], cfg, h, cache, pos)
    elif mixer == "ssd":
        h, new_cache = L.ssd_apply_decode(params["mixer"], cfg, h, cache, pos)
    elif mixer == "rglru":
        h, new_cache = L.rglru_apply_decode(params["mixer"], cfg, h, cache,
                                            pos)
    x = x + h
    if ffn != "none":
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = L.mlp_apply(params["ffn"], h) if ffn == "mlp" \
            else L.moe_apply(params["ffn"], cfg, h)
        x = x + h
    return x, new_cache


def block_apply_prefill(params: dict, cfg: ModelConfig, elem: str,
                        x: jnp.ndarray, capacity: int) -> tuple:
    """Like train, but also returns the decode cache for this layer."""
    mixer, ffn = _parse(elem)
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        window = _mixer_window(cfg, mixer)
        S = h.shape[1]
        positions = jnp.arange(S)
        q, k, v = L.attn_qkv(params["mixer"], cfg, h, positions)
        out = L.chunked_attention(q, k, v, causal=True, window=window,
                                  block_kv=cfg.block_kv)
        h = jnp.einsum("bshk,hkd->bsd", out, params["mixer"]["wo"])
        cache = L.attn_make_cache_from_prefill(cfg, k, v, window=window,
                                               capacity=capacity)
    elif mixer == "mla":
        S = h.shape[1]
        positions = jnp.arange(S)
        ckv, krope = L._mla_kv_latent(params["mixer"], cfg, h, positions)
        hh = L.mla_apply_train(params["mixer"], cfg, h)
        B = h.shape[0]
        pad = capacity - S
        cache = {
            "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
            "krope": jnp.pad(krope, ((0, 0), (0, pad), (0, 0))),
            "k_pos": jnp.pad(
                jnp.broadcast_to(positions, (B, S)).astype(jnp.int32),
                ((0, 0), (0, pad)), constant_values=-1),
        }
        h = hh
    elif mixer == "ssd":
        h, cache = L.ssd_apply_train(params["mixer"], cfg, h,
                                     return_state=True)
    elif mixer == "rglru":
        out, conv, h_last = L.rglru_core(params["mixer"], cfg, h)
        cache = {"state": h_last.astype(jnp.float32), "conv": conv}
        h = out
    x = x + h
    if ffn != "none":
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = L.mlp_apply(params["ffn"], h) if ffn == "mlp" \
            else L.moe_apply(params["ffn"], cfg, h)
        x = x + h
    return x, cache


# ----------------------------------------------------------------------------
# Whole-model params
# ----------------------------------------------------------------------------
def init_params(rng, cfg: ModelConfig) -> dict:
    dt = _dt(cfg)
    k_embed, k_head, k_stacks = jax.random.split(rng, 3)
    params: dict[str, Any] = {
        "embed": L._winit(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": L._norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._winit(k_head, (cfg.d_model, cfg.vocab_size),
                                     dt)
    stacks = []
    for si, stack in enumerate(cfg.stacks):
        ks = jax.random.fold_in(k_stacks, si)
        elem_params = []
        for ei, elem in enumerate(stack.pattern):
            keys = jax.random.split(jax.random.fold_in(ks, ei),
                                    stack.repeats)
            stacked = jax.vmap(lambda k: block_init(k, cfg, elem))(keys)
            elem_params.append(stacked)
        stacks.append(tuple(elem_params))
    params["stacks"] = stacks
    return params


def init_abstract(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct params (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ----------------------------------------------------------------------------
# Forward (training / prefill trunk)
# ----------------------------------------------------------------------------
def _embed_inputs(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    if cfg.embed_inputs:                         # musicgen: frame embeddings
        return batch["embeddings"].astype(_dt(cfg))
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    tok = tok * jnp.asarray(math.sqrt(cfg.d_model), tok.dtype)
    if cfg.num_patch_tokens:                     # llava: patch prefix
        patches = batch["patch_embeds"].astype(tok.dtype)
        tok = jnp.concatenate([patches, tok], axis=1)
    return constrain(tok, "act_btd")


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)                    # "full"


def _layer_slice(elem_params, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], tuple(elem_params))


def forward_trunk(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B,S,D] embeddings -> final hidden states."""
    for stack, elem_params in zip(cfg.stacks, params["stacks"]):
        pattern = stack.pattern

        def body(h, layer_params):
            for elem, p in zip(pattern, layer_params):
                h = block_apply_train(p, cfg, elem, h)
            return h, None

        body = _remat_wrap(cfg, body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, tuple(elem_params))
        else:                      # roofline probe: unrolled
            for i in range(stack.repeats):
                x, _ = body(x, _layer_slice(elem_params, i))
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def logits_fn(params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return h @ params["lm_head"]


def loss_fn(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    x = _embed_inputs(params, cfg, batch)
    h = forward_trunk(params, cfg, x)
    logits = logits_fn(params, cfg, h).astype(jnp.float32)
    if cfg.embed_inputs:
        labels = batch["labels"]
        lg = logits
    else:
        tokens = batch["tokens"]
        off = cfg.num_patch_tokens
        lg = logits[:, off:-1] if off else logits[:, :-1]
        labels = tokens[:, 1:]
    logp = jax.nn.log_softmax(lg, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------------------------
# Train step (microbatched gradient accumulation)
# ----------------------------------------------------------------------------
def make_optimizer(cfg: ModelConfig, optim_cfg: AdamWConfig | None = None):
    optim_cfg = optim_cfg or AdamWConfig(lr=3e-4, weight_decay=0.1,
                                         schedule="cosine")
    if cfg.optimizer == "adafactor":
        return (adafactor_init,
                lambda p, g, s: adafactor_update(p, g, s, lr=optim_cfg.lr))
    return (adamw_init,
            lambda p, g, s: adamw_update(p, g, s, optim_cfg))


def train_step_fn(cfg: ModelConfig, optim_cfg: AdamWConfig | None = None):
    _, update = make_optimizer(cfg, optim_cfg)

    def train_step(params, opt_state, batch):
        gb = jax.tree_util.tree_leaves(batch)[0].shape[0]
        mb = min(cfg.microbatch, gb)
        n_micro = gb // mb

        def reshape(x):
            return x.reshape((n_micro, mb) + x.shape[1:])
        micro = constrain_batch_tree(jax.tree_util.tree_map(reshape, batch),
                                     leading=1)
        acc_dtype = jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16" \
            else jnp.float32

        if cfg.grad_accum == "grad_of_scan":
            # differentiate the whole accumulation loop: one gradient
            # reduction per step instead of one per microbatch
            micro_loss = jax.checkpoint(
                lambda p, mb_: loss_fn(p, cfg, mb_))

            def total_loss(p):
                def body(acc, mbatch):
                    return acc + micro_loss(p, mbatch), None
                if cfg.scan_microbatch:
                    s, _ = jax.lax.scan(body,
                                        jnp.zeros((), jnp.float32), micro)
                else:
                    s = jnp.zeros((), jnp.float32)
                    for i in range(n_micro):
                        s, _ = body(s, jax.tree_util.tree_map(
                            lambda a: a[i], micro))
                return s / n_micro

            loss_mean, grads = jax.value_and_grad(total_loss)(params)
            loss_sum = loss_mean * n_micro
        else:
            def acc_body(carry, mbatch):
                loss_sum, grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, cfg, mbatch)
                grads = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dtype), grads, g)
                return (loss_sum + l, grads), None

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            carry0 = (jnp.zeros((), jnp.float32), zero_grads)
            if cfg.scan_microbatch:
                (loss_sum, grads), _ = jax.lax.scan(acc_body, carry0, micro)
            else:                      # roofline probe: unrolled
                carry = carry0
                for i in range(n_micro):
                    carry, _ = acc_body(
                        carry, jax.tree_util.tree_map(lambda a: a[i], micro))
                loss_sum, grads = carry
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params, new_opt, stats = update(params, grads, opt_state)
        stats["loss"] = loss_sum / n_micro
        return new_params, new_opt, stats

    return train_step


# ----------------------------------------------------------------------------
# Serving: prefill + decode
# ----------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> list:
    caches = []
    for stack in cfg.stacks:
        elem_caches = []
        for elem in stack.pattern:
            one = block_cache_init(cfg, elem, batch, capacity)
            stacked = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None],
                                           (stack.repeats,) + x.shape).copy(),
                one)
            elem_caches.append(stacked)
        caches.append(tuple(elem_caches))
    return caches


def cache_abstract(cfg: ModelConfig, batch: int, capacity: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity))


def prefill_step_fn(cfg: ModelConfig, capacity: int):
    def prefill(params, batch):
        x = _embed_inputs(params, cfg, batch)
        caches = []
        for stack, elem_params in zip(cfg.stacks, params["stacks"]):
            pattern = stack.pattern

            def body(h, layer_params):
                new_caches = []
                for elem, p in zip(pattern, layer_params):
                    h, c = block_apply_prefill(p, cfg, elem, h, capacity)
                    new_caches.append(c)
                return h, tuple(new_caches)

            body = _remat_wrap(cfg, body)
            if cfg.scan_layers:
                x, stack_caches = jax.lax.scan(body, x, tuple(elem_params))
            else:                  # roofline probe: unrolled
                per_layer = []
                for i in range(stack.repeats):
                    x, c = body(x, _layer_slice(elem_params, i))
                    per_layer.append(c)
                stack_caches = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *per_layer)
            caches.append(stack_caches)
        h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_fn(params, cfg, h[:, -1:, :])
        return logits, caches

    return prefill


def decode_step_fn(cfg: ModelConfig):
    def decode(params, caches, tokens, pos):
        """tokens: [B,1] int32; pos: scalar int32. Returns (logits, caches)."""
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        new_caches = []
        for stack, elem_params, stack_cache in zip(cfg.stacks,
                                                   params["stacks"], caches):
            pattern = stack.pattern

            def body(h, inp):
                layer_params, layer_cache = inp
                new_lc = []
                for elem, p, c in zip(pattern, layer_params, layer_cache):
                    h, nc = block_apply_decode(p, cfg, elem, h, c, pos)
                    new_lc.append(nc)
                return h, tuple(new_lc)

            if cfg.scan_layers:
                x, new_stack_cache = jax.lax.scan(
                    body, x, (tuple(elem_params), stack_cache))
            else:                  # roofline probe: unrolled
                per_layer = []
                for i in range(stack.repeats):
                    x, c = body(x, (_layer_slice(elem_params, i),
                                    jax.tree_util.tree_map(
                                        lambda a: a[i], stack_cache)))
                    per_layer.append(c)
                new_stack_cache = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *per_layer)
            new_caches.append(new_stack_cache)
        h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = logits_fn(params, cfg, h)
        return logits, new_caches

    return decode


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


def analytic_param_count(cfg: ModelConfig) -> int:
    """Parameter count from abstract shapes (sanity vs init; roofline)."""
    abstract = init_abstract(cfg)
    return int(sum(math.prod(x.shape)
                   for x in jax.tree_util.tree_leaves(abstract)))
