"""Layer zoo shared by all 10 assigned architectures.

Mixers:
  * gqa_attention — rotary + GQA, full-causal or sliding-window, optional
    qk-norm (qwen3). Train/prefill use a chunked online-softmax scan over KV
    blocks (flash-attention structure; the Pallas kernel in
    repro.kernels.flash_attention mirrors it). Decode attends over a cache
    (ring buffer for SWA).
  * mla — DeepSeek-V3 multi-head latent attention. Decode uses the absorbed
    form over the compressed KV cache.
  * ssd — Mamba2 state-space duality mixer (chunked intra/inter algorithm;
    the Pallas ssd_scan kernel mirrors the inter-chunk recurrence).
  * rglru — RecurrentGemma's gated linear recurrence, trained with an
    associative scan (log-depth on TPU).

FFNs: SwiGLU MLP and token-choice MoE with sort-based expert-parallel
dispatch (capacity + drop, MaxText-style).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig
from repro.sharding.context import constrain

NEG_INF = -1e30


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _norm_init(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * params["scale"]).astype(x.dtype)


def _winit(rng, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd] (hd even); positions: [S] absolute int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # [S, half]
    sin = jnp.sin(ang)[None, :, None, :]
    cos = jnp.cos(ang)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Chunked online-softmax attention (train / prefill path)
# ----------------------------------------------------------------------------
def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int | None = None,
                      q_offset: int | jnp.ndarray = 0,
                      block_kv: int = 512) -> jnp.ndarray:
    """q: [B,S,H,hd]; k,v: [B,T,KH,hd] with H % KH == 0. Returns [B,S,H,hd].

    Scans KV blocks with running (max, normalizer, accumulator) — bounded
    memory for 32k prefill; the jnp oracle for the Pallas flash kernel.
    """
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    rep = H // KH
    scale = 1.0 / math.sqrt(hd)
    qh = (q * scale).reshape(B, S, KH, rep, hd)

    blk = min(block_kv, T)
    nb = -(-T // blk)
    pad = nb * blk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, blk, KH, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, blk, KH, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        kq, vq, bi = inp
        s = jnp.einsum("bsgrd,btgd->bgrst", qh.astype(jnp.float32),
                       kq.astype(jnp.float32))
        k_pos = bi * blk + jnp.arange(blk)
        valid = (k_pos[None, :] < T)
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrst,btgd->bgrsd", p, vq.astype(jnp.float32))
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, rep, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, rep, S), jnp.float32)
    a0 = jnp.zeros((B, KH, rep, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def cache_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, k_pos: jnp.ndarray,
                    pos: jnp.ndarray, *,
                    window: int | None = None) -> jnp.ndarray:
    """Decode: q [B,1,H,hd] over cache [B,C,KH,hd]; k_pos [B,C] absolute
    positions of cached keys (-1 = empty slot)."""
    B, _, H, hd = q.shape
    C, KH = k_cache.shape[1], k_cache.shape[2]
    rep = H // KH
    scale = 1.0 / math.sqrt(hd)
    qh = (q * scale).reshape(B, KH, rep, hd)
    s = jnp.einsum("bgrd,btgd->bgrt", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window is not None:
        valid = valid & (pos - k_pos < window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrt,btgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ----------------------------------------------------------------------------
# GQA attention block (mixers 'attn' and 'swa')
# ----------------------------------------------------------------------------
def attn_init(rng, cfg: ModelConfig) -> dict:
    D, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    H_pad = max(cfg.attn_pad_heads, H) if cfg.attn_pad_heads else H
    assert H_pad % KH == 0, (H_pad, KH)
    dt = _dt(cfg)
    ks = jax.random.split(rng, 4)
    wq = _winit(ks[0], (D, H_pad, hd), dt)
    wo = _winit(ks[3], (H_pad, hd, D), dt,
                scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1)))
    if H_pad > H:
        # GQA maps head h -> kv group h // rep, so padding must be PER
        # GROUP (last rep_pad - rep slots of each group), and the padded
        # heads' wo rows are zero-init: the function is exactly the
        # unpadded model's at init.
        rep, rep_pad = H // KH, H_pad // KH
        mask = jnp.arange(H_pad) % rep_pad < rep     # real-head positions
        wo = wo * mask[:, None, None].astype(wo.dtype)
    p = {
        "wq": wq,
        "wk": _winit(ks[1], (D, KH, hd), dt),
        "wv": _winit(ks[2], (D, KH, hd), dt),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = _norm_init(hd)
        p["k_norm"] = _norm_init(hd)
    return p


def attn_qkv(params: dict, cfg: ModelConfig, x: jnp.ndarray,
             positions: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply_train(params: dict, cfg: ModelConfig, x: jnp.ndarray, *,
                     window: int | None, q_offset=0) -> jnp.ndarray:
    B, S, D = x.shape
    positions = q_offset + jnp.arange(S)
    q, k, v = attn_qkv(params, cfg, x, positions)
    if cfg.use_pallas_attn:
        from repro.kernels.flash_attention.ops import flash_attention
        interp = jax.default_backend() == "cpu"
        out = flash_attention(q, k, v, causal=True, window=window,
                              q_offset=int(q_offset) if not hasattr(
                                  q_offset, "dtype") else 0,
                              block_q=min(128, S), block_k=min(cfg.block_kv,
                                                               S),
                              interpret=interp)
    else:
        out = chunked_attention(q, k, v, causal=True, window=window,
                                q_offset=q_offset, block_kv=cfg.block_kv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def attn_cache_init(cfg: ModelConfig, batch: int, capacity: int, *,
                    window: int | None) -> dict:
    KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    C = min(capacity, window) if window is not None else capacity
    dt = _dt(cfg)
    return {
        "k": jnp.zeros((batch, C, KH, hd), dt),
        "v": jnp.zeros((batch, C, KH, hd), dt),
        "k_pos": jnp.full((batch, C), -1, jnp.int32),
    }


def attn_apply_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                      cache: dict, pos: jnp.ndarray, *,
                      window: int | None) -> tuple[jnp.ndarray, dict]:
    """x: [B,1,D]; pos: scalar int32 absolute position of this token."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = attn_qkv(params, cfg, x, positions)
    C = cache["k"].shape[1]
    slot = (pos % C) if window is not None else pos
    k_c = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_c = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    kp = jax.lax.dynamic_update_slice(
        cache["k_pos"], jnp.broadcast_to(pos, (k.shape[0], 1)).astype(jnp.int32),
        (0, slot))
    out = cache_attention(q, k_c, v_c, kp, pos, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k_c, "v": v_c, "k_pos": kp}


def attn_make_cache_from_prefill(cfg: ModelConfig, k, v, *, window,
                                 capacity: int) -> dict:
    """Build a decode cache from prefill-computed k/v [B,S,KH,hd]."""
    B, S = k.shape[0], k.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    if window is not None:
        C = min(capacity, window)
        # keep the last C positions, placed at slot pos % C (ring layout)
        keep_k, keep_v, keep_p = k[:, -C:], v[:, -C:], pos[-C:]
        slots = keep_p % C
        kc = jnp.zeros((B, C) + k.shape[2:], k.dtype).at[:, slots].set(keep_k)
        vc = jnp.zeros((B, C) + v.shape[2:], v.dtype).at[:, slots].set(keep_v)
        kp = jnp.full((B, C), -1, jnp.int32).at[:, slots].set(
            jnp.broadcast_to(keep_p, (B, C)))
        return {"k": kc, "v": vc, "k_pos": kp}
    C = capacity
    kc = jnp.zeros((B, C) + k.shape[2:], k.dtype).at[:, :S].set(k)
    vc = jnp.zeros((B, C) + v.shape[2:], v.dtype).at[:, :S].set(v)
    kp = jnp.full((B, C), -1, jnp.int32).at[:, :S].set(
        jnp.broadcast_to(pos, (B, S)))
    return {"k": kc, "v": vc, "k_pos": kp}


# ----------------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------------
def mlp_init(rng, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _winit(ks[0], (D, F), dt),
        "w_up": _winit(ks[1], (D, F), dt),
        "w_down": _winit(ks[2], (F, D), dt,
                         scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def mlp_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ----------------------------------------------------------------------------
# Token-choice MoE with sort-based expert-parallel dispatch
# ----------------------------------------------------------------------------
def moe_init(rng, cfg: ModelConfig) -> dict:
    mc = cfg.moe
    D, E, F = cfg.d_model, mc.num_experts, mc.d_ff_expert
    dt = _dt(cfg)
    ks = jax.random.split(rng, 6)
    p = {
        "router": _winit(ks[0], (D, E), jnp.float32, scale=0.006),
        "w_gate": _winit(ks[1], (E, D, F), dt),
        "w_up": _winit(ks[2], (E, D, F), dt),
        "w_down": _winit(ks[3], (E, F, D), dt,
                         scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }
    if mc.router_scale:                      # deepseek aux-free bias routing
        p["e_bias"] = jnp.zeros((E,), jnp.float32)
    if mc.num_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               mc.d_ff_shared * mc.num_shared_experts)
    return p


def _route(params: dict, mc: MoEConfig, xf: jnp.ndarray):
    """xf: [T, D] -> (gates [T,K], ids [T,K])."""
    logits = (xf.astype(jnp.float32) @ params["router"])
    if mc.router_scale:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["e_bias"][None, :]
        _, ids = jax.lax.top_k(sel, mc.top_k)
        gates = jnp.take_along_axis(scores, ids, axis=-1)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, mc.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, ids


def moe_apply(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B,S,D]. Sort-based dispatch with per-expert capacity + drop."""
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    K, E = mc.top_k, mc.num_experts
    xf = x.reshape(T, D)
    gates, ids = _route(params, mc, xf)

    cap = int(math.ceil(T * K / E * mc.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)                    # lane-align capacity

    flat_ids = ids.reshape(-1)                        # [T*K]
    sort_idx = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[sort_idx]
    counts = jnp.zeros((E,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_ids]
    keep = pos_sorted < cap
    slot_sorted = jnp.where(keep, sorted_ids * cap + pos_sorted, E * cap)

    tok_sorted = (sort_idx // K).astype(jnp.int32)
    dispatch_tok = jnp.zeros((E * cap + 1,), jnp.int32) \
        .at[slot_sorted].set(tok_sorted)
    slot_used = jnp.zeros((E * cap + 1,), jnp.bool_) \
        .at[slot_sorted].set(keep)
    xe = xf[dispatch_tok[:E * cap]] * slot_used[:E * cap, None]
    xe = constrain(xe.reshape(E, cap, D), "moe_ecd")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ye_flat = jnp.concatenate(
        [ye.reshape(E * cap, D), jnp.zeros((1, D), ye.dtype)], axis=0)

    # route outputs back to (token, k) order
    slot_of_flat = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
        slot_sorted.astype(jnp.int32))
    yk = ye_flat[slot_of_flat].reshape(T, K, D)
    out = jnp.sum(yk * gates[..., None].astype(yk.dtype), axis=1)

    if mc.num_shared_experts:
        out = out + mlp_apply(params["shared"], xf)
    return out.reshape(B, S, D).astype(x.dtype)


# ----------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention
# ----------------------------------------------------------------------------
def mla_init(rng, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dt = _dt(cfg)
    ks = jax.random.split(rng, 7)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": _winit(ks[0], (D, m.q_lora_rank), dt),
        "q_norm": _norm_init(m.q_lora_rank),
        "wuq": _winit(ks[1], (m.q_lora_rank, H, qk), dt),
        "wdkv": _winit(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": _norm_init(m.kv_lora_rank),
        "wuk": _winit(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim), dt),
        "wuv": _winit(ks[4], (m.kv_lora_rank, H, m.v_head_dim), dt),
        "wo": _winit(ks[5], (H, m.v_head_dim, D), dt,
                     scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def _mla_q(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    cq = rmsnorm(params["q_norm"], x @ params["wdq"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    dkv = x @ params["wdkv"]
    ckv = rmsnorm(params["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(dkv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return ckv, k_rope[:, :, 0, :]


def mla_apply_train(params: dict, cfg: ModelConfig, x: jnp.ndarray, *,
                    q_offset=0) -> jnp.ndarray:
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    positions = q_offset + jnp.arange(S)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, k_rope = _mla_kv_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    # pad v head dim up to qk dim so the shared chunked kernel applies,
    # then slice back (v_head 128 vs qk 192)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    out = chunked_attention(q, k, v_p, causal=True, q_offset=q_offset,
                            block_kv=cfg.block_kv)[..., :m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def mla_cache_init(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    m = cfg.mla
    dt = _dt(cfg)
    return {
        "ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dt),
        "k_pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def mla_apply_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache: dict, pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """Absorbed-form decode: attend in the compressed latent space."""
    m = cfg.mla
    B = x.shape[0]
    positions = pos[None]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)       # [B,1,H,*]
    ckv_new, krope_new = _mla_kv_latent(params, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], krope_new,
                                         (0, pos, 0))
    kp = jax.lax.dynamic_update_slice(
        cache["k_pos"], jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32),
        (0, pos))
    # absorb wuk into the query: q_lat [B,H,r]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wuk"])[:, 0]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32)) +
         jnp.einsum("bhk,btk->bht", q_rope[:, 0].astype(jnp.float32),
                    krope.astype(jnp.float32))) * scale
    valid = (kp >= 0) & (kp <= pos)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bht,btr->bhr", p, ckv.astype(jnp.float32))
    v = jnp.einsum("bhr,rhk->bhk", ctx_lat.astype(_dt(cfg)), params["wuv"])
    y = jnp.einsum("bhk,hkd->bd", v, params["wo"])[:, None, :]
    return y, {"ckv": ckv, "krope": krope, "k_pos": kp}


# ----------------------------------------------------------------------------
# SSD — Mamba2 mixer
# ----------------------------------------------------------------------------
def ssd_dims(cfg: ModelConfig):
    sc: SSMConfig = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    return d_inner, H, sc.head_dim, sc.d_state


def ssd_init(rng, cfg: ModelConfig) -> dict:
    sc = cfg.ssm
    D = cfg.d_model
    d_inner, H, P, N = ssd_dims(cfg)
    conv_dim = d_inner + 2 * sc.ngroups * N
    dt = _dt(cfg)
    ks = jax.random.split(rng, 5)
    in_dim = 2 * d_inner + 2 * sc.ngroups * N + H
    return {
        "w_in": _winit(ks[0], (D, in_dim), dt),
        "conv_w": _winit(ks[1], (sc.conv_width, conv_dim), jnp.float32, 0.2),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # a = -exp(A_log)
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "y_norm": _norm_init(d_inner),
        "w_out": _winit(ks[2], (d_inner, D), dt,
                        scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: [B,S,C]; w: [W,C]. Returns (y, new_state)
    where state is the last W-1 inputs (for decode)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(W))
    y = y + b[None, None, :]
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return y.astype(x.dtype), new_state


def _ssd_split(cfg: ModelConfig, proj: jnp.ndarray):
    sc = cfg.ssm
    d_inner, H, P, N = ssd_dims(cfg)
    g = sc.ngroups
    z, xs, Bm, Cm, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + g * N,
               2 * d_inner + 2 * g * N], axis=-1)
    return z, xs, Bm, Cm, dt_raw


def ssd_mix_chunked(cfg: ModelConfig, X, Bm, Cm, dlog, h0=None):
    """The SSD chunked algorithm (jnp oracle for the Pallas ssd_scan kernel).

    X: [B,S,H,P] inputs (already dt-scaled); Bm/Cm: [B,S,N] (ngroups=1);
    dlog: [B,S,H] per-step log-decay (<= 0). Returns (Y [B,S,H,P],
    final_state [B,H,N,P]).
    """
    sc = cfg.ssm
    B_, S, H, P = X.shape
    N = Bm.shape[-1]
    L = min(sc.chunk, S)
    nc = S // L
    assert nc * L == S, (S, L)
    Xc = X.reshape(B_, nc, L, H, P)
    Bc = Bm.reshape(B_, nc, L, N)
    Cc = Cm.reshape(B_, nc, L, N)
    dc = dlog.reshape(B_, nc, L, H)
    cum = jnp.cumsum(dc, axis=2)                       # [B,nc,L,H]

    # intra-chunk (masked decay attention)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    att = scores[..., None] * dec                          # [B,nc,L,L,H]
    Y_intra = jnp.einsum("bclsh,bcshp->bclhp", att, Xc.astype(jnp.float32))

    # per-chunk input state contribution
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,L,H]
    S_state = jnp.einsum("bcln,bclh,bclhp->bchnp",
                         Bc.astype(jnp.float32), decay_to_end,
                         Xc.astype(jnp.float32))           # [B,nc,H,N,P]

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]

    def step(h, inp):
        s_c, d_c = inp                                     # [B,H,N,P],[B,H]
        h_new = h * d_c[..., None, None] + s_c
        return h_new, h                                    # emit state BEFORE

    h_init = jnp.zeros((B_, H, N, P), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    hT, h_before = jax.lax.scan(
        step, h_init, (S_state.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)           # [B,nc,H,N,P]

    Y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cc.astype(jnp.float32), jnp.exp(cum), h_before)
    Y = (Y_intra + Y_inter).reshape(B_, S, H, P)
    return Y, hT


def ssd_apply_train(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                    conv_state=None, h0=None, return_state: bool = False):
    sc = cfg.ssm
    B, S, D = x.shape
    d_inner, H, P, N = ssd_dims(cfg)
    proj = x @ params["w_in"]
    z, xs, Bm, Cm, dt_raw = _ssd_split(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + sc.ngroups * N]
    Cm = conv_out[..., d_inner + sc.ngroups * N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                          # [H], negative
    dlog = dt * a[None, None, :]                           # [B,S,H]
    X = xs.reshape(B, S, H, P)
    U = X.astype(jnp.float32) * dt[..., None]
    # pad S to a chunk multiple with state-neutral steps (B=0 ⇒ no input
    # contribution; dlog=0 ⇒ decay 1 ⇒ state unchanged)
    L = min(sc.chunk, S)
    pad = (-S) % L
    if pad:
        U_p = jnp.pad(U, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dlog_p = jnp.pad(dlog, ((0, 0), (0, pad), (0, 0)))
        Y, hT = ssd_mix_chunked(cfg, U_p, Bm_p, Cm_p, dlog_p, h0)
        Y = Y[:, :S]
    else:
        Y, hT = ssd_mix_chunked(cfg, U, Bm, Cm, dlog, h0)
    Y = Y + params["D_skip"][None, None, :, None] * X.astype(jnp.float32)
    y = Y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["y_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["w_out"]
    if return_state:
        return out, {"state": hT.astype(jnp.float32), "conv": new_conv}
    return out


def ssd_cache_init(cfg: ModelConfig, batch: int) -> dict:
    sc = cfg.ssm
    d_inner, H, P, N = ssd_dims(cfg)
    conv_dim = d_inner + 2 * sc.ngroups * N
    return {
        "state": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, sc.conv_width - 1, conv_dim), jnp.float32),
    }


def ssd_apply_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache: dict, pos) -> tuple[jnp.ndarray, dict]:
    """Single-token state update. x: [B,1,D]."""
    del pos
    sc = cfg.ssm
    B = x.shape[0]
    d_inner, H, P, N = ssd_dims(cfg)
    proj = x @ params["w_in"]
    z, xs, Bm, Cm, dt_raw = _ssd_split(cfg, proj)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + sc.ngroups * N][:, 0]
    Cm = conv_out[..., d_inner + sc.ngroups * N:][:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a[None, :])                       # [B,H]
    X = xs.reshape(B, H, P).astype(jnp.float32)
    U = X * dt[..., None]
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), U)
    Y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), state)
    Y = Y + params["D_skip"][None, :, None] * X
    y = Y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["y_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["w_out"], {"state": state, "conv": new_conv}


# ----------------------------------------------------------------------------
# RG-LRU — RecurrentGemma recurrent mixer
# ----------------------------------------------------------------------------
def rglru_init(rng, cfg: ModelConfig) -> dict:
    rc: RGLRUConfig = cfg.rglru
    D = cfg.d_model
    W = rc.lru_width or D
    dt = _dt(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "w_x": _winit(ks[0], (D, W), dt),
        "w_gate": _winit(ks[1], (D, W), dt),
        "conv_w": _winit(ks[2], (rc.conv_width, W), jnp.float32, 0.2),
        "conv_b": jnp.zeros((W,), jnp.float32),
        "w_rg": _winit(ks[3], (W, W), dt),                 # recurrence gate
        "w_ig": _winit(ks[4], (W, W), dt),                 # input gate
        "lam": jnp.full((W,), 2.2, jnp.float32),           # a≈0.9 at init
        "w_out": _winit(ks[5], (W, D), dt,
                        scale=0.02 / math.sqrt(2 * max(cfg.num_layers, 1))),
    }


def _rglru_scan(log_a: jnp.ndarray, b: jnp.ndarray, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t via associative scan over S.
    log_a, b: [B,S,W]."""
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_core(params: dict, cfg: ModelConfig, x: jnp.ndarray,
               conv_state=None, h0=None):
    rc = cfg.rglru
    u = x @ params["w_x"]
    gate = x @ params["w_gate"]
    conv_out, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                                      conv_state)
    uc = conv_out.astype(jnp.float32)
    r = jax.nn.sigmoid(uc @ params["w_rg"].astype(jnp.float32))
    i = jax.nn.sigmoid(uc @ params["w_ig"].astype(jnp.float32))
    log_a = -rc.c_exponent * jax.nn.softplus(params["lam"]) * r    # [B,S,W]
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = beta * (i * uc)
    h = _rglru_scan(log_a, b, h0)
    y = (h.astype(x.dtype) * jax.nn.silu(gate))
    return y @ params["w_out"], new_conv, h[:, -1]


def rglru_apply_train(params: dict, cfg: ModelConfig, x: jnp.ndarray):
    out, _, _ = rglru_core(params, cfg, x)
    return out


def rglru_cache_init(cfg: ModelConfig, batch: int) -> dict:
    rc = cfg.rglru
    W = rc.lru_width or cfg.d_model
    return {
        "state": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, rc.conv_width - 1, W), jnp.float32),
    }


def rglru_apply_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                       cache: dict, pos) -> tuple[jnp.ndarray, dict]:
    del pos
    out, new_conv, h_last = rglru_core(params, cfg, x,
                                       conv_state=cache["conv"],
                                       h0=cache["state"])
    return out, {"state": h_last.astype(jnp.float32), "conv": new_conv}
