"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation (dry-run pattern).

`make_batch()` materializes a concrete random batch of the same structure
for smoke tests and the end-to-end examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec


def _emb_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch pytree of ShapeDtypeStructs for train/prefill steps."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.embed_inputs:                          # musicgen frame embeddings
        return {
            "embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               _emb_dtype(cfg)),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.num_patch_tokens:                      # llava patch prefix
        S_text = S - cfg.num_patch_tokens
        assert S_text > 1, (S, cfg.num_patch_tokens)
        return {
            "tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.num_patch_tokens, cfg.d_model), _emb_dtype(cfg)),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Decode step: one new token against a seq_len cache."""
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    if shape.kind in ("train", "prefill"):
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def make_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if k in ("tokens", "labels"):
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=s.shape), jnp.int32)
        elif k == "pos":
            out[k] = jnp.asarray(shape.seq_len - 1, jnp.int32)
        else:
            out[k] = jnp.asarray(
                rng.normal(0, 1, size=s.shape), jnp.float32).astype(s.dtype)
    return out
