"""--arch registry: maps architecture ids to their configs."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS: dict[str, str] = {
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "yi-9b": "repro.configs.yi_9b",
    "yi-34b": "repro.configs.yi_34b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "musicgen-large": "repro.configs.musicgen_large",
    "llava-next-34b": "repro.configs.llava_next_34b",
}


def list_archs() -> list[str]:
    return sorted(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return importlib.import_module(ARCHS[name]).config()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return importlib.import_module(ARCHS[name]).smoke_config()
