"""Model-zoo configuration: one dataclass covering all 10 assigned
architectures (dense GQA/SWA transformers, Mamba2 SSD, RG-LRU hybrids,
token-choice MoE, DeepSeek MLA+MoE, audio/VLM backbones).

A model is a sequence of *stacks*; each stack is a layer pattern repeated
N times and scanned with `jax.lax.scan` (keeps HLO compact for the 33-cell
dry-run). Pattern elements are "<mixer>+<ffn>" strings:

  mixers: attn | swa | mla | ssd | rglru      ffns: mlp | moe | none
"""
from __future__ import annotations

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_scale: bool = False            # deepseek sigmoid+bias routing


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    ngroups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0                    # 0 => d_model
    conv_width: int = 4
    c_exponent: float = 8.0
    local_window: int = 2048


@dataclass(frozen=True)
class Stack:
    pattern: tuple[str, ...]              # e.g. ("rglru+mlp","rglru+mlp","swa+mlp")
    repeats: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                           # dense | ssm | hybrid | moe | audio | vlm
    d_model: int
    vocab_size: int
    stacks: tuple[Stack, ...]
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                     # 0 => d_model // num_heads
    d_ff: int = 0
    sliding_window: int = 4096            # used by 'swa' mixers
    qk_norm: bool = False
    attn_pad_heads: int = 0               # pad q-heads to this count with
    #   zero-init wo rows (exact at init) so heads shard cleanly over TP —
    #   avoids the head-dim-TP fallback that psums attention scores
    #   (§Perf lever; MaxText-style padding)
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # modality frontend stub: inputs are precomputed embeddings
    embed_inputs: bool = False            # musicgen (frame embeddings)
    num_patch_tokens: int = 0             # llava (patch embeddings prefix)
    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "full"                   # none | full | dots
    block_kv: int = 512                   # chunked-attention KV block
    use_pallas_attn: bool = False         # Pallas flash kernel (TPU target;
    #                                       dry-run uses the jnp path)
    microbatch: int = 16                  # grad-accumulation microbatch size
    optimizer: str = "adamw"              # adamw | adafactor
    grad_accum: str = "scan_of_grads"     # scan_of_grads | grad_of_scan —
    #   grad_of_scan differentiates the whole microbatch loop at once, so
    #   the cross-replica gradient reduction happens ONCE per step instead
    #   of once per microbatch (§Perf lever; collective bytes ÷ n_micro)
    grad_accum_dtype: str = "float32"     # float32 | bfloat16 accumulator
    # sharding
    fsdp: bool = True                     # shard params/opt over data axis
    seq_shard_decode: bool = True         # long-context: shard cache seq
    embed_shard: str = "vocab"            # vocab | dmodel — embedding table
    #   TP axis; "dmodel" avoids GSPMD's involuntary full remat on the
    #   vocab-sharded gather (a §Perf lever)
    # roofline probes: python-unroll the layer / microbatch loops so
    # cost_analysis counts every iteration (scan bodies are counted once)
    scan_layers: bool = True
    scan_microbatch: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def num_layers(self) -> int:
        return sum(len(s.pattern) * s.repeats for s in self.stacks)

    def layer_types(self) -> list[str]:
        out = []
        for s in self.stacks:
            for _ in range(s.repeats):
                out.extend(s.pattern)
        return out

    def has_mixer(self, kind: str) -> bool:
        return any(p.split("+")[0] == kind for p in self.layer_types())

    @property
    def subquadratic(self) -> bool:
        """True if decode memory is bounded (no unbounded full-attn cache)."""
        return not self.has_mixer("attn") and not self.has_mixer("mla")

    def to_dict(self) -> dict:
        return asdict(self)


# ----------------------------------------------------------------------------
# Assigned input-shape grid
# ----------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                             # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# smoke-test (reduced) shape used by per-arch CI tests
SMOKE_SHAPE = ShapeSpec("smoke", 64, 2, "train")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (assignment rule)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (f"{cfg.name} uses unbounded full attention; 500k-token "
                       "decode is skipped per assignment (see DESIGN.md)")
    return True, ""
