"""Uncertainty-driven acquisition (DESIGN.md §15): spend the hardware
budget where the model is least sure.

`AcquisitionEstimator` is a `LearnedEstimator`-shaped scorer with an
MC-dropout variance head: K stochastic forward passes (dropout live,
one folded rng per sample) through the same batched `predict_kernels`
machinery the deterministic path uses. `estimate` returns the MC-mean
score (a drop-in learned estimator); `estimate_with_variance` adds the
per-kernel std. `route_variance` turns per-candidate stds into a
measurement plan under a fixed eval budget, and `acquire` executes the
plan through a (metered, logged) `HardwareEstimator` — closing the
search side of the data flywheel.

Learning to Optimize Tensor Programs (PAPERS.md) is the motivation: at
equal hardware budget, measuring where the model disagrees with itself
buys more ranking improvement per eval than measuring uniformly.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import KernelGraph
from repro.search.estimator import CostEstimator, HardwareEstimator

__all__ = ["AcquisitionEstimator", "route_variance"]


def route_variance(stds, budget: int, *, spread: str = "kernel",
                   exclude=None, means=None,
                   kappa: float = 1.0) -> list[tuple[int, int]]:
    """Plan which (group, candidate) pairs to measure under `budget`
    total evals, most *attractive* candidate first.

    Attraction is highest predictive std by default (pure exploration).
    With `means`, candidates are ranked by the lower confidence bound
    ``mean - kappa * std`` instead (lowest first): predicted-fast OR
    uncertain candidates win, so the plan exploits the model's belief
    while still spending where it self-disagrees — the flywheel's
    policy (a kappa of 0 is pure exploitation, large kappa approaches
    pure variance routing).

    spread='global' — one flat ranking: take the `budget` most
    attractive candidates wherever they live. spread='kernel' —
    round-robin passes: every group contributes its next-most-attractive
    unmeasured candidate (groups ordered by that candidate within a
    pass) before any group gets a second pick, so each kernel's sweep
    keeps accumulating the ≥2 measured configs a pairwise rank loss
    needs.

    `exclude` is a set of already-measured (group, candidate) pairs —
    budget is never wasted re-measuring.

    >>> stds = [[0.9, 0.1], [0.5, 0.4]]
    >>> route_variance(stds, 3, spread='global')
    [(0, 0), (1, 0), (1, 1)]
    >>> route_variance(stds, 3, spread='kernel')
    [(0, 0), (1, 0), (1, 1)]
    >>> route_variance(stds, 3, spread='kernel', exclude={(1, 0)})
    [(0, 0), (1, 1), (0, 1)]
    >>> route_variance(stds, 2, spread='global',
    ...                means=[[2.0, 0.0], [1.0, 3.0]], kappa=1.0)
    [(0, 1), (1, 0)]
    """
    if spread not in ("kernel", "global"):
        raise ValueError(f"unknown spread policy {spread!r}")
    exclude = set(exclude or ())
    budget = max(int(budget), 0)
    if means is None:
        def score(gi, ci):
            return -float(np.asarray(stds[gi])[ci])
    else:
        def score(gi, ci):
            return (float(np.asarray(means[gi])[ci])
                    - kappa * float(np.asarray(stds[gi])[ci]))
    cands = [[(score(gi, ci), gi, ci) for ci in range(len(np.asarray(g)))
              if (gi, ci) not in exclude]
             for gi, g in enumerate(stds)]
    for g in cands:
        g.sort(key=lambda t: t[0])
    if spread == "global":
        flat = sorted((t for g in cands for t in g), key=lambda t: t[0])
        return [(gi, ci) for _, gi, ci in flat[:budget]]
    plan: list[tuple[int, int]] = []
    depth = 0
    while len(plan) < budget and any(depth < len(g) for g in cands):
        layer = sorted((g[depth] for g in cands if depth < len(g)),
                       key=lambda t: t[0])
        for _, gi, ci in layer[:budget - len(plan)]:
            plan.append((gi, ci))
        depth += 1
    return plan


class AcquisitionEstimator(CostEstimator):
    """MC-dropout mean/variance scoring over the GNN cost model.

    Scores are predicted log-runtimes averaged over `samples` stochastic
    forward passes (`runtimes()` exponentiates, like `LearnedEstimator`);
    the std across passes is the model's self-disagreement — high where
    the training corpus never covered a candidate, which is exactly
    where the next hardware eval teaches the most. Deterministic for a
    fixed (params, seed): pass s uses ``fold_in(key(seed), s)``.

    Built `from_params` like every learned scorer; requires
    ``model_cfg.dropout > 0`` (no dropout ⇒ zero variance ⇒ nothing to
    route on — a deep ensemble would be the alternative head).
    """

    name = "acquisition"

    def __init__(self, params, model_cfg, normalizer, *,
                 samples: int = 8, seed: int = 0, max_nodes: int = 64,
                 chunk: int = 128, adjacency: str | None = None,
                 node_budget: int | None = None):
        super().__init__()
        if samples < 2:
            raise ValueError(f"need >= 2 MC samples, got {samples}")
        if model_cfg.dropout <= 0.0:
            raise ValueError(
                "MC-dropout acquisition needs model_cfg.dropout > 0 "
                f"(got {model_cfg.dropout}) — variance would be "
                "identically zero")
        import jax
        from repro.core.model import cost_model_apply
        self.params = params
        self.model_cfg = model_cfg
        self.normalizer = normalizer
        self.samples = int(samples)
        self.seed = int(seed)
        self._kw = dict(max_nodes=max_nodes, chunk=chunk,
                        adjacency=adjacency, node_budget=node_budget)
        self.adjacency = adjacency or model_cfg.adjacency
        self.max_nodes = max_nodes
        self._base_key = jax.random.key(self.seed)
        self._fold_in = jax.random.fold_in

        @jax.jit
        def predict_mc(params, batch, rng):
            return cost_model_apply(params, model_cfg, batch, rng=rng,
                                    deterministic=False)
        self._predict_mc = predict_mc

    @classmethod
    def from_params(cls, params, model_cfg, normalizer,
                    **kw) -> "AcquisitionEstimator":
        """Mirror of `LearnedEstimator.from_params` for call-site
        symmetry (MC passes are uncached by construction — every sample
        must re-roll dropout — so there is no service variant)."""
        return cls(params, model_cfg, normalizer, **kw)

    # -- scoring -------------------------------------------------------------
    def _mc_stack(self, kernels: list[KernelGraph]) -> np.ndarray:
        from repro.core.evaluate import predict_kernels
        outs = []
        for s in range(self.samples):
            key = self._fold_in(self._base_key, s)
            outs.append(predict_kernels(
                self.params, self.model_cfg, kernels, self.normalizer,
                predict_fn=lambda p, b: self._predict_mc(p, b, key),
                **self._kw))
        return np.stack(outs)                      # [samples, kernels]

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        return self._mc_stack(kernels).mean(axis=0)

    def _to_runtime(self, scores: np.ndarray) -> np.ndarray:
        return np.exp(scores)

    def estimate_with_variance(self, kernels) -> tuple[np.ndarray,
                                                       np.ndarray]:
        """(mean, std) of the MC score samples, per kernel."""
        kernels = list(kernels)
        if not kernels:
            z = np.zeros((0,), np.float64)
            return z, z.copy()
        stack = self._mc_stack(kernels)
        self._queries += len(kernels)
        return stack.mean(axis=0), stack.std(axis=0)

    def group_variance(self, groups) -> tuple[list[np.ndarray],
                                              list[np.ndarray]]:
        """`estimate_with_variance` over many candidate groups in one
        batched flush (the `estimate_groups` idiom)."""
        groups = [list(g) for g in groups]
        mean, std = self.estimate_with_variance(
            [k for g in groups for k in g])
        means, stds, i = [], [], 0
        for g in groups:
            means.append(mean[i:i + len(g)])
            stds.append(std[i:i + len(g)])
            i += len(g)
        return means, stds

    # -- budgeted acquisition ------------------------------------------------
    def acquire(self, groups, hardware: HardwareEstimator, *,
                budget: int | None = None, spread: str = "kernel",
                exclude=None,
                kappa: float | None = None) -> list[tuple[int, int, float]]:
        """Measure the most acquisition-worthy candidates within budget.

        Scores all `groups` (lists of candidate `KernelGraph`s) with the
        variance head, plans via `route_variance` — pure highest-std
        when `kappa` is None, the ``mean - kappa * std`` lower
        confidence bound otherwise — and measures the plan through
        `hardware` in ONE batched `estimate` call — charging its
        `BudgetMeter` and feeding its `MeasurementLog`, if attached.
        `budget` defaults to everything the meter still affords (all
        candidates, if unmetered). Returns ``(group, candidate,
        measured_runtime)`` triples.
        """
        groups = [list(g) for g in groups]
        total = sum(len(g) for g in groups) - len(set(exclude or ()))
        if budget is None:
            budget = total
        if hardware.meter is not None:
            budget = hardware.meter.affordable(min(budget, total))
        means, stds = self.group_variance(groups)
        plan = route_variance(stds, budget, spread=spread, exclude=exclude,
                              means=None if kappa is None else means,
                              kappa=0.0 if kappa is None else kappa)
        if not plan:
            return []
        runtimes = hardware.estimate([groups[gi][ci] for gi, ci in plan])
        return [(gi, ci, float(rt)) for (gi, ci), rt in zip(plan, runtimes)]
