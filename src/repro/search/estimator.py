"""Cost estimators + the shared hardware budget (DESIGN.md §10).

One protocol for every way this repo can price a kernel:

* `HardwareEstimator`  — the simulator ("run it on the accelerator");
  every measurement charges a shared `BudgetMeter`, which replaces the two
  autotuners' ad-hoc `hardware_evals` / `eval_seconds` bookkeeping.
* `AnalyticalEstimator` — the Appendix-A baseline (free, rough).
* `LearnedEstimator`    — the GNN through `serving.CostModelService`
  (cached + coalesced); `from_params` is the one place service-construction
  kwargs live — `evaluate.learned_tile_scorer`,
  `evaluate.learned_runtime_predictor` and `autotuner.model_cost_fn` all
  build through it.
* `CascadeEstimator`    — staged filtering: a cheap stage prunes, an
  expensive stage refines the survivors (optionally ending in hardware).

Estimator scores are *rankings with units attached*: hardware/analytical
return seconds, the learned model returns predicted log-runtime. Callers
that need seconds use `runtimes()` / `program_costs()`, which apply each
estimator's score→runtime transform (`exp` for the learned model).
Every `estimate` call is accounted in `.queries`, which is how the
cascade acceptance gate ("≤ half the learned-model queries") is measured.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator


class BudgetExhausted(RuntimeError):
    """Raised when a charge would push a `BudgetMeter` past its budget."""


class BudgetMeter:
    """Hardware wall-clock budget, charged *as evaluations happen*.

    One eval = one config measured on the accelerator (a kernel for tile
    search, a whole program for fusion search), costing `eval_seconds` of
    simulated hardware time — the same apples-to-apples accounting the
    fusion autotuner used, now enforced inside every search loop instead
    of tallied after the fact.

    >>> m = BudgetMeter(budget_s=5.0, eval_seconds=2.0)
    >>> m.affordable(4)
    2
    >>> m.charge(2); (m.evals, m.spent_s, m.exhausted)
    (2, 4.0, True)
    """

    def __init__(self, budget_s: float = math.inf, eval_seconds: float = 2.0):
        if eval_seconds <= 0:
            raise ValueError(f"eval_seconds must be > 0, got {eval_seconds}")
        self.budget_s = float(budget_s)
        self.eval_seconds = float(eval_seconds)
        self.evals = 0
        self.spent_s = 0.0

    @property
    def remaining_s(self) -> float:
        return max(self.budget_s - self.spent_s, 0.0)

    def affordable(self, n: int = 1) -> int:
        """How many of `n` requested evals fit in the remaining budget."""
        if math.isinf(self.budget_s):
            return n
        fit = int((self.remaining_s + 1e-9) / self.eval_seconds)
        return min(n, max(fit, 0))

    @property
    def exhausted(self) -> bool:
        return self.affordable(1) == 0

    def charge(self, n: int = 1, seconds: float | None = None) -> None:
        """Record `n` evals (costing `seconds`, default n*eval_seconds).
        Raises `BudgetExhausted` — without charging — if it won't fit."""
        s = n * self.eval_seconds if seconds is None else float(seconds)
        if self.spent_s + s > self.budget_s + 1e-9:
            raise BudgetExhausted(
                f"charge of {s:.3g}s exceeds budget "
                f"({self.spent_s:.3g}/{self.budget_s:.3g}s spent)")
        self.evals += n
        self.spent_s += s


class CostEstimator:
    """`estimate(kernels) -> np.ndarray` + query accounting.

    Subclasses implement `_estimate`; the public wrapper counts `.queries`
    (graphs scored) only on success. Scores are comparable *within* one
    estimator (lower = faster); `_to_runtime` maps them to seconds.

    `adjacency` / `max_nodes` advertise the batched-graph representation
    behind the estimator (None = representation-free). The fusion
    autotuner keys its dense-path oversized-kernel drop off these, so
    wrappers around a dense learned backend must forward them
    (`CascadeEstimator` inherits its final stage's).
    """

    name = "estimator"
    adjacency: str | None = None
    max_nodes: int | None = None

    def __init__(self):
        self._queries = 0

    @property
    def queries(self) -> int:
        """Total graphs this estimator has been asked to score."""
        return self._queries

    def estimate(self, kernels: Sequence[KernelGraph]) -> np.ndarray:
        kernels = list(kernels)
        if not kernels:
            return np.zeros((0,), np.float64)
        out = np.asarray(self._estimate(kernels), np.float64)
        if out.shape != (len(kernels),):
            raise ValueError(f"{self.name}: estimate returned shape "
                             f"{out.shape}, expected ({len(kernels)},)")
        self._queries += len(kernels)
        return out

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        raise NotImplementedError

    def _to_runtime(self, scores: np.ndarray) -> np.ndarray:
        return scores

    def runtimes(self, kernels: Sequence[KernelGraph]) -> np.ndarray:
        """Scores converted to (estimated) seconds."""
        return self._to_runtime(self.estimate(kernels))

    def estimate_groups(self, groups: Sequence[Sequence[KernelGraph]]
                        ) -> list[np.ndarray]:
        """Score many candidate groups in ONE batched `estimate` call —
        the whole flattened set reaches the backend as a single coalesced
        flush (the engine's per-program / per-population fast path)."""
        groups = [list(g) for g in groups]
        flat = [k for g in groups for k in g]
        scores = self.estimate(flat)
        out, i = [], 0
        for g in groups:
            out.append(scores[i:i + len(g)])
            i += len(g)
        return out

    def program_costs(self, groups: Sequence[Sequence[KernelGraph]]
                      ) -> np.ndarray:
        """Σ runtime per group (the fusion objective), batched the same
        way. Empty groups cost 0."""
        per_group = self.estimate_groups(groups)
        return np.array([float(np.sum(self._to_runtime(s))) if len(s) else 0.0
                         for s in per_group], np.float64)


class HardwareEstimator(CostEstimator):
    """The measurement oracle as an estimator. Every kernel measured
    charges one eval to the shared `BudgetMeter` (if given); a whole
    program measured as one config charges one eval.

    `log` (anything with ``record(kernel, runtime)``, e.g.
    `repro.flywheel.MeasurementLog`) observes every charged per-kernel
    measurement — the data-flywheel tap that turns paid hardware evals
    into corpus delta shards (DESIGN.md §15). `measure_program` totals
    are NOT logged: one program eval yields a single end-to-end runtime
    that can't be attributed back to per-kernel labels.
    """

    name = "hardware"

    def __init__(self, sim: TPUSimulator, *, meter: BudgetMeter | None = None,
                 runs: int = 3, log=None):
        super().__init__()
        self.sim = sim
        self.meter = meter
        self.runs = runs
        self.log = log

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        if self.meter is not None:
            self.meter.charge(len(kernels))
        out = np.array([self.sim.measure(k, runs=self.runs)
                        for k in kernels], np.float64)
        if self.log is not None:
            for k, rt in zip(kernels, out):
                self.log.record(k, float(rt))
        return out

    def measure(self, kernel: KernelGraph) -> float:
        return float(self.estimate([kernel])[0])

    def measure_program(self, kernels: Sequence[KernelGraph]) -> float:
        """One fusion config = one hardware eval (the config runs end to
        end once), regardless of how many kernels it fused into."""
        if self.meter is not None:
            self.meter.charge(1)
        self._queries += 1
        return float(self.sim.measure_program(list(kernels), runs=self.runs))


class AnalyticalEstimator(CostEstimator):
    """The hand-tuned Appendix-A model: free, good at within-kernel tile
    ranking, poor at absolute cross-kernel runtimes — i.e. a pruning
    stage, not a verdict."""

    name = "analytical"

    def __init__(self, model=None):
        super().__init__()
        if model is None:
            from repro.core.analytical import AnalyticalModel
            model = AnalyticalModel()
        self.model = model

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        return np.array([self.model.predict(k) for k in kernels], np.float64)


class LearnedEstimator(CostEstimator):
    """The GNN cost model as an estimator. Scores are predicted
    log-runtimes; `runtimes()` exponentiates. Backed either by a
    `serving.CostModelService` (cached + coalesced — the default) or by
    the direct uncached `predict_kernels` path (`cache_capacity=0`)."""

    name = "learned"

    def __init__(self, service=None, *,
                 direct: Callable[[list[KernelGraph]], np.ndarray] | None = None,
                 adjacency: str | None = None, max_nodes: int | None = None):
        super().__init__()
        if (service is None) == (direct is None):
            raise ValueError("exactly one of service/direct required")
        self.service = service
        self._direct = direct
        self.adjacency = service.adjacency if service is not None else adjacency
        self.max_nodes = service.max_nodes if service is not None else max_nodes

    @classmethod
    def from_params(cls, params, model_cfg, normalizer, *,
                    max_nodes: int = 64, chunk: int = 128,
                    adjacency: str | None = None,
                    node_budget: int | None = None, predict_fn=None,
                    service=None, cache_capacity: int = 65536
                    ) -> "LearnedEstimator":
        """THE constructor for learned scoring plumbing — every scorer /
        predictor / cost-fn in `core.evaluate` and `repro.autotuner`
        builds through here. Pass an existing `service` to share one
        prediction cache across clients; `cache_capacity=0` (and no
        service) opts out into direct uncached scoring. `params` may be
        a `repro.quant.QuantizedCostModel` — scoring then runs the int8
        serving path under the model's embedded config (DESIGN.md §14)."""
        from repro.quant.quantize import QuantizedCostModel
        if isinstance(params, QuantizedCostModel):
            model_cfg = params.serving_config(model_cfg)
            params = params.params
        if service is None and cache_capacity:
            from repro.serving import CostModelService
            service = CostModelService(params, model_cfg, normalizer,
                                       adjacency=adjacency,
                                       max_nodes=max_nodes, chunk=chunk,
                                       node_budget=node_budget,
                                       predict_fn=predict_fn,
                                       cache_capacity=cache_capacity)
        if service is not None:
            return cls(service)

        from repro.core.evaluate import make_predict_fn, predict_kernels
        predict = predict_fn or make_predict_fn(model_cfg)

        def direct(graphs: list[KernelGraph]) -> np.ndarray:
            return predict_kernels(params, model_cfg, graphs, normalizer,
                                   max_nodes=max_nodes, chunk=chunk,
                                   predict_fn=predict, adjacency=adjacency,
                                   node_budget=node_budget)
        return cls(None, direct=direct,
                   adjacency=adjacency or model_cfg.adjacency,
                   max_nodes=max_nodes)

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        if self.service is not None:
            return self.service.predict_many(kernels)
        return self._direct(kernels)

    def _to_runtime(self, scores: np.ndarray) -> np.ndarray:
        return np.exp(scores)

    # --- drop-in adapters for the pre-search call sites --------------------
    def tile_scorer(self) -> Callable:
        """`scorer(kernel, tiles) -> scores` (tile autotuner contract)."""
        def scorer(kernel: KernelGraph, tiles) -> np.ndarray:
            kernel.structural_digest()   # memoize once; tile variants share
            return self.estimate([kernel.with_tile(t) for t in tiles])
        return scorer

    def runtime_predictor(self) -> Callable:
        """`predict_runtimes(kernels) -> seconds` (fusion eval contract)."""
        def predict_runtimes(kernels) -> np.ndarray:
            return self._to_runtime(self.estimate(list(kernels)))
        return predict_runtimes

    def _default_drop(self) -> int | None:
        # the dense path's padded slots truncate oversized kernels anyway;
        # drop them from objectives so the bias is explicit (model_cost_fn)
        return self.max_nodes if self.adjacency == "dense" else None

    def cost_fn(self, *, drop_above: int | None | str = "auto") -> Callable:
        """Program-cost objective Σ exp(score) (fusion annealer
        contract)."""
        drop = self._default_drop() if drop_above == "auto" else drop_above

        def cost(kernels) -> float:
            ks = list(kernels)
            if drop is not None:
                ks = [k for k in ks if k.num_nodes <= drop]
            if not ks:
                return 0.0
            return float(np.sum(np.exp(self.estimate(ks))))
        return cost


class CascadeEstimator(CostEstimator):
    """Staged filtering: each stage scores the survivors of the previous
    one and keeps its top fraction; the final stage scores what's left
    (analytical prune → learned refine → optional hardware verify).

    Returned scores are *rank-faithful*, not calibrated: survivors carry
    the final stage's scores; pruned candidates are shifted above the
    survivor maximum (later-stage prunees ranking better than earlier
    ones, each set ordered by the stage that pruned it). Rankings — which
    is all top-k search consumes — are exact; don't feed cascade scores
    to an absolute-error metric.

    `keep` is a fraction (0,1] or an absolute count, scalar or per
    non-final stage — applied PER GROUP under `estimate_groups`, so every
    kernel keeps its own refine candidates regardless of how expensive
    it is in absolute terms (a flat cross-kernel prune would starve the
    analytically-expensive kernels, exactly the ones worth refining).
    Budgeted final stages (a `HardwareEstimator` with a meter) charge as
    usual; `queries` of each stage tell you what the cascade saved.
    `adjacency`/`max_nodes` are inherited from the final (refine) stage.
    """

    name = "cascade"

    def __init__(self, stages: Sequence[CostEstimator],
                 keep: float | int | Sequence[float | int] = 0.5,
                 min_keep: int = 1):
        super().__init__()
        if len(stages) < 1:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        n_prune = len(self.stages) - 1
        keeps = list(keep) if isinstance(keep, (list, tuple)) \
            else [keep] * n_prune
        if len(keeps) != n_prune:
            raise ValueError(f"{len(keeps)} keep values for {n_prune} "
                             "pruning stages")
        self.keeps = keeps
        self.min_keep = int(min_keep)
        self.adjacency = getattr(self.stages[-1], "adjacency", None)
        self.max_nodes = getattr(self.stages[-1], "max_nodes", None)

    def _keep_count(self, stage_i: int, n: int) -> int:
        k = self.keeps[stage_i]
        k = int(math.ceil(k * n)) if isinstance(k, float) and k <= 1.0 \
            else int(k)
        return max(min(k, n), min(self.min_keep, n))

    def _run(self, groups: list[list[KernelGraph]]) -> list[np.ndarray]:
        """The staged loop over per-group active sets; every stage still
        scores ALL groups' survivors in one batched call."""
        actives = [np.arange(len(g)) for g in groups]
        outs = [np.empty((len(g),), np.float64) for g in groups]
        pruned: list[list[tuple[np.ndarray, np.ndarray]]] = \
            [[] for _ in groups]
        for si, stage in enumerate(self.stages):
            flat = [groups[gi][int(j)]
                    for gi, act in enumerate(actives) for j in act]
            s = stage.estimate(flat)
            off = 0
            last = si == len(self.stages) - 1
            for gi, act in enumerate(actives):
                sg = s[off:off + len(act)]
                off += len(act)
                if last:
                    outs[gi][act] = sg
                    continue
                k = self._keep_count(si, len(act))
                order = np.argsort(sg, kind="stable")
                pruned[gi].append((act[order[k:]], sg[order[k:]]))
                actives[gi] = act[order[:k]]
        for gi, out in enumerate(outs):
            final = out[actives[gi]]
            hi = float(final.max()) if len(final) else 0.0
            # later-stage prunees outrank earlier ones; within a chunk
            # the pruning stage's own order is preserved (squashed into
            # (0, 1))
            for idx, sg in reversed(pruned[gi]):
                if not len(idx):
                    continue
                rank = np.empty(len(sg))
                rank[np.argsort(sg, kind="stable")] = np.arange(len(sg))
                out[idx] = hi + 1.0 + rank / max(len(sg), 1)
                hi = float(out[idx].max())
        return outs

    def _estimate(self, kernels: list[KernelGraph]) -> np.ndarray:
        return self._run([kernels])[0]

    def estimate_groups(self, groups: Sequence[Sequence[KernelGraph]]
                        ) -> list[np.ndarray]:
        """Per-group staged pruning (each group keeps its own top
        fraction), with every stage batched across all groups."""
        groups = [list(g) for g in groups]
        outs = self._run(groups)
        self._queries += sum(len(g) for g in groups)
        return outs

    # Cascade scores are ordinal: prunees carry synthetic rank-shift
    # values, and survivor scores keep the final stage's units. Summing
    # or exponentiating them would be comparing noise, so the
    # calibrated-output surfaces refuse loudly.
    def runtimes(self, kernels: Sequence[KernelGraph]) -> np.ndarray:
        raise TypeError(
            "CascadeEstimator scores are rank-only (pruned candidates "
            "carry synthetic rank scores); query a calibrated stage "
            "(e.g. the learned refine estimator) directly for runtimes")

    def program_costs(self, groups: Sequence[Sequence[KernelGraph]]
                      ) -> np.ndarray:
        raise TypeError(
            "CascadeEstimator cannot serve as a program-cost objective "
            "(its scores are rank-only) — pass the learned or analytical "
            "estimator itself to the fusion autotuner and keep the "
            "cascade for top-k candidate ranking")
