"""Unified cost estimation + budgeted search (DESIGN.md §10).

`CostEstimator` adapters (hardware / analytical / learned / cascade) with
shared `BudgetMeter` accounting, and the batched search engine
(`topk_rerank`, population `anneal`) both autotuners are thin wrappers
over. `AcquisitionEstimator` adds the MC-dropout variance head +
budget routing of the data flywheel (DESIGN.md §15).
"""
from repro.search.acquisition import AcquisitionEstimator, route_variance
from repro.search.engine import (
    AnnealResult,
    RerankChoice,
    anneal,
    score_groups,
    topk_rerank,
)
from repro.search.estimator import (
    AnalyticalEstimator,
    BudgetExhausted,
    BudgetMeter,
    CascadeEstimator,
    CostEstimator,
    HardwareEstimator,
    LearnedEstimator,
)

__all__ = [
    "AcquisitionEstimator", "AnalyticalEstimator", "AnnealResult",
    "BudgetExhausted", "BudgetMeter", "CascadeEstimator", "CostEstimator",
    "HardwareEstimator", "LearnedEstimator", "RerankChoice", "anneal",
    "route_variance", "score_groups", "topk_rerank",
]
