"""Generic budgeted search loops (DESIGN.md §10).

Two primitives, each scoring candidates through a `CostEstimator` in as
few batched calls as possible:

* `topk_rerank` — score EVERY candidate of every group in one coalesced
  estimator call, then verify each group's model-top-k on hardware within
  the shared `BudgetMeter`. Generalizes the tile autotuner: a whole
  program's kernels × tile candidates reach the prediction service as a
  single flush instead of a per-kernel Python loop.
* `anneal` — population-based simulated annealing: every temperature step
  proposes `population` candidate states and scores the unseen ones in ONE
  batched call. With `population=1` it replays the classic sequential
  annealer exactly (same RNG draw sequence, same visit order, bit-equal
  costs); with `population>1` each flush amortizes dispatch overhead
  across the whole population — the autotuner's scoring-throughput win.

Both loops only ever *stop* on budget exhaustion (never over-charge): the
meter is asked what is affordable before any hardware is touched.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.search.estimator import BudgetMeter, CostEstimator


# ----------------------------------------------------------------------------
# top-k rerank
# ----------------------------------------------------------------------------
@dataclass
class RerankChoice:
    """Outcome for one candidate group."""
    chosen: int                   # candidate index within the group
    chosen_runtime: float         # measured; NaN if budget allowed none
    measured: list[tuple[int, float]] = field(default_factory=list)
    scores: np.ndarray | None = None

    @property
    def hardware_evals(self) -> int:
        return len(self.measured)


def score_groups(estimator: CostEstimator,
                 groups: Sequence[Sequence[KernelGraph]]
                 ) -> list[np.ndarray]:
    """All groups' candidates through one batched estimator call."""
    return estimator.estimate_groups(groups)


def topk_rerank(groups: Sequence[Sequence[KernelGraph]], *,
                measure: Callable[[KernelGraph], float],
                estimator: CostEstimator | None = None,
                scores: Sequence[np.ndarray] | None = None,
                top_k: int = 10,
                meter: BudgetMeter | None = None) -> list[RerankChoice]:
    """Model-rank every group, measure each group's top-k on hardware.

    Exactly one of `estimator` / `scores` supplies the model ranking
    (`scores[g][i]` = model score of candidate i of group g; lower =
    faster). `measure(graph) -> seconds` is the raw hardware call — the
    engine charges `meter` (one eval per measurement) and simply stops
    measuring when the budget runs out, leaving later groups to fall back
    to their model-best candidate (`chosen_runtime=NaN`, zero evals).
    """
    if (estimator is None) == (scores is None):
        raise ValueError("exactly one of estimator/scores required")
    if scores is None:
        scores = score_groups(estimator, groups)
    if len(scores) != len(groups):
        raise ValueError(f"{len(scores)} score arrays for "
                         f"{len(groups)} groups")
    out = []
    for group, s in zip(groups, scores):
        s = np.asarray(s)
        if len(s) != len(group):
            raise ValueError("scores misaligned with group")
        order = np.argsort(s)[:max(top_k, 1)]
        measured: list[tuple[int, float]] = []
        for i in order:
            if meter is not None:
                if meter.affordable(1) < 1:
                    break
                meter.charge(1)
            measured.append((int(i), float(measure(group[int(i)]))))
        if measured:
            bi, bt = min(measured, key=lambda x: x[1])
        else:                       # budget allowed nothing: trust the model
            bi, bt = int(order[0]), float("nan")
        out.append(RerankChoice(chosen=bi, chosen_runtime=bt,
                                measured=measured, scores=s))
    return out


# ----------------------------------------------------------------------------
# population-based simulated annealing
# ----------------------------------------------------------------------------
@dataclass
class AnnealResult:
    visited: list[tuple[float, Any]]   # (cost, state), best-first
    evals: int                         # unique states scored
    steps: int                         # temperature steps taken
    budget_stopped: bool = False       # ended early on budget exhaustion

    @property
    def best(self) -> tuple[float, Any]:
        return self.visited[0]


def anneal(initial: Any, *,
           propose: Callable[[Any, np.random.Generator], Any],
           cost_many: Callable[[list[Any]], Sequence[float]],
           steps: int, rng: np.random.Generator,
           t0: float = 0.1, t1: float = 1e-3,
           population: int = 1,
           key: Callable[[Any], Hashable] = lambda s: s,
           meter: BudgetMeter | None = None) -> AnnealResult:
    """Simulated annealing over arbitrary states.

    `propose(cur, rng)` draws one candidate from the current state;
    `cost_many(states)` scores a batch in one call (this is where the
    population batching pays — back it with
    `CostEstimator.program_costs` / one service flush). `key` makes
    states hashable for the visited-cache (revisits are free). `meter`,
    when given, limits *evaluations*: a step that cannot afford all its
    unseen proposals scores only the affordable prefix and ends the
    search (`cost_many` is expected to do the actual charging — e.g.
    `HardwareEstimator.measure_program`).

    With `population=1` and the same `rng`, the visit sequence is
    bit-identical to the classic sequential loop this generalizes
    (`fusion_autotuner._anneal` pre-refactor): one `rng.random()` for the
    flip count, one `rng.integers` per flip, and the Metropolis draw only
    when the candidate is not already an improvement.
    """
    if population < 1:
        raise ValueError(f"population must be >= 1, got {population}")
    if meter is not None and meter.affordable(1) < 1:
        return AnnealResult([], evals=0, steps=0, budget_stopped=True)
    cur = initial
    cur_cost = float(cost_many([cur])[0])
    visited: dict[Hashable, float] = {key(cur): cur_cost}
    best: list[tuple[float, Any]] = [(cur_cost, cur)]
    evals = 1
    budget_stopped = False
    steps_taken = 0
    for i in range(steps):
        temp = t0 * (t1 / t0) ** (i / max(steps - 1, 1))
        cands = [propose(cur, rng) for _ in range(population)]
        # unseen unique states, in proposal order
        need: list[tuple[Hashable, Any]] = []
        batch_keys: set[Hashable] = set()
        for c in cands:
            k = key(c)
            if k not in visited and k not in batch_keys:
                batch_keys.add(k)
                need.append((k, c))
        if need:
            allowed = len(need) if meter is None \
                else meter.affordable(len(need))
            if allowed < len(need):
                need = need[:allowed]
                budget_stopped = True
            if need:
                costs = cost_many([c for _, c in need])
                for (k, c), cv in zip(need, costs):
                    cv = float(cv)
                    visited[k] = cv
                    best.append((cv, c))
                    evals += 1
        # Metropolis sweep in proposal order; unscored (budget-cut)
        # candidates are skipped
        for c in cands:
            k = key(c)
            if k not in visited:
                continue
            c_cost = visited[k]
            if c_cost < cur_cost or rng.random() < np.exp(
                    -(c_cost - cur_cost) / max(temp * cur_cost, 1e-30)):
                cur, cur_cost = c, c_cost
        steps_taken = i + 1
        if budget_stopped:
            break
    best.sort(key=lambda x: x[0])
    return AnnealResult(best, evals=evals, steps=steps_taken,
                        budget_stopped=budget_stopped)
