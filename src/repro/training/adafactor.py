"""Adafactor (Shazeer & Stern, 2018), simplified: factored second moment,
no first moment — the optimizer-state memory trick that lets 671B-param
training fit the single-pod HBM budget (see EXPERIMENTS.md §Dry-run).

State per ≥2D leaf: row/col second-moment factors (O(n+m) instead of O(nm));
per 1D leaf: full second moment. Update is RMS-clipped like the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS1 = 1e-30
_EPS2 = 1e-3


def _leaf_init(p):
    if p.ndim >= 2:
        return {
            "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
            "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
        }
    return {"v": jnp.zeros(p.shape, jnp.float32)}


def adafactor_init(params) -> dict:
    return {
        "factored": jax.tree_util.tree_map(_leaf_init, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _leaf_update(p, g, s, beta2, lr, clip_threshold=1.0):
    g = g.astype(jnp.float32)
    g2 = jnp.square(g) + _EPS1
    if p.ndim >= 2:
        v_row = beta2 * s["v_row"] + (1 - beta2) * jnp.mean(g2, axis=-1)
        v_col = beta2 * s["v_col"] + (1 - beta2) * jnp.mean(g2, axis=-2)
        row_mean = jnp.mean(v_row, axis=-1, keepdims=True)
        r = v_row / jnp.maximum(row_mean, _EPS1)
        u = g * jax.lax.rsqrt(r[..., None] * v_col[..., None, :] + _EPS1)
        new_s = {"v_row": v_row, "v_col": v_col}
    else:
        v = beta2 * s["v"] + (1 - beta2) * g2
        u = g * jax.lax.rsqrt(v + _EPS1)
        new_s = {"v": v}
    # RMS-clip the update
    rms = jnp.sqrt(jnp.mean(jnp.square(u)) + _EPS1)
    u = u / jnp.maximum(1.0, rms / clip_threshold)
    scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))),
                        _EPS2)
    new_p = (p.astype(jnp.float32) - lr * scale * u).astype(p.dtype)
    return new_p, new_s


def adafactor_update(params, grads, state, *, lr: float = 1e-2,
                     beta2_base: float = 0.999):
    step = state["step"] + 1
    # increasing-beta2 schedule from the paper
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -0.8)
    beta2 = jnp.minimum(beta2, beta2_base)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["factored"])
    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        np_, ns_ = _leaf_update(p, g, s, beta2, lr)
        new_p.append(np_)
        new_s.append(ns_)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"factored": jax.tree_util.tree_unflatten(treedef, new_s),
             "step": step},
            {"lr": jnp.asarray(lr, jnp.float32),
             "grad_norm": jnp.sqrt(sum(jnp.sum(jnp.square(
                 g.astype(jnp.float32))) for g in flat_g))})
