"""Atomic, manifest-driven, *elastic* checkpointing.

* Every leaf of the state pytree is saved as its own .npy file plus a JSON
  manifest (tree structure via tree_util key-paths, shapes, dtypes, step,
  and arbitrary user metadata).
* Atomicity: everything is written into `<dir>/.tmp-<step>` and renamed to
  `<dir>/step_<step>` in one `os.replace` — a killed writer never corrupts
  an existing checkpoint (the fault-tolerance tests kill a trainer mid-save).
* Elastic restore: leaves are loaded host-side as numpy and re-placed with
  whatever shardings the *restoring* mesh wants — a run checkpointed on a
  (16,16) mesh restores cleanly onto (2,16,16) or a single device. Nothing
  about the mesh is baked into the files.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np

_MANIFEST = "manifest.json"
_PREFIX = "step_"

_LAYER_RE = re.compile(r"/layers/(\d+)/")


def _resolve_leaf(key: str, want_shape: tuple, by_key: dict, path: str):
    """Load the checkpoint leaf for template key `key`, converting between
    the unrolled (`.../layers/<i>/...`) and stacked (`.../stacked/...`) GNN
    layouts when the on-disk layout differs from the template's
    (core/gnn.py `stack_params`; DESIGN.md §12). Bit-exact both ways:
    stacking is `np.stack` of the per-layer arrays, unstacking is a slice.

    Returns the numpy array, or None if the key can't be resolved.
    """
    if key in by_key:
        return np.load(os.path.join(path, by_key[key]["file"]))
    if "/stacked/" in key and len(want_shape) >= 1:
        # template wants stacked [L, ...]; try per-layer on-disk leaves
        num = want_shape[0]
        parts = []
        for i in range(num):
            k = key.replace("/stacked/", f"/layers/{i}/")
            if k not in by_key:
                return None
            parts.append(np.load(os.path.join(path, by_key[k]["file"])))
        return np.stack(parts, axis=0)
    m = _LAYER_RE.search(key)
    if m is not None:
        # template wants layer i unrolled; try the stacked on-disk leaf
        k = key[:m.start()] + "/stacked/" + key[m.end():]
        if k in by_key:
            stacked = np.load(os.path.join(path, by_key[k]["file"]))
            i = int(m.group(1))
            if i < stacked.shape[0]:
                return stacked[i]
    return None


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state, *,
                    meta: dict | None = None, keep: int = 3) -> str:
    """Save `state` (any pytree of arrays) for `step`. Returns final path.

    Multi-host discipline is process-0-writes / all-restore: non-primary
    processes return the would-be path without touching disk (leaves are
    device_get to full host arrays, so process 0 holds every byte), while
    `restore_checkpoint` runs on every process and re-places leaves with
    whatever shardings its mesh wants.
    """
    final = os.path.join(ckpt_dir, f"{_PREFIX}{step:08d}")
    if jax.process_index() != 0:
        return final
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys, leaves, _ = _leaf_paths(state)
    manifest = {"step": int(step), "meta": meta or {}, "leaves": []}
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    # retention
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"{_PREFIX}{s:08d}"),
                      ignore_errors=True)
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith(_PREFIX):
            # ignore incomplete dirs (no manifest)
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                out.append(int(name[len(_PREFIX):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `like` (a pytree template).

    `shardings` — optional pytree (same structure) of jax.sharding.Sharding
    to place leaves onto a (possibly different) mesh; None = default device.
    Returns (state, step, meta).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"{_PREFIX}{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    keys, leaves, treedef = _leaf_paths(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    if len(shard_leaves) != len(leaves):
        raise ValueError("shardings tree does not match state tree")
    for key, leaf, shd in zip(keys, leaves, shard_leaves):
        want_shape = tuple(np.shape(leaf))
        arr = _resolve_leaf(key, want_shape, by_key, path)
        if arr is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != {want_shape}")
        if shd is not None:
            new_leaves.append(jax.device_put(arr, shd))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return state, int(manifest["step"]), manifest.get("meta", {})
