"""GPipe-style pipeline parallelism over a named mesh axis.

Stage s holds its own slice of the layer stack; microbatch m flows through
stage s at schedule step t = s + m; activations hop stages with
`lax.ppermute`. Bubble overhead is the standard (S−1)/(M+S−1).

This is the PP building block for the multi-pod "pod" axis (2 stages) —
the dry-run's default pod-axis use is data-parallel, but
`pipeline_apply` + `tests/test_pipeline.py` demonstrate the schedule is
available and correct when layer memory, not batch, is the binding
constraint at 1000+ nodes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.context import shard_map_nocheck


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh: Mesh,
                   axis: str = "stage"):
    """Run a pipeline of `n_stages = mesh.shape[axis]` stages.

    stage_fn(params_slice, x) -> y, with y.shape == x.shape (inter-stage
    activations are homogeneous).
    stage_params: pytree with leading dim n_stages on every leaf (sharded
    over `axis`).
    x_micro: [M, mb, ...] microbatched input (replicated).
    Returns [M, mb, ...] outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    M = x_micro.shape[0]
    T = n_stages + M - 1

    p_specs = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    def per_device(params_local, xs):
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        buf0 = jnp.zeros_like(xs[0])

        def step(buf, t):
            m_in = jnp.clip(t, 0, M - 1)
            inp = jnp.where(stage_id == 0, xs[m_in], buf)
            y = stage_fn(params_local, inp)
            out = jnp.where(stage_id == n_stages - 1, y, 0.0)
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return nxt, out

        _, outs = jax.lax.scan(step, buf0, jnp.arange(T))
        # last stage emits microbatch m at step t = m + n_stages - 1
        outs = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, M, axis=0)
        # broadcast final-stage outputs to all stages for a replicated result
        return jax.lax.psum(outs, axis) if n_stages > 1 else outs

    fn = shard_map_nocheck(per_device, mesh,
                           in_specs=(p_specs, P()), out_specs=P())
    return fn(stage_params, x_micro)


def pipeline_stage_split(params_stacked, n_stages: int):
    """Split a [L, ...]-stacked layer tree into [n_stages, L/S, ...]."""
    def one(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree_util.tree_map(one, params_stacked)
