"""AdamW + gradient clipping + LR schedules (no optax in this environment).

Matches the paper's training hyperparameters: tunable learning rate,
exponential learning-rate decay, and optional global-norm gradient clipping
(Appendix B's 'Grad. clip: norm').
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float | None = 1.0      # None = no clipping
    schedule: str = "exponential"            # constant | exponential | cosine
    lr_decay: float = 0.99                   # per decay_every steps
    decay_every: int = 10_000
    warmup_steps: int = 0
    total_steps: int = 100_000               # cosine horizon


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step_f = step.astype(jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(step_f / cfg.warmup_steps, 1.0)
    else:
        warm = 1.0
    if cfg.schedule == "constant":
        base = cfg.lr
    elif cfg.schedule == "exponential":
        base = cfg.lr * jnp.power(cfg.lr_decay, step_f / cfg.decay_every)
    elif cfg.schedule == "cosine":
        frac = jnp.clip(step_f / max(cfg.total_steps, 1), 0.0, 1.0)
        base = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    else:
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    return base * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), gn


def adamw_init(params) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gn = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip_norm)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
        state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    step_f = step.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - jnp.power(b1, step_f))
    vhat_scale = 1.0 / (1.0 - jnp.power(b2, step_f))

    def upd(p, m_, v_):
        u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + cfg.eps)
        if cfg.weight_decay > 0:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, \
        {"lr": lr, "grad_norm": gn}
