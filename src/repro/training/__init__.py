"""Training substrate: optimizer, schedules, checkpointing (elastic),
gradient compression, pipeline parallelism, and the cost-model trainer."""
from repro.training.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    schedule_lr,
)
from repro.training.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.compression import (
    compress_int8,
    decompress_int8,
    compressed_allreduce,
)
from repro.training.trainer import CostModelTrainer, TrainerConfig

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
    "global_norm", "schedule_lr", "latest_step", "restore_checkpoint",
    "save_checkpoint", "compress_int8", "decompress_int8",
    "compressed_allreduce", "CostModelTrainer", "TrainerConfig",
]
