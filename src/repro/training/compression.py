"""Int8 gradient compression with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism at
1000+-node scale: gradients are quantized to int8 with a shared per-leaf
scale before the cross-replica reduction, and the local quantization error
is fed back into the next step's gradient (error feedback keeps SGD/Adam
convergence; Karimireddy et al., 2019).

Algorithm per leaf g (inside shard_map over the data axis):
  1. scale = pmax(max|g|) / 127                (one scalar all-reduce)
  2. q     = round(g / scale)  ∈ int8
  3. s     = psum(q.int32)                     (int8 wire bytes, exact sum)
  4. ĝ     = s * scale                         (sum of replicas' gradients)
  5. e'    = g - q * scale                     (local error, fed back next step)

The scale/clip/round primitives live in `repro.quant.scale` — one shared
module with the inference-side weight quantizer (DESIGN.md §14) — so the
int8 math here and in `repro.quant` cannot drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.scale import amax_scale, dequantize_int8, quantize_int8


def compress_int8(g: jnp.ndarray, scale: jnp.ndarray):
    """Quantize with a given positive scale; returns (q_int8, local_error)."""
    q = quantize_int8(g, scale)
    err = g - q.astype(g.dtype) * scale
    return q, err


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return dequantize_int8(q, scale, dtype)


def compressed_allreduce(grads, error_feedback, axis_name: str | None):
    """All-reduce `grads` (a pytree) with int8 quantization + error feedback.

    Must be called inside shard_map/pmap context over `axis_name`;
    with axis_name=None it degrades to the identity algorithm on one device
    (still quantizes, so the error-feedback math is exercised everywhere).

    Returns (reduced_grads_mean, new_error_feedback).
    """
    def one(g, e):
        g = g + e                                    # error feedback
        amax = jnp.max(jnp.abs(g))
        if axis_name is not None:
            amax = jax.lax.pmax(amax, axis_name)
        scale = amax_scale(amax)
        q, err = compress_int8(g, scale)
        s = q.astype(jnp.int32)
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        else:
            n = 1.0
        return decompress_int8(s, scale, g.dtype) / n, err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return red, err


def zeros_like_error(params):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
