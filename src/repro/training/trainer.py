"""Cost-model trainer: pjit/shard_map distribution, fault tolerance,
checkpoint/resume, optional int8-compressed data parallelism.

The trainer is deliberately framework-grade rather than script-grade:
  * deterministic batch streams (seed, step, host) — restart-reproducible,
  * SIGTERM/SIGINT-safe: a final checkpoint is written on the way out,
  * periodic atomic checkpoints + automatic resume from the latest,
  * metrics streamed to JSONL for the benchmark harness,
  * data parallelism over a named mesh axis; parameters are replicated
    (the model is ~1-10M params — DP is the right parallelism; the LM zoo
    under repro.models exercises TP/FSDP/EP/SP instead).

Batches are whatever the sampler yields: dense `features.GraphBatch` or
packed `features.SparseGraphBatch` (adjacency='sparse'; DESIGN.md §4). The
jit step caches one executable per batch shape, so sparse batches must come
from the pow2-bucketed batcher in `repro.data.batching` to bound
recompilation. Sparse batches have no uniform leading batch dim, so the
int8 compressed-DP path (which shards on it) is dense-only.

With `TrainerConfig.prefetch > 0` the sampler is wrapped in a
`repro.data.prefetch.Prefetcher`: a background thread encodes that many
batches ahead of the jitted step (optionally staging them on device), with
a byte-identical batch stream and restart-safe determinism (DESIGN.md §9).

The sampler's record list may be a `repro.data.store.StreamingCorpus` (or
a split view of one): records then stream shard-by-shard from disk as
batches draw them, with a byte-identical batch stream to in-memory records
— `python -m repro.launch.train cost-model --from-store` is this path
(DESIGN.md §11, docs/DATA.md).
"""
from __future__ import annotations

import json
import os
import signal
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.losses import log_mse_loss, mse_loss, pairwise_rank_loss
from repro.core.model import CostModelConfig, cost_model_apply, cost_model_init
from repro.training import checkpoint as ckpt_lib
from repro.training.compression import compressed_allreduce, zeros_like_error
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainerConfig:
    task: str = "tile"                   # tile | fusion | fusion_mse
    rank_phi: str = "hinge"              # hinge | logistic (tile task)
    steps: int = 2000
    ckpt_every: int = 500
    log_every: int = 100
    keep_ckpts: int = 3
    seed: int = 0
    ckpt_dir: str = ""
    metrics_path: str = ""
    compress_grads: bool = False          # int8 + error feedback over DP axis
    data_axis: str = "data"
    # async input pipeline (DESIGN.md §9): number of batches a background
    # thread encodes ahead of the jitted step (0 = synchronous encode). The
    # delivered batch stream is byte-identical either way; `.run` owns the
    # worker's lifecycle (started per run, stopped on exit/interrupt).
    prefetch: int = 0
    prefetch_device_put: bool = False     # also overlap host->device copies
    optim: AdamWConfig = field(default_factory=AdamWConfig)


def make_mesh_1d(axis: str = "data") -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(-1), (axis,))


class CostModelTrainer:
    def __init__(self, model_cfg: CostModelConfig, cfg: TrainerConfig,
                 sampler, mesh: Mesh | None = None):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.sampler = sampler
        self.mesh = mesh or make_mesh_1d(cfg.data_axis)
        self.step = 0
        self._stop = False
        self._metrics_f = None

        # reject dense-only config combos here rather than as a
        # NotImplementedError buried in the first step's jit trace
        if model_cfg.adjacency in ("sparse", "segmented"):
            if cfg.compress_grads:
                raise ValueError(
                    "compress_grads shards batches on a leading batch dim; "
                    "packed sparse batches have none — use adjacency='dense'")
            if model_cfg.use_pallas_aggregate:
                raise ValueError(
                    "use_pallas_aggregate targets the dense [B,N,N] layout "
                    "— use adjacency='dense' with it")
            if model_cfg.gnn == "gat" and not model_cfg.directed:
                raise ValueError(
                    "undirected GAT is dense-only (DESIGN.md §4) — use "
                    "adjacency='dense'")

        key = jax.random.key(cfg.seed)
        self.params = cost_model_init(key, model_cfg)
        self.opt_state = adamw_init(self.params)
        if cfg.compress_grads:
            self.opt_state["ef"] = zeros_like_error(self.params)

        self._train_step = self._build_train_step()

    # ------------------------------------------------------------------
    def _loss_fn(self, params, batch, targets, group_ids, valid, rng):
        preds = cost_model_apply(params, self.model_cfg, batch, rng=rng,
                                 deterministic=False)
        if self.cfg.task == "tile":
            return pairwise_rank_loss(preds, targets, group_ids, valid,
                                      phi=self.cfg.rank_phi)
        if self.cfg.task == "fusion":
            return log_mse_loss(preds, targets, valid)
        if self.cfg.task == "fusion_mse":
            return mse_loss(preds, targets, valid)
        if self.cfg.task == "tile_mse":
            # ablation row 'MSE loss (not rank)': absolute (log) runtimes
            return log_mse_loss(preds, targets, valid)
        raise ValueError(f"unknown task {self.cfg.task!r}")

    def _build_train_step(self):
        cfg = self.cfg
        mesh = self.mesh
        data_spec = P(cfg.data_axis)
        repl = NamedSharding(mesh, P())

        def batch_shardings(batch_tree):
            def spec_for(x):
                if x.ndim >= 1:
                    return NamedSharding(mesh, data_spec)
                return repl
            return jax.tree_util.tree_map(spec_for, batch_tree)

        if not cfg.compress_grads:
            @partial(jax.jit, donate_argnums=(0,))
            def train_step(params, opt_state, batch, targets, group_ids,
                           valid, rng):
                loss, grads = jax.value_and_grad(self._loss_fn)(
                    params, batch, targets, group_ids, valid, rng)
                new_params, new_opt, stats = adamw_update(
                    params, grads, opt_state, cfg.optim)
                stats["loss"] = loss
                return new_params, new_opt, stats
            self._batch_shardings = batch_shardings
            return train_step

        # compressed-DP path: per-device grads + int8 all-reduce
        axis = cfg.data_axis

        def shmap_step(params, opt_state, batch, targets, group_ids, valid,
                       rng):
            ef = opt_state["ef"]

            def local(params, batch, targets, group_ids, valid, ef):
                loss, grads = jax.value_and_grad(self._loss_fn)(
                    params, batch, targets, group_ids, valid, rng)
                red, new_ef = compressed_allreduce(grads, ef, axis)
                loss = jax.lax.pmean(loss, axis)
                return loss, red, new_ef

            from repro.sharding.context import shard_map_nocheck
            spec_params = jax.tree_util.tree_map(lambda _: P(), params)
            spec_batch = jax.tree_util.tree_map(
                lambda x: P(axis) if x.ndim >= 1 else P(), batch)
            loss, grads, new_ef = shard_map_nocheck(
                local, mesh,
                in_specs=(spec_params, spec_batch, P(axis), P(axis), P(axis),
                          jax.tree_util.tree_map(lambda _: P(), ef)),
                out_specs=(P(), jax.tree_util.tree_map(lambda _: P(), params),
                           jax.tree_util.tree_map(lambda _: P(), ef)),
            )(params, batch, targets, group_ids, valid, ef)
            opt_no_ef = {k: v for k, v in opt_state.items() if k != "ef"}
            new_params, new_opt, stats = adamw_update(
                params, grads, opt_no_ef, cfg.optim)
            new_opt["ef"] = new_ef
            stats["loss"] = loss
            return new_params, new_opt, stats

        self._batch_shardings = batch_shardings
        return jax.jit(shmap_step, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass   # not on main thread (e.g. under pytest plugins)

    def _log(self, record: dict):
        if self.cfg.metrics_path:
            if self._metrics_f is None:
                os.makedirs(os.path.dirname(self.cfg.metrics_path) or ".",
                            exist_ok=True)
                self._metrics_f = open(self.cfg.metrics_path, "a")
            self._metrics_f.write(json.dumps(record) + "\n")
            self._metrics_f.flush()

    def save(self):
        if not self.cfg.ckpt_dir:
            return
        state = {"params": self.params, "opt": self.opt_state}
        ckpt_lib.save_checkpoint(
            self.cfg.ckpt_dir, self.step, state,
            meta={"model_cfg": self.model_cfg.to_dict(),
                  "task": self.cfg.task},
            keep=self.cfg.keep_ckpts)

    def maybe_resume(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        state, step, _ = ckpt_lib.restore_checkpoint(self.cfg.ckpt_dir, like)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step
        return True

    # ------------------------------------------------------------------
    def run(self, steps: int | None = None, *, resume: bool = True,
            eval_fn: Callable[[dict, int], dict] | None = None,
            eval_every: int = 0) -> dict:
        cfg = self.cfg
        total = steps if steps is not None else cfg.steps
        if resume:
            self.maybe_resume()
        self._install_signal_handlers()
        sampler = self.sampler
        if cfg.prefetch:
            from repro.data.prefetch import Prefetcher
            sampler = Prefetcher(self.sampler, depth=cfg.prefetch,
                                 start_step=self.step,
                                 device_put=cfg.prefetch_device_put)
        try:
            return self._run_loop(sampler, total, eval_fn, eval_every)
        finally:
            if sampler is not self.sampler:
                sampler.close()

    def _run_loop(self, sampler, total: int, eval_fn, eval_every) -> dict:
        cfg = self.cfg
        t0 = time.time()
        last_loss = float("nan")
        while self.step < total and not self._stop:
            b = sampler.batch(self.step)
            rng = jax.random.fold_in(jax.random.key(cfg.seed + 1), self.step)
            group_ids = getattr(b, "group_ids",
                                np.zeros_like(b.targets, np.int32))
            self.params, self.opt_state, stats = self._train_step(
                self.params, self.opt_state, b.graphs,
                jnp.asarray(b.targets), jnp.asarray(group_ids),
                jnp.asarray(b.valid), rng)
            self.step += 1
            if self.step % cfg.log_every == 0 or self.step == total:
                last_loss = float(stats["loss"])
                self._log({"step": self.step, "loss": last_loss,
                           "lr": float(stats["lr"]),
                           "grad_norm": float(stats["grad_norm"]),
                           "wall": time.time() - t0})
            if cfg.ckpt_every and self.step % cfg.ckpt_every == 0:
                self.save()
            if eval_fn and eval_every and self.step % eval_every == 0:
                ev = eval_fn(self.params, self.step)
                self._log({"step": self.step, **{f"eval/{k}": v
                                                 for k, v in ev.items()}})
        self.save()
        if self._metrics_f:
            self._metrics_f.close()
            self._metrics_f = None
        return {"step": self.step, "loss": last_loss,
                "wall": time.time() - t0, "interrupted": self._stop}
