"""Cost-model trainer: pjit/shard_map distribution, fault tolerance,
checkpoint/resume, optional int8-compressed data parallelism.

The trainer is deliberately framework-grade rather than script-grade:
  * deterministic batch streams (seed, step, host) — restart-reproducible,
  * SIGTERM/SIGINT-safe: a final checkpoint is written on the way out,
  * periodic atomic checkpoints + automatic resume from the latest,
  * metrics streamed to JSONL for the benchmark harness,
  * data parallelism over a named mesh axis; parameters are replicated
    (the model is ~1-10M params — DP is the right parallelism; the LM zoo
    under repro.models exercises TP/FSDP/EP/SP instead).

Batches are whatever the sampler yields: dense `features.GraphBatch` or
packed `features.SparseGraphBatch` (adjacency='sparse'; DESIGN.md §4). The
jit step caches one executable per batch shape, so sparse batches must come
from the pow2-bucketed batcher in `repro.data.batching` to bound
recompilation. Sparse batches have no uniform leading batch dim, so the
int8 compressed-DP path (which shards on it) is dense-only.

With `TrainerConfig.prefetch > 0` the sampler is wrapped in a
`repro.data.prefetch.Prefetcher`: a background thread encodes that many
batches ahead of the jitted step (optionally staging them on device), with
a byte-identical batch stream and restart-safe determinism (DESIGN.md §9).

The sampler's record list may be a `repro.data.store.StreamingCorpus` (or
a split view of one): records then stream shard-by-shard from disk as
batches draw them, with a byte-identical batch stream to in-memory records
— `python -m repro.launch.train cost-model --from-store` is this path
(DESIGN.md §11, docs/DATA.md).

With `TrainerConfig.dp >= 1` the trainer runs the *mesh train step*
(DESIGN.md §13): a ``(dp, mp)`` mesh from `repro.sharding.make_train_mesh`,
the sampler wrapped in a `GlobalBatchSampler` whose batches carry a leading
[dp] device axis (each device trains on its own disjoint record shard),
per-device forward/backward under `shard_map` with psum'd loss and grads
— int8-compressed when `compress_grads` (which composes with sparse
batches here: the *global* batch has the leading axis the legacy path
lacked). ``dp=1`` is bit-identical to the legacy jit path — same batch
stream, same rng fold, pmean over a size-1 axis is exact. Checkpoints are
written by process 0 only and restore onto any dp layout (error-feedback
buffers, the one per-device-layout state, restart at zero across layouts).
"""
from __future__ import annotations

import json
import os
import signal
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.losses import log_mse_loss, mse_loss, pairwise_rank_loss
from repro.core.model import CostModelConfig, cost_model_apply, cost_model_init
from repro.training import checkpoint as ckpt_lib
from repro.training.compression import compressed_allreduce, zeros_like_error
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainerConfig:
    task: str = "tile"                   # tile | fusion | fusion_mse
    rank_phi: str = "hinge"              # hinge | logistic (tile task)
    steps: int = 2000
    ckpt_every: int = 500
    log_every: int = 100
    keep_ckpts: int = 3
    seed: int = 0
    ckpt_dir: str = ""
    metrics_path: str = ""
    compress_grads: bool = False          # int8 + error feedback over DP axis
    data_axis: str = "data"
    # mesh train step (DESIGN.md §13): dp=0 keeps the legacy single-device
    # jit path bit-for-bit; dp>=1 builds a (dp, mp) mesh, wraps the sampler
    # in a GlobalBatchSampler and shards the leading batch axis over
    # `data_axis`. dp=1 is bit-identical to dp=0 (bench_scaling gates it).
    dp: int = 0
    mp: int = 1                           # model axis size (params replicated)
    # async input pipeline (DESIGN.md §9): number of batches a background
    # thread encodes ahead of the jitted step (0 = synchronous encode). The
    # delivered batch stream is byte-identical either way; `.run` owns the
    # worker's lifecycle (started per run, stopped on exit/interrupt).
    prefetch: int = 0
    prefetch_device_put: bool = False     # also overlap host->device copies
    optim: AdamWConfig = field(default_factory=AdamWConfig)


def make_mesh_1d(axis: str = "data") -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(-1), (axis,))


class CostModelTrainer:
    def __init__(self, model_cfg: CostModelConfig, cfg: TrainerConfig,
                 sampler, mesh: Mesh | None = None):
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.step = 0
        self._stop = False
        self._metrics_f = None
        self._use_mesh = cfg.dp >= 1

        if cfg.dp < 0 or cfg.mp < 1:
            raise ValueError(f"dp must be >= 0 and mp >= 1, "
                             f"got dp={cfg.dp} mp={cfg.mp}")

        if model_cfg.precision != "f32":
            raise ValueError(
                f"training runs in f32, got precision="
                f"{model_cfg.precision!r} — train the f32 model and "
                "quantize afterwards (repro.quant.quantize_params)")

        # reject dense-only config combos here rather than as a
        # NotImplementedError buried in the first step's jit trace
        if self._use_mesh and model_cfg.adjacency == "segmented":
            raise ValueError(
                "segmented batches have no uniform leading axis to shard "
                "over the mesh — use adjacency='dense' or 'sparse' with "
                "TrainerConfig.dp")
        if model_cfg.adjacency in ("sparse", "segmented"):
            if cfg.compress_grads and not self._use_mesh:
                raise ValueError(
                    "compress_grads=True needs a leading batch dim to shard "
                    "and packed sparse batches have none; the mesh train "
                    "step stacks per-device sub-batches with one — set "
                    "TrainerConfig.dp >= 1 (compress_grads composes with "
                    "adjacency='sparse' there) or use adjacency='dense'")
            if model_cfg.use_pallas_aggregate:
                raise ValueError(
                    "use_pallas_aggregate on the sparse layouts routes "
                    "through kernels/segment_aggregate, which has no VJP — "
                    "it is inference-only; train with "
                    "use_pallas_aggregate=False (or adjacency='dense')")
            if model_cfg.gnn == "gat" and not model_cfg.directed:
                raise ValueError(
                    "undirected GAT is dense-only (DESIGN.md §4) — use "
                    "adjacency='dense'")

        if self._use_mesh:
            from repro.data.sampler import GlobalBatchSampler
            from repro.sharding.mesh import DATA_AXIS, make_train_mesh
            if cfg.data_axis != DATA_AXIS:
                raise ValueError(
                    f"the mesh train step uses axis {DATA_AXIS!r}; got "
                    f"data_axis={cfg.data_axis!r}")
            self.mesh = mesh or make_train_mesh(cfg.dp, cfg.mp)
            if isinstance(sampler, GlobalBatchSampler):
                if sampler.num_shards != cfg.dp:
                    raise ValueError(
                        f"GlobalBatchSampler has {sampler.num_shards} "
                        f"shards but dp={cfg.dp}")
                self.sampler = sampler
            else:
                self.sampler = GlobalBatchSampler.for_mesh(sampler, cfg.dp)
        else:
            self.mesh = mesh or make_mesh_1d(cfg.data_axis)
            self.sampler = sampler

        key = jax.random.key(cfg.seed)
        self.params = cost_model_init(key, model_cfg)
        self.opt_state = adamw_init(self.params)
        if cfg.compress_grads:
            ef = zeros_like_error(self.params)
            if self._use_mesh:
                # per-DEVICE residuals: leading [dp] axis, sharded P(data)
                ef = jax.tree_util.tree_map(
                    lambda x: jnp.zeros((cfg.dp,) + x.shape, x.dtype), ef)
            self.opt_state["ef"] = ef

        self._train_step = self._build_train_step()

    # ------------------------------------------------------------------
    def _loss_fn(self, params, batch, targets, group_ids, valid, rng):
        preds = cost_model_apply(params, self.model_cfg, batch, rng=rng,
                                 deterministic=False)
        if self.cfg.task == "tile":
            return pairwise_rank_loss(preds, targets, group_ids, valid,
                                      phi=self.cfg.rank_phi)
        if self.cfg.task == "fusion":
            return log_mse_loss(preds, targets, valid)
        if self.cfg.task == "fusion_mse":
            return mse_loss(preds, targets, valid)
        if self.cfg.task == "tile_mse":
            # ablation row 'MSE loss (not rank)': absolute (log) runtimes
            return log_mse_loss(preds, targets, valid)
        raise ValueError(f"unknown task {self.cfg.task!r}")

    def _build_train_step(self):
        if self._use_mesh:
            return self._build_mesh_step()
        cfg = self.cfg
        mesh = self.mesh
        data_spec = P(cfg.data_axis)
        repl = NamedSharding(mesh, P())

        def batch_shardings(batch_tree):
            def spec_for(x):
                if x.ndim >= 1:
                    return NamedSharding(mesh, data_spec)
                return repl
            return jax.tree_util.tree_map(spec_for, batch_tree)

        if not cfg.compress_grads:
            @partial(jax.jit, donate_argnums=(0,))
            def train_step(params, opt_state, batch, targets, group_ids,
                           valid, rng):
                loss, grads = jax.value_and_grad(self._loss_fn)(
                    params, batch, targets, group_ids, valid, rng)
                new_params, new_opt, stats = adamw_update(
                    params, grads, opt_state, cfg.optim)
                stats["loss"] = loss
                return new_params, new_opt, stats
            self._batch_shardings = batch_shardings
            return train_step

        # compressed-DP path: per-device grads + int8 all-reduce
        axis = cfg.data_axis

        def shmap_step(params, opt_state, batch, targets, group_ids, valid,
                       rng):
            ef = opt_state["ef"]

            def local(params, batch, targets, group_ids, valid, ef):
                loss, grads = jax.value_and_grad(self._loss_fn)(
                    params, batch, targets, group_ids, valid, rng)
                red, new_ef = compressed_allreduce(grads, ef, axis)
                loss = jax.lax.pmean(loss, axis)
                return loss, red, new_ef

            from repro.sharding.context import shard_map_nocheck
            spec_params = jax.tree_util.tree_map(lambda _: P(), params)
            spec_batch = jax.tree_util.tree_map(
                lambda x: P(axis) if x.ndim >= 1 else P(), batch)
            loss, grads, new_ef = shard_map_nocheck(
                local, mesh,
                in_specs=(spec_params, spec_batch, P(axis), P(axis), P(axis),
                          jax.tree_util.tree_map(lambda _: P(), ef)),
                out_specs=(P(), jax.tree_util.tree_map(lambda _: P(), params),
                           jax.tree_util.tree_map(lambda _: P(), ef)),
            )(params, batch, targets, group_ids, valid, ef)
            opt_no_ef = {k: v for k, v in opt_state.items() if k != "ef"}
            new_params, new_opt, stats = adamw_update(
                params, grads, opt_no_ef, cfg.optim)
            new_opt["ef"] = new_ef
            stats["loss"] = loss
            return new_params, new_opt, stats

        self._batch_shardings = batch_shardings
        return jax.jit(shmap_step, donate_argnums=(0,))

    def _build_mesh_step(self):
        """The dp (x mp) mesh train step (DESIGN.md §13).

        Inputs carry a leading [dp] device axis (GlobalBatchSampler); the
        step shards it over `data_axis`, runs the per-device
        forward/backward under shard_map, and psums loss + grads (int8
        `compressed_allreduce` when `compress_grads` — its error-feedback
        residuals live in `opt_state['ef']` with the same leading [dp]
        axis). The optimizer update runs once on the replicated mean
        gradient outside the shard_map, so params never diverge across
        devices. dp=1 is bit-identical to the legacy jit path: identical
        batch, identical rng, and psum/pmean over a size-1 axis is exact.
        """
        cfg = self.cfg
        mesh = self.mesh
        axis = cfg.data_axis
        compress = cfg.compress_grads

        from repro.sharding.context import (constrain_batch_tree,
                                            shard_map_nocheck)

        def repl(tree):
            return jax.tree_util.tree_map(lambda _: P(), tree)

        def lead(tree):
            return jax.tree_util.tree_map(lambda _: P(axis), tree)

        def squeeze(tree):
            return jax.tree_util.tree_map(lambda x: x[0], tree)

        def local(params, batch, targets, group_ids, valid, rngs, ef):
            loss, grads = jax.value_and_grad(self._loss_fn)(
                params, squeeze(batch), targets[0], group_ids[0], valid[0],
                rngs[0])
            if compress:
                grads, new_ef = compressed_allreduce(grads, squeeze(ef),
                                                     axis)
                new_ef = jax.tree_util.tree_map(lambda x: x[None], new_ef)
            else:
                grads = jax.lax.pmean(grads, axis)
                new_ef = ef
            return jax.lax.pmean(loss, axis), grads, new_ef

        @partial(jax.jit, donate_argnums=(0,))
        def mesh_step(params, opt_state, batch, targets, group_ids, valid,
                      rngs):
            batch = constrain_batch_tree(batch, leading=0)
            targets, group_ids, valid = constrain_batch_tree(
                (targets, group_ids, valid), leading=0)
            ef = opt_state.get("ef") if compress else {}
            loss, grads, new_ef = shard_map_nocheck(
                local, mesh,
                in_specs=(repl(params), lead(batch), P(axis), P(axis),
                          P(axis), P(axis), lead(ef)),
                out_specs=(P(), repl(params), lead(ef)),
            )(params, batch, targets, group_ids, valid, rngs, ef)
            opt_no_ef = {k: v for k, v in opt_state.items() if k != "ef"}
            new_params, new_opt, stats = adamw_update(
                params, grads, opt_no_ef, cfg.optim)
            if compress:
                new_opt["ef"] = new_ef
            stats["loss"] = loss
            return new_params, new_opt, stats

        self._batch_shardings = None
        return mesh_step

    # ------------------------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass   # not on main thread (e.g. under pytest plugins)

    def _log(self, record: dict):
        if self.cfg.metrics_path:
            if self._metrics_f is None:
                os.makedirs(os.path.dirname(self.cfg.metrics_path) or ".",
                            exist_ok=True)
                self._metrics_f = open(self.cfg.metrics_path, "a")
            self._metrics_f.write(json.dumps(record) + "\n")
            self._metrics_f.flush()

    def save(self):
        if not self.cfg.ckpt_dir:
            return
        state = {"params": self.params, "opt": self.opt_state}
        ckpt_lib.save_checkpoint(
            self.cfg.ckpt_dir, self.step, state,
            meta={"model_cfg": self.model_cfg.to_dict(),
                  "task": self.cfg.task},
            keep=self.cfg.keep_ckpts)

    def _state_shardings(self, like):
        """NamedSharding tree for `like`: everything replicated over the
        mesh except the per-device error-feedback residuals, which shard
        their leading [dp] axis over the data axis."""
        if not self._use_mesh:
            return None
        repl = NamedSharding(self.mesh, P())
        sh = jax.tree_util.tree_map(lambda _: repl, like)
        if "ef" in like.get("opt", {}):
            dps = NamedSharding(self.mesh, P(self.cfg.data_axis))
            sh["opt"]["ef"] = jax.tree_util.tree_map(
                lambda _: dps, like["opt"]["ef"])
        return sh

    def maybe_resume(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        try:
            state, step, _ = ckpt_lib.restore_checkpoint(
                self.cfg.ckpt_dir, like,
                shardings=self._state_shardings(like))
        except ValueError:
            if "ef" not in self.opt_state:
                raise
            # cross-dp-layout restore: error-feedback residuals are
            # per-device [dp, ...] state, so a checkpoint from a different
            # dp layout can't be mapped onto this one — restore everything
            # else bit-exactly and restart the residuals at zero (they are
            # quantization carry, not model state)
            like = {"params": self.params,
                    "opt": {k: v for k, v in self.opt_state.items()
                            if k != "ef"}}
            state, step, _ = ckpt_lib.restore_checkpoint(
                self.cfg.ckpt_dir, like,
                shardings=self._state_shardings(like))
            state["opt"]["ef"] = jax.tree_util.tree_map(
                jnp.zeros_like, self.opt_state["ef"])
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step
        return True

    def warm_start(self, ckpt_dir: str, *, step: int | None = None,
                   restore_opt: bool = True,
                   reset_opt_step: bool = True) -> int:
        """Initialize from ANOTHER run's checkpoint, keeping this run
        fresh — the flywheel fine-tune path (DESIGN.md §15, TLP-style).

        Unlike `maybe_resume` (which continues the same run: `self.step`
        jumps to the checkpoint step, so a finished run is a no-op),
        `warm_start` copies the checkpoint's params — and, with
        `restore_opt`, the AdamW moments — but leaves ``self.step`` at 0,
        so the full `cfg.steps` of fine-tuning actually run.

        `reset_opt_step=True` (default) also zeroes the *optimizer's*
        step counter, restarting the `AdamWConfig.warmup_steps` LR warmup
        — the short re-warmup that keeps fresh delta gradients from
        blowing away a good checkpoint. `reset_opt_step=False` preserves
        the counter: the schedule continues as if training never stopped.
        Error-feedback residuals (`opt['ef']`) are never imported — they
        are per-device quantization carry, not model state.

        Returns the checkpoint step warm-started from. Note `run`'s
        default ``resume=True`` still prefers a checkpoint in THIS run's
        `cfg.ckpt_dir` if one exists — pass ``resume=False`` (or a fresh
        ckpt_dir) when fine-tuning into a new directory.
        """
        pick = ckpt_lib.latest_step(ckpt_dir) if step is None else step
        if pick is None:
            raise FileNotFoundError(
                f"no checkpoint to warm-start from in {ckpt_dir!r}")
        like = {"params": self.params}
        if restore_opt:
            like["opt"] = {k: v for k, v in self.opt_state.items()
                           if k != "ef"}
        state, ck_step, _ = ckpt_lib.restore_checkpoint(
            ckpt_dir, like, step=pick,
            shardings=self._state_shardings(like))
        self.params = state["params"]
        if restore_opt:
            opt = dict(state["opt"])
            if reset_opt_step:
                opt["step"] = jnp.zeros_like(opt["step"])
            if "ef" in self.opt_state:
                opt["ef"] = self.opt_state["ef"]
            self.opt_state = opt
        self.step = 0
        return ck_step

    # ------------------------------------------------------------------
    def run(self, steps: int | None = None, *, resume: bool = True,
            eval_fn: Callable[[dict, int], dict] | None = None,
            eval_every: int = 0) -> dict:
        cfg = self.cfg
        total = steps if steps is not None else cfg.steps
        if resume:
            self.maybe_resume()
        self._install_signal_handlers()
        sampler = self.sampler
        if cfg.prefetch:
            from repro.data.prefetch import Prefetcher
            sampler = Prefetcher(self.sampler, depth=cfg.prefetch,
                                 start_step=self.step,
                                 device_put=cfg.prefetch_device_put)
        try:
            if self._use_mesh:
                from repro.sharding.context import activation_sharding
                mapping = {"dp": cfg.data_axis,
                           "axis_sizes": {cfg.data_axis: cfg.dp,
                                          "model": cfg.mp}}
                with self.mesh, activation_sharding(mapping):
                    return self._run_loop(sampler, total, eval_fn,
                                          eval_every)
            return self._run_loop(sampler, total, eval_fn, eval_every)
        finally:
            if sampler is not self.sampler:
                sampler.close()

    def _step_rng(self, step: int):
        base = jax.random.key(self.cfg.seed + 1)
        if not self._use_mesh:
            return jax.random.fold_in(base, step)
        # one key per device, folded from the SAME ladder the legacy path
        # climbs: device d of dp at step k folds in k*dp + d, so dp=1
        # device 0 gets fold_in(base, k) — bit-identical to legacy
        dp = self.cfg.dp
        return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            base, step * dp + jnp.arange(dp))

    def _run_loop(self, sampler, total: int, eval_fn, eval_every) -> dict:
        cfg = self.cfg
        t0 = time.time()
        last_loss = float("nan")
        while self.step < total and not self._stop:
            b = sampler.batch(self.step)
            rng = self._step_rng(self.step)
            group_ids = getattr(b, "group_ids",
                                np.zeros_like(b.targets, np.int32))
            self.params, self.opt_state, stats = self._train_step(
                self.params, self.opt_state, b.graphs,
                jnp.asarray(b.targets), jnp.asarray(group_ids),
                jnp.asarray(b.valid), rng)
            self.step += 1
            if self.step % cfg.log_every == 0 or self.step == total:
                last_loss = float(stats["loss"])
                self._log({"step": self.step, "loss": last_loss,
                           "lr": float(stats["lr"]),
                           "grad_norm": float(stats["grad_norm"]),
                           "wall": time.time() - t0})
            if cfg.ckpt_every and self.step % cfg.ckpt_every == 0:
                self.save()
            if eval_fn and eval_every and self.step % eval_every == 0:
                ev = eval_fn(self.params, self.step)
                self._log({"step": self.step, **{f"eval/{k}": v
                                                 for k, v in ev.items()}})
        self.save()
        if self._metrics_f:
            self._metrics_f.close()
            self._metrics_f = None
        return {"step": self.step, "loss": last_loss,
                "wall": time.time() - t0, "interrupted": self._stop}
