"""Cost-model serving client (docs/SERVING.md §server).

Synchronous request/response client for `repro.serving.server`: frames a
predict request (graphs as `KernelGraph.to_dict()` payloads), reads the
response, and turns the server's explicit error vocabulary into typed
exceptions. Transient failures — a dropped connection, a corrupt frame,
an `overloaded` shed, a `worker_failure` — are retried with exponential
backoff over a fresh connection (scoring is pure, so resends are
idempotent; a retried graph that was already scored is a cache hit).
`deadline_exceeded` is *not* retried: the caller's latency budget is
gone, retrying would only lie about it.

Import cost matters here: this module (and everything it pulls in) is
numpy+stdlib only, so the load benchmark can fan out client *processes*
that never pay the jax import.

>>> CostModelClient("127.0.0.1", 1, retries=0).retries
0
"""
from __future__ import annotations

import socket
import time
from typing import Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.serving.server import FrameError, recv_frame, send_frame


class ClientError(Exception):
    """Base class for serving-client failures."""


class Overloaded(ClientError):
    """Server shed the request at admission (queue full) and retries ran
    out."""


class DeadlineExceeded(ClientError):
    """The request's deadline passed before the server started scoring."""


class WorkerFailure(ClientError):
    """The server's scoring pass died (fault injection / bug) and retries
    ran out."""


class ServerShutdown(ClientError):
    """The server stopped before scoring the request."""


class ProtocolError(ClientError):
    """Undecodable frame, response/request id mismatch, or malformed
    response."""


_RETRYABLE_ERRORS = {"overloaded", "worker_failure"}
_ERROR_TYPES = {"overloaded": Overloaded,
                "deadline_exceeded": DeadlineExceeded,
                "worker_failure": WorkerFailure,
                "shutting_down": ServerShutdown}


class CostModelClient:
    """Retrying synchronous client for one cost-model server.

    Parameters:
      host, port   server address (`CostModelServer.address`)
      timeout_s    socket timeout per send/recv (a hung server surfaces
                   as `ClientError`, never as an indefinite block)
      retries      max *re*-attempts after a retryable failure
      backoff_s    initial backoff; doubles per attempt, capped at
                   `backoff_cap_s` (kept small — the admission queue
                   drains in milliseconds)
    """

    def __init__(self, host: str, port: int, *, timeout_s: float = 10.0,
                 retries: int = 3, backoff_s: float = 0.01,
                 backoff_cap_s: float = 0.1):
        self.host, self.port = host, int(port)
        self.timeout_s = float(timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sock: socket.socket | None = None
        self._next_id = 0
        self.reconnects = 0            # transport resets survived
        self.retried = 0               # requests that needed a re-attempt

    # -- transport ----------------------------------------------------------
    def _connect(self) -> socket.socket:
        if self._sock is None:
            s = socket.create_connection((self.host, self.port),
                                         timeout=self.timeout_s)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
        return self._sock

    def _reset(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
            self.reconnects += 1

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self) -> "CostModelClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request/response core ----------------------------------------------
    def _roundtrip_once(self, doc: dict) -> dict:
        """One framed exchange; raises OSError/FrameError on transport
        trouble (the retry loop owns recovery)."""
        sock = self._connect()
        send_frame(sock, doc)
        resp = recv_frame(sock)
        if resp is None:
            raise FrameError("server closed connection before responding")
        if resp.get("id") != doc["id"]:
            raise FrameError(f"response id {resp.get('id')!r} != request "
                             f"id {doc['id']!r}")
        return resp

    def _call(self, doc: dict) -> dict:
        """Send with retry/backoff; returns the ok response or raises the
        typed error. Non-retryable server errors raise immediately."""
        self._next_id += 1
        doc = dict(doc, id=self._next_id)
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                self.retried += 1
                time.sleep(min(self.backoff_s * (2 ** (attempt - 1)),
                               self.backoff_cap_s))
            try:
                resp = self._roundtrip_once(doc)
            except FrameError as e:
                self._reset()
                last = ProtocolError(str(e))
                continue
            except (OSError, socket.timeout) as e:
                self._reset()
                last = ClientError(f"transport failure: {e}")
                continue
            if resp.get("ok"):
                return resp
            err = resp.get("error", "unknown")
            exc = _ERROR_TYPES.get(err, ClientError)(
                f"{err}: {resp.get('detail', '')}")
            if err not in _RETRYABLE_ERRORS:
                raise exc
            last = exc
        raise last if last is not None else ClientError("retries exhausted")

    # -- public API ----------------------------------------------------------
    def predict_many(self, graphs: Sequence[KernelGraph], *,
                     deadline_ms: float | None = None) -> np.ndarray:
        """Score a batch of kernels on the server; returns float32 scores
        in input order (bit-identical to in-process scoring — float32
        survives the JSON double round trip exactly)."""
        doc = {"op": "predict",
               "graphs": [g.to_dict() for g in graphs]}
        if deadline_ms is not None:
            doc["deadline_ms"] = float(deadline_ms)
        resp = self._call(doc)
        scores = resp.get("scores")
        if not isinstance(scores, list) or len(scores) != len(graphs):
            raise ProtocolError(f"expected {len(graphs)} scores, got "
                                f"{scores!r}")
        return np.asarray(scores, np.float32)

    def predict(self, graph: KernelGraph, *,
                deadline_ms: float | None = None) -> float:
        return float(self.predict_many([graph], deadline_ms=deadline_ms)[0])

    def inject_fault(self, graphs: Sequence[KernelGraph], mode: str, *,
                     delay_s: float = 0.05,
                     deadline_ms: float | None = None) -> np.ndarray:
        """Predict with a per-request fault attached (the server honors it
        only when constructed with `allow_request_faults=True`). Same
        retry semantics as `predict_many` — the point of most fault tests
        is that this still returns, or raises a *typed* error, never
        hangs."""
        doc = {"op": "predict", "graphs": [g.to_dict() for g in graphs],
               "fault": {"mode": mode, "delay_s": delay_s}}
        if deadline_ms is not None:
            doc["deadline_ms"] = float(deadline_ms)
        resp = self._call(doc)
        return np.asarray(resp["scores"], np.float32)

    def ping(self) -> float:
        """Round-trip liveness probe; returns the server's wall time."""
        return float(self._call({"op": "ping"})["pong"])

    def stats(self) -> dict:
        """Server + service counters (`ServerStats.to_dict` + cache/flush
        stats)."""
        resp = self._call({"op": "stats"})
        return {"server": resp["server"], "service": resp["service"]}

    def snapshot(self, path: str | None = None) -> int:
        """Ask the server to persist its warm cache; returns entry count."""
        doc = {"op": "snapshot"}
        if path is not None:
            doc["path"] = path
        return int(self._call(doc)["entries"])

    def shutdown(self) -> None:
        """Request a graceful server shutdown (acknowledged, then the
        server stops in the background)."""
        self._call({"op": "shutdown"})
        self.close()
