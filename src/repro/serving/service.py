"""`CostModelService` — the one public scoring entry point (docs/SERVING.md).

Composition of the serving pipeline:

    predict_many(graphs)
      └─ cache lookup (canonical_hash)          repro.serving.cache
         └─ miss → coalescer ticket (deduped)   repro.serving.coalescer
            └─ flush → pack + bucket + encode   repro.data.batching
               │    (structural features from   repro.core.features
               │     the shared EncodeCache —   .encode_cache(); tile
               │     sweeps re-encode only      sweeps over one kernel
               │     TILE_SLICE; DESIGN.md §9)  hit one cached entry
               └─ one jitted apply per bucket   repro.core.model

A service instance is bound to one frozen (params, model config,
normalizer) triple — that is what makes content-addressed caching sound:
with the model fixed, a graph's prediction is a pure function of its
canonical hash. Train a new model → build a new service.

Both batched-graph representations are supported. The sparse backend packs
cache misses through the PR-1 bucketed batcher (one compiled executable
per pow2 `BucketSpec`); the dense backend pads fixed-size chunks. The
facade also exposes drop-in scorers for the call sites that used to go
straight to `core.evaluate` — `tile_scorer()`, `runtime_predictor()`,
`cost_fn()` — and a `stats()` surface (hit rate, bucket occupancy, flush
sizes, p50/p99 latency).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core import features as F
from repro.core.graph import KernelGraph
from repro.core.model import CostModelConfig
from repro.data.batching import BucketSpec, bucket_for, encode_packed, \
    pack_graphs
from repro.serving.cache import CacheStats, PredictionCache
from repro.serving.coalescer import RequestCoalescer, Ticket


def _percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile; 0.0 on empty input.

    >>> _percentile([], 50)
    0.0
    >>> _percentile([3.0, 1.0, 2.0], 50)
    2.0
    >>> _percentile([1.0, 2.0, 3.0, 4.0], 99)
    4.0
    """
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q,
                               method="higher"))


@dataclass(frozen=True)
class BucketStats:
    """Aggregate use of one compiled bucket shape across flushes."""
    flushes: int
    graphs: int
    mean_node_occupancy: float    # real nodes / node_capacity, averaged


@dataclass(frozen=True)
class ServiceStats:
    """Snapshot of everything the service has done so far."""
    requests: int                 # predict_many / submit calls
    graphs: int                   # total graph queries seen
    cache: CacheStats             # hits/misses/evictions/size/capacity
    coalesced: int                # duplicate in-flight queries absorbed
    flushes: int
    flush_sizes: tuple[int, ...]  # graphs per flush (last 4096 flushes)
    buckets: dict[BucketSpec | str, BucketStats] = field(default_factory=dict)
    latency_p50_ms: float = 0.0   # per predict_many call
    latency_p99_ms: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache.hit_rate

    def summary(self) -> str:
        """Human-readable multi-line report (used by the replay CLI)."""
        lines = [
            f"requests={self.requests} graphs={self.graphs} "
            f"hit_rate={self.hit_rate:.1%} "
            f"(hits={self.cache.hits} misses={self.cache.misses} "
            f"coalesced={self.coalesced})",
            f"cache size={self.cache.size}/{self.cache.capacity} "
            f"evictions={self.cache.evictions}",
            f"flushes={self.flushes} "
            f"mean_flush={np.mean(self.flush_sizes):.1f} "
            f"max_flush={max(self.flush_sizes)}"
            if self.flush_sizes else "flushes=0",
            f"latency p50={self.latency_p50_ms:.2f}ms "
            f"p99={self.latency_p99_ms:.2f}ms",
        ]
        for spec, b in sorted(self.buckets.items(), key=lambda kv: str(kv[0])):
            lines.append(f"  bucket {spec}: flushes={b.flushes} "
                         f"graphs={b.graphs} "
                         f"occupancy={b.mean_node_occupancy:.1%}")
        return "\n".join(lines)


class PendingRequest:
    """Deferred result of `submit`: per-slot either a cached float or a
    coalescer `Ticket`. `result()` flushes whatever is still pending."""

    def __init__(self, service: "CostModelService",
                 entries: list[float | Ticket]):
        self._service = service
        self._entries = entries

    def result(self) -> np.ndarray:
        if any(isinstance(e, Ticket) and not e.ready for e in self._entries):
            self._service.flush()
        return np.array([e.value if isinstance(e, Ticket) else e
                         for e in self._entries], np.float32)


class CostModelService:
    """Cached, coalescing batch scorer over one trained cost model.

    Parameters mirror `core.evaluate.predict_kernels`: `adjacency` and
    `max_nodes` default to the model config's values, `node_budget`
    (sparse packing budget, also the coalescer auto-flush threshold)
    defaults to `8 * max_nodes`, `chunk` is the dense batch width. Pass
    `predict_fn` to share one jitted apply across services.

    `params` may also be a `repro.quant.QuantizedCostModel` (DESIGN.md
    §14): the service then serves its int8 tree under the model's
    embedded serving config (``precision="int8"`` — weights decode
    inside jit, or in-VMEM on the sparse Pallas path), and stamps
    `precision` into cache-snapshot meta so an int8 warm cache can't
    silently warm an f32 service (or vice versa).
    """

    def __init__(self, params, model_cfg: CostModelConfig, normalizer, *,
                 adjacency: str | None = None, cache_capacity: int = 65536,
                 node_budget: int | None = None, chunk: int = 128,
                 max_nodes: int | None = None, predict_fn=None,
                 include_static_perf: bool = True):
        from repro.core.evaluate import make_predict_fn
        from repro.quant.quantize import QuantizedCostModel
        if isinstance(params, QuantizedCostModel):
            model_cfg = params.serving_config(model_cfg)
            params = params.params
        self.params = params
        self.model_cfg = model_cfg
        self.precision = model_cfg.precision
        self.normalizer = normalizer
        self.adjacency = adjacency or model_cfg.adjacency
        if self.adjacency not in ("dense", "sparse", "segmented"):
            raise ValueError(f"unknown adjacency {self.adjacency!r}")
        self.max_nodes = max_nodes or model_cfg.max_nodes
        self.node_budget = node_budget or 8 * self.max_nodes
        self.chunk = int(chunk)
        self.include_static_perf = include_static_perf
        self._predict = predict_fn or make_predict_fn(model_cfg)
        # the LSTM reduction consumes node *order*, so isomorphic-but-
        # reordered graphs may score differently — key the cache on order
        self._order_sensitive = model_cfg.reduction == "lstm"
        self.cache = PredictionCache(cache_capacity)
        score = {"sparse": self._score_sparse,
                 "segmented": self._score_segmented,
                 "dense": self._score_dense}[self.adjacency]
        self.coalescer = RequestCoalescer(score,
                                          node_budget=self.node_budget,
                                          on_scored=self.cache.put)
        self._bucket_use: dict[BucketSpec | str, list[float]] = {}
        # cache and coalescer are internally locked; this lock only guards
        # the service-level counters, so submit() is safe from any thread
        # (the socket server's connection threads + scoring worker)
        self._stats_lock = threading.Lock()
        self._requests = 0
        self._graphs = 0
        self._latencies_ms: deque[float] = deque(maxlen=4096)

    # --- scoring backends (one flush = one call) ---------------------------
    # Both backends encode through the process-wide `features.EncodeCache`:
    # a prediction-cache miss for a new tile of an already-seen kernel
    # costs a tile-slice rewrite, not a full structural re-encode.
    def _score_sparse(self, graphs: Sequence[KernelGraph]) -> np.ndarray:
        out = np.zeros((len(graphs),), np.float32)
        # inference scores whatever it is handed: kernels beyond the budget
        # keep their historical oversized singleton packs here (the
        # 'segmented' backend routes them through graph segmentation)
        for pack in pack_graphs(graphs, self.node_budget,
                                oversized="singleton"):
            part = [graphs[i] for i in pack]
            spec = bucket_for(part)
            enc = encode_packed(
                part, self.normalizer,
                include_static_perf=self.include_static_perf, spec=spec)
            preds = np.asarray(self._predict(self.params, enc))
            out[pack] = preds[:len(pack)]
            use = self._bucket_use.setdefault(spec, [0, 0, 0.0])
            use[0] += 1
            use[1] += len(pack)
            use[2] += sum(g.num_nodes for g in part) / spec.node_capacity
        return out

    def _score_segmented(self, graphs: Sequence[KernelGraph]) -> np.ndarray:
        """Whole-program miss path (DESIGN.md §12): graphs within the node
        budget ride the ordinary sparse bucket ladder; bigger ones are
        segmented into ≤ node_budget blocks and reassembled before readout,
        one giant graph per device batch."""
        from repro.data.batching import encode_segmented
        out = np.zeros((len(graphs),), np.float32)
        small = [i for i, g in enumerate(graphs)
                 if g.num_nodes <= self.node_budget]
        if small:
            out[np.asarray(small)] = self._score_sparse(
                [graphs[i] for i in small])
        for i in range(len(graphs)):
            g = graphs[i]
            if g.num_nodes <= self.node_budget:
                continue
            enc = encode_segmented(
                [g], self.node_budget, self.normalizer,
                include_static_perf=self.include_static_perf)
            out[i] = float(np.asarray(self._predict(self.params, enc))[0])
            use = self._bucket_use.setdefault("segmented", [0, 0, 0.0])
            use[0] += 1
            use[1] += 1
            use[2] += g.num_nodes / enc.num_nodes
        return out

    def _score_dense(self, graphs: Sequence[KernelGraph]) -> np.ndarray:
        out = []
        key = f"dense[{self.chunk}x{self.max_nodes}]"
        for i in range(0, len(graphs), self.chunk):
            part = list(graphs[i:i + self.chunk])
            pad = self.chunk - len(part)
            enc = F.encode_batch(
                part + [part[-1]] * pad, self.max_nodes, self.normalizer,
                include_static_perf=self.include_static_perf)
            preds = np.asarray(self._predict(self.params, enc))
            out.append(preds[:len(part)])
            use = self._bucket_use.setdefault(key, [0, 0, 0.0])
            use[0] += 1
            use[1] += len(part)
            use[2] += len(part) / self.chunk
        return np.concatenate(out)

    # --- public API --------------------------------------------------------
    def cache_key(self, graph: KernelGraph) -> str:
        """The content-addressed key this service caches `graph` under
        (order-sensitive iff the model's reduction depends on node
        order)."""
        return graph.canonical_hash(order_sensitive=self._order_sensitive)

    def submit(self, graphs: Sequence[KernelGraph]) -> PendingRequest:
        """Queue a batch of queries without forcing a flush: cached graphs
        resolve immediately, misses coalesce with other in-flight requests
        (identical graphs share one ticket). Call `.result()` — or let the
        node-budget auto-flush fire — to resolve."""
        with self._stats_lock:
            self._requests += 1
            self._graphs += len(graphs)
        entries: list[float | Ticket] = []
        for g in graphs:
            key = self.cache_key(g)
            val = self.cache.get(key)
            entries.append(self.coalescer.add(key, g)
                           if val is None else val)
        return PendingRequest(self, entries)

    def predict_many(self, graphs: Sequence[KernelGraph]) -> np.ndarray:
        """Synchronous scoring of a list of kernels; the primary entry
        point. Returns one float32 score per graph, in input order."""
        t0 = time.perf_counter()
        out = self.submit(graphs).result()
        self._latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return out

    def predict(self, graph: KernelGraph) -> float:
        return float(self.predict_many([graph])[0])

    def flush(self) -> None:
        """Force-score everything pending in the coalescer."""
        self.coalescer.flush()

    # --- warm-cache persistence (docs/SERVING.md §warm cache) --------------
    # A snapshot is only sound for a service bound to the same frozen
    # (params, model config, normalizer) triple that produced it — the
    # cache key does not encode the model. The server stamps its snapshot
    # path per model; these helpers just delegate to the cache.
    def snapshot_cache(self, path: str) -> int:
        """Persist the prediction cache to `path` (atomic npz; see
        `PredictionCache.snapshot`), stamped with this service's
        precision. Returns the entry count."""
        return self.cache.snapshot(path, meta={"precision": self.precision})

    def restore_cache(self, path: str) -> int:
        """Warm-start the prediction cache from a `snapshot_cache` file.
        Refuses (SnapshotFormatError) a snapshot stamped with a different
        precision. Returns the number of entries loaded."""
        return self.cache.restore(path,
                                  expect_meta={"precision": self.precision})

    def stats(self) -> ServiceStats:
        buckets = {
            spec: BucketStats(flushes=int(u[0]), graphs=int(u[1]),
                              mean_node_occupancy=u[2] / u[0])
            for spec, u in dict(self._bucket_use).items()}
        lat = list(self._latencies_ms)
        with self._stats_lock:
            requests, graphs = self._requests, self._graphs
        return ServiceStats(
            requests=requests, graphs=graphs,
            cache=self.cache.stats(), coalesced=self.coalescer.coalesced,
            flushes=self.coalescer.flushes,
            flush_sizes=tuple(self.coalescer.flush_sizes), buckets=buckets,
            latency_p50_ms=_percentile(lat, 50),
            latency_p99_ms=_percentile(lat, 99))

    # --- drop-in scorers for the existing call sites -----------------------
    def tile_scorer(self) -> Callable:
        """`scorer(kernel, tiles) -> scores` for the tile autotuner /
        `eval_tile_task` (lower = faster)."""
        def scorer(kernel: KernelGraph, tiles) -> np.ndarray:
            kernel.structural_digest()     # memoize once; tile variants share
            return self.predict_many([kernel.with_tile(t) for t in tiles])
        return scorer

    def runtime_predictor(self) -> Callable:
        """`predict_runtimes(kernels) -> seconds` for the fusion task
        (the model predicts log-runtime; exponentiate)."""
        def predict_runtimes(kernels) -> np.ndarray:
            return np.exp(self.predict_many(list(kernels)))
        return predict_runtimes

    def cost_fn(self, *, drop_above: int | None = None) -> Callable:
        """Program-cost objective for the fusion annealer:
        Σ exp(predicted log-runtime). `drop_above` reproduces the dense
        path's max-nodes truncation guard (see `model_cost_fn`)."""
        def cost(kernels) -> float:
            ks = list(kernels)
            if drop_above is not None:
                ks = [k for k in ks if k.num_nodes <= drop_above]
            if not ks:
                return 0.0
            return float(np.sum(np.exp(self.predict_many(ks))))
        return cost
