"""Persistent multi-tenant cost-model server (docs/SERVING.md §server).

`CostModelService` (PR 2) is in-process only — one Python process, one
client. This module wraps it in a long-lived socket server so many
concurrent search clients (the paper's "access to TPUs is limited or
expensive" deployment: autotuners hammering one shared model) share one
cache, one coalescer, and one set of warm jit executables:

* **Protocol** — length-prefixed JSON frames (4-byte big-endian length +
  UTF-8 JSON body) over TCP. Graphs travel as `KernelGraph.to_dict()`
  payloads; scores come back as JSON doubles (float32 values are exact in
  a double, so the wire round trip is bit-identical).
* **Admission control** — a bounded work queue plus a per-request
  deadline. A full queue answers `overloaded` *immediately* (shed, never
  hang); a request whose deadline passed while queued answers
  `deadline_exceeded` without touching the model. Both are explicit,
  counted responses — the load benchmark gates that nothing is ever
  silently dropped.
* **Cross-client coalescing** — one scoring worker drains the queue in
  batches and funnels every request through `CostModelService.submit`,
  so identical graphs from *different* sockets share one coalescer
  ticket and one model evaluation per flush.
* **Warm cache** — with `snapshot_path=`, `start()` restores a persisted
  `PredictionCache` snapshot (content-addressed npz, `serving.cache`)
  and `stop()` writes one, so a restarted server answers replayed
  traffic from disk.
* **Fault injection** — a structured `FaultPolicy` (drop connection,
  delay, corrupt frame, kill the scoring worker mid-flush) threaded
  through the response path for the concurrency/fault test suite
  (`tests/test_server.py`). Off by default.

This module stays numpy+stdlib at import time (the service object is
passed in, jax arrives with it) so clients and test harnesses can import
the protocol pieces without paying the jax import.

>>> buf = pack_frame({"op": "ping"})
>>> import struct
>>> struct.unpack(">I", buf[:4])[0] == len(buf) - 4
True
>>> unpack_frame(buf[4:])
{'op': 'ping'}
>>> FaultPolicy("delay", every=3).matches(6)
True
>>> FaultPolicy("drop", requests=(2,)).matches(3)
False
"""
from __future__ import annotations

import json
import os
import queue
import socket
import struct
import threading
import time
from dataclasses import dataclass

from repro.core.graph import KernelGraph

# --------------------------------------------------------------------------
# Framing
# --------------------------------------------------------------------------
MAX_FRAME_BYTES = 64 << 20          # hard cap against hostile/corrupt lengths
_LEN = struct.Struct(">I")


class FrameError(Exception):
    """Malformed wire data: oversize length, truncated frame, bad JSON."""


def pack_frame(doc: dict) -> bytes:
    """Serialize one protocol message: 4-byte big-endian length + JSON."""
    body = json.dumps(doc, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME_BYTES:
        raise FrameError(f"frame of {len(body)} bytes exceeds "
                         f"{MAX_FRAME_BYTES}")
    return _LEN.pack(len(body)) + body


def unpack_frame(body: bytes) -> dict:
    """Decode a frame body; raises `FrameError` on bad JSON / non-object."""
    try:
        doc = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as e:
        raise FrameError(f"undecodable frame body: {e}") from e
    if not isinstance(doc, dict):
        raise FrameError(f"frame body is {type(doc).__name__}, expected "
                         "object")
    return doc


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly `n` bytes; None on clean EOF at a frame boundary."""
    chunks, got = [], 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise FrameError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> dict | None:
    """Read one frame off `sock`; None on clean EOF before a frame starts."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (length,) = _LEN.unpack(head)
    if length > MAX_FRAME_BYTES:
        raise FrameError(f"declared frame length {length} exceeds "
                         f"{MAX_FRAME_BYTES}")
    body = _recv_exact(sock, length)
    if body is None:
        raise FrameError("connection closed between length and body")
    return unpack_frame(body)


def send_frame(sock: socket.socket, doc: dict) -> None:
    sock.sendall(pack_frame(doc))


# --------------------------------------------------------------------------
# Fault injection
# --------------------------------------------------------------------------
FAULT_MODES = ("drop", "delay", "corrupt", "kill_flush")


@dataclass(frozen=True)
class FaultPolicy:
    """Deterministic per-request fault selector for the test suite.

    Matches on the server's global predict-request sequence number
    (1-based): `requests` is an explicit set of sequence numbers, `every`
    fires on every k-th request; either alone or both together.

    Modes (applied by the server, see `CostModelServer`):

    * ``drop``       — close the connection instead of responding;
    * ``delay``      — sleep `delay_s` before sending the response;
    * ``corrupt``    — send a correctly-framed garbage body;
    * ``kill_flush`` — raise inside the scoring worker mid-flush (after
      requests were submitted to the coalescer, before their batch
      resolves), killing that worker pass; the server answers the whole
      batch with a clean `worker_failure` error and keeps serving.
    """
    mode: str
    requests: tuple[int, ...] = ()
    every: int | None = None
    delay_s: float = 0.05

    def __post_init__(self):
        if self.mode not in FAULT_MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"expected one of {FAULT_MODES}")
        object.__setattr__(self, "requests", tuple(self.requests))

    def matches(self, seq: int) -> bool:
        if seq in self.requests:
            return True
        return bool(self.every) and seq % self.every == 0


class _InjectedFault(Exception):
    """Raised by the scoring worker for `kill_flush` faults."""


# --------------------------------------------------------------------------
# Server
# --------------------------------------------------------------------------
@dataclass
class ServerStats:
    """Server-level counters (the service keeps its own cache/flush stats)."""
    connections: int = 0
    requests: int = 0                 # predict requests admitted or shed
    completed: int = 0                # predict requests answered with scores
    shed_overloaded: int = 0          # rejected at admission (queue full)
    shed_deadline: int = 0            # expired while queued
    worker_failures: int = 0          # scoring passes killed (faults/bugs)
    faults_injected: int = 0
    restored_entries: int = 0         # warm-cache entries loaded at start

    def to_dict(self) -> dict:
        return {k: int(getattr(self, k)) for k in (
            "connections", "requests", "completed", "shed_overloaded",
            "shed_deadline", "worker_failures", "faults_injected",
            "restored_entries")}


@dataclass
class _Work:
    """One admitted predict request, queued for the scoring worker."""
    sock: socket.socket
    send_lock: threading.Lock
    req_id: object
    graphs: list
    deadline: float | None            # absolute time.monotonic() cutoff
    fault: FaultPolicy | None
    seq: int


_STOP = object()                      # queue sentinel


class CostModelServer:
    """Length-prefixed-JSON socket server around one `CostModelService`.

    One accept thread, one connection thread per client (they parse and
    decode off the scoring path), one scoring worker that drains the
    bounded queue in batches and pushes everything through
    `service.submit` + one `service.flush` — the cross-client coalescing
    path. Admission (queue full → `overloaded`) and deadline expiry
    (`deadline_exceeded`) are answered from the connection/worker threads
    without scoring, so an overloaded server sheds explicitly instead of
    stalling every client.

    Parameters:
      service             a `CostModelService` (or any object with
                          `submit/flush/stats/snapshot_cache/restore_cache`)
      host, port          bind address; port 0 picks a free port
      max_queue           admission bound (queued predict requests)
      coalesce_limit      max requests one worker pass drains into a batch
      default_deadline_ms deadline applied when a request carries none
                          (None: no default deadline)
      snapshot_path       warm-cache npz: restored on `start()` (if the
                          file exists), written on `stop()` and on the
                          `snapshot` op
      fault_policy        server-side `FaultPolicy` (tests only)
      allow_request_faults honor a per-request ``"fault"`` dict from the
                          client (tests only)
    """

    def __init__(self, service, *, host: str = "127.0.0.1", port: int = 0,
                 max_queue: int = 64, coalesce_limit: int = 32,
                 default_deadline_ms: float | None = None,
                 snapshot_path: str | None = None,
                 fault_policy: FaultPolicy | None = None,
                 allow_request_faults: bool = False):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if coalesce_limit < 1:
            raise ValueError("coalesce_limit must be >= 1")
        self.service = service
        self.host, self.port = host, int(port)
        self.max_queue = int(max_queue)
        self.coalesce_limit = int(coalesce_limit)
        self.default_deadline_ms = default_deadline_ms
        self.snapshot_path = snapshot_path
        self.fault_policy = fault_policy
        self.allow_request_faults = bool(allow_request_faults)
        self.stats = ServerStats()
        self._queue: queue.Queue = queue.Queue(maxsize=self.max_queue)
        self._listener: socket.socket | None = None
        self._threads: list[threading.Thread] = []
        self._conn_threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._lock = threading.Lock()           # conns + counters
        self._seq = 0
        self._running = False

    # -- lifecycle ----------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        """(host, port) actually bound — read after `start()`."""
        if self._listener is None:
            raise RuntimeError("server not started")
        return self._listener.getsockname()[:2]

    @property
    def running(self) -> bool:
        return self._running

    def start(self) -> "CostModelServer":
        if self._running:
            raise RuntimeError("server already started")
        if self.snapshot_path and os.path.exists(self.snapshot_path):
            self.stats.restored_entries = self.service.restore_cache(
                self.snapshot_path)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((self.host, self.port))
        self._listener.listen(128)
        self._running = True
        for name, target in (("accept", self._accept_loop),
                             ("worker", self._worker_loop)):
            t = threading.Thread(target=target,
                                 name=f"costmodel-server-{name}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, *, timeout: float = 10.0) -> None:
        """Graceful shutdown: stop accepting, let the worker finish its
        current batch, answer everything still queued with
        `shutting_down`, close every connection, join every thread, and
        persist the warm cache. Idempotent."""
        if not self._running:
            return
        self._running = False
        if self._listener is not None:
            # shutdown() wakes a thread blocked in accept(); close() alone
            # can leave it parked on the fd forever
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        self._queue.put(_STOP)         # blocking: guaranteed delivery
        for t in self._threads:
            t.join(timeout=timeout)
        # fail whatever the worker never reached — no silent drops
        while True:
            try:
                w = self._queue.get_nowait()
            except queue.Empty:
                break
            if w is not _STOP:
                self._respond_error(w, "shutting_down",
                                    "server stopped before scoring")
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            self._close_conn(c)
        for t in list(self._conn_threads):
            t.join(timeout=timeout)
        self._threads.clear()
        self._conn_threads.clear()
        if self.snapshot_path:
            self.service.snapshot_cache(self.snapshot_path)

    def __enter__(self) -> "CostModelServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- accept / connection threads ---------------------------------------
    def _accept_loop(self) -> None:
        while self._running:
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                break                  # listener closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                if not self._running:
                    conn.close()
                    break
                self._conns.add(conn)
                self.stats.connections += 1
            t = threading.Thread(target=self._conn_loop, args=(conn,),
                                 name="costmodel-server-conn", daemon=True)
            t.start()
            # prune finished handlers so long-lived servers don't hoard them
            self._conn_threads = [c for c in self._conn_threads
                                  if c.is_alive()]
            self._conn_threads.append(t)

    def _close_conn(self, conn: socket.socket) -> None:
        with self._lock:
            self._conns.discard(conn)
        try:
            conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            conn.close()
        except OSError:
            pass

    def _conn_loop(self, conn: socket.socket) -> None:
        send_lock = threading.Lock()
        try:
            while self._running:
                try:
                    req = recv_frame(conn)
                except (FrameError, OSError):
                    break              # protocol violation / reset: drop
                if req is None:
                    break              # client closed cleanly
                self._dispatch(conn, send_lock, req)
        finally:
            self._close_conn(conn)

    def _dispatch(self, conn, send_lock, req: dict) -> None:
        op = req.get("op")
        req_id = req.get("id")
        if op == "predict":
            self._admit(conn, send_lock, req)
        elif op == "ping":
            self._send(conn, send_lock,
                       {"id": req_id, "ok": True, "pong": time.time()})
        elif op == "stats":
            self._send(conn, send_lock,
                       {"id": req_id, "ok": True, "server": self.stats.to_dict(),
                        "service": _service_stats_doc(self.service)})
        elif op == "snapshot":
            path = req.get("path") or self.snapshot_path
            if not path:
                self._send(conn, send_lock,
                           {"id": req_id, "ok": False, "error": "bad_request",
                            "detail": "no snapshot path configured"})
                return
            n = self.service.snapshot_cache(path)
            self._send(conn, send_lock,
                       {"id": req_id, "ok": True, "entries": n, "path": path})
        elif op == "shutdown":
            self._send(conn, send_lock, {"id": req_id, "ok": True})
            threading.Thread(target=self.stop, daemon=True).start()
        else:
            self._send(conn, send_lock,
                       {"id": req_id, "ok": False, "error": "bad_request",
                        "detail": f"unknown op {op!r}"})

    def _admit(self, conn, send_lock, req: dict) -> None:
        req_id = req.get("id")
        with self._lock:
            self._seq += 1
            seq = self._seq
            self.stats.requests += 1
        fault = self._fault_for(seq, req)
        try:
            graphs = [KernelGraph.from_dict(g) for g in req["graphs"]]
        except (KeyError, TypeError, ValueError) as e:
            self._send(conn, send_lock,
                       {"id": req_id, "ok": False, "error": "bad_request",
                        "detail": f"undecodable graphs: {e}"})
            return
        deadline_ms = req.get("deadline_ms", self.default_deadline_ms)
        deadline = (time.monotonic() + float(deadline_ms) / 1e3
                    if deadline_ms is not None else None)
        work = _Work(conn, send_lock, req_id, graphs, deadline, fault, seq)
        try:
            self._queue.put_nowait(work)
        except queue.Full:
            with self._lock:
                self.stats.shed_overloaded += 1
            self._respond_error(work, "overloaded",
                                f"admission queue full ({self.max_queue})")

    def _fault_for(self, seq: int, req: dict) -> FaultPolicy | None:
        if self.allow_request_faults and req.get("fault"):
            f = dict(req["fault"])
            return FaultPolicy(f["mode"], delay_s=float(f.get("delay_s",
                                                              0.05)))
        if self.fault_policy is not None and self.fault_policy.matches(seq):
            return self.fault_policy
        return None

    # -- scoring worker -----------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            work = self._queue.get()
            if work is _STOP:
                return
            batch = [work]
            # drain whatever is already queued: cross-client batching
            while len(batch) < self.coalesce_limit:
                try:
                    nxt = self._queue.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    self._queue.put(nxt)      # re-deliver for ourselves
                    break
                batch.append(nxt)
            now = time.monotonic()
            ready = []
            for w in batch:
                if w.deadline is not None and now > w.deadline:
                    with self._lock:
                        self.stats.shed_deadline += 1
                    self._respond_error(w, "deadline_exceeded",
                                        "expired while queued")
                else:
                    ready.append(w)
            if not ready:
                continue
            try:
                pendings = [self.service.submit(w.graphs) for w in ready]
                for w in ready:
                    if w.fault is not None and w.fault.mode == "kill_flush":
                        with self._lock:
                            self.stats.faults_injected += 1
                        raise _InjectedFault(f"kill_flush at seq {w.seq}")
                self.service.flush()
                results = [p.result() for p in pendings]
            except Exception as e:             # noqa: BLE001 — keep serving
                with self._lock:
                    self.stats.worker_failures += 1
                for w in ready:
                    self._respond_error(w, "worker_failure",
                                        f"{type(e).__name__}: {e}")
                continue
            for w, scores in zip(ready, results):
                self._respond_scores(w, scores)

    # -- responses ----------------------------------------------------------
    def _respond_scores(self, w: _Work, scores) -> None:
        with self._lock:
            self.stats.completed += 1
        self._respond(w, {"id": w.req_id, "ok": True,
                          "scores": [float(s) for s in scores]})

    def _respond_error(self, w: _Work, error: str, detail: str) -> None:
        self._respond(w, {"id": w.req_id, "ok": False, "error": error,
                          "detail": detail})

    def _respond(self, w: _Work, doc: dict) -> None:
        fault = w.fault
        if fault is not None and fault.mode in ("drop", "delay", "corrupt"):
            with self._lock:
                self.stats.faults_injected += 1
            if fault.mode == "drop":
                self._close_conn(w.sock)
                return
            if fault.mode == "delay":
                time.sleep(fault.delay_s)
            elif fault.mode == "corrupt":
                body = b"\xff" * 24            # framed, but not JSON
                try:
                    with w.send_lock:
                        w.sock.sendall(_LEN.pack(len(body)) + body)
                except OSError:
                    pass
                return
        self._send(w.sock, w.send_lock, doc)

    def _send(self, conn, send_lock, doc: dict) -> None:
        try:
            with send_lock:
                send_frame(conn, doc)
        except OSError:
            self._close_conn(conn)     # client went away; nothing to do


def _service_stats_doc(service) -> dict:
    """JSON-able subset of `ServiceStats` for the `stats` op."""
    s = service.stats()
    return {"requests": s.requests, "graphs": s.graphs,
            "hits": s.cache.hits, "misses": s.cache.misses,
            "hit_rate": s.hit_rate, "cache_size": s.cache.size,
            "evictions": s.cache.evictions, "coalesced": s.coalesced,
            "flushes": s.flushes,
            "latency_p50_ms": s.latency_p50_ms,
            "latency_p99_ms": s.latency_p99_ms,
            "buckets": {str(k): {"flushes": b.flushes, "graphs": b.graphs,
                                 "occupancy": b.mean_node_occupancy}
                        for k, b in s.buckets.items()}}
