"""Content-addressed prediction cache (docs/SERVING.md, stage 1).

Keys are `KernelGraph.canonical_hash()` strings, values are scalar model
predictions. The cache is a plain LRU over an `OrderedDict`: a `get` hit
refreshes recency, a `put` past capacity evicts the least-recently-used
entry. Everything is counted so `CostModelService.stats()` can report hit
rates and eviction pressure.

>>> c = PredictionCache(capacity=2)
>>> c.put("a", 1.0); c.put("b", 2.0)
>>> c.get("a")
1.0
>>> c.put("c", 3.0)            # evicts "b" ("a" was refreshed by the hit)
>>> c.get("b") is None
True
>>> s = c.stats()
>>> (s.hits, s.misses, s.evictions, s.size)
(1, 1, 1, 2)
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


@dataclass(frozen=True)
class CacheStats:
    """Counters since construction (`hits`/`misses` only count `get`)."""
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PredictionCache:
    """Bounded LRU map: canonical graph hash -> predicted score."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._data: OrderedDict[str, float] = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        """Peek without touching recency or hit/miss counters."""
        return key in self._data

    def get(self, key: str) -> float | None:
        """Counted lookup; a hit refreshes the entry's recency."""
        val = self._data.get(key)
        if val is None:
            self._misses += 1
            return None
        self._data.move_to_end(key)
        self._hits += 1
        return val

    def put(self, key: str, value: float) -> None:
        if key in self._data:
            self._data.move_to_end(key)
            self._data[key] = float(value)
            return
        self._data[key] = float(value)
        if len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self._evictions += 1

    def clear(self) -> None:
        self._data.clear()

    def stats(self) -> CacheStats:
        return CacheStats(self._hits, self._misses, self._evictions,
                          len(self._data), self.capacity)
