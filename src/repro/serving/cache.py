"""Content-addressed prediction cache (docs/SERVING.md, stage 1).

Keys are `KernelGraph.canonical_hash()` strings, values are scalar model
predictions. The cache is a plain LRU over an `OrderedDict`: a `get` hit
refreshes recency, a `put` past capacity evicts the least-recently-used
entry. Everything is counted so `CostModelService.stats()` can report hit
rates and eviction pressure. All operations are thread-safe — the server
(`repro.serving.server`) fills the cache from its scoring worker while
connection threads probe it.

A cache can be persisted and restored: `snapshot(path)` writes the
entries to a single checksummed npz in the corpus-store style
(`repro.data.store` — canonical-JSON payload block + one binary float64
values block, atomic tmp-then-rename), and `restore(path)` loads them
back preserving LRU order, so a restarted server answers replayed
traffic from disk (docs/SERVING.md §warm cache).

>>> c = PredictionCache(capacity=2)
>>> c.put("a", 1.0); c.put("b", 2.0)
>>> c.get("a")
1.0
>>> c.put("c", 3.0)            # evicts "b" ("a" was refreshed by the hit)
>>> c.get("b") is None
True
>>> s = c.stats()
>>> (s.hits, s.misses, s.evictions, s.size)
(1, 1, 1, 2)

>>> import tempfile, os
>>> path = os.path.join(tempfile.mkdtemp(), "cache.npz")
>>> c.snapshot(path)
2
>>> warm = PredictionCache(capacity=8)
>>> warm.restore(path)
2
>>> warm.get("c"), warm.get("a")       # exact values, LRU order kept
(3.0, 1.0)
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

# v1: header {format_version, kind, keys, values_sha256}
# v2: + optional "meta" dict (model binding, e.g. {"precision": "int8"}) —
#     restore() accepts both; a v1 file is a v2 file with empty meta
SNAPSHOT_FORMAT_VERSION = 2
_ACCEPTED_VERSIONS = (1, 2)


@dataclass(frozen=True)
class CacheStats:
    """Counters since construction (`hits`/`misses` only count `get`)."""
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PredictionCache:
    """Bounded LRU map: canonical graph hash -> predicted score."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._data: OrderedDict[str, float] = OrderedDict()
        self._lock = threading.RLock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        """Peek without touching recency or hit/miss counters."""
        return key in self._data

    def get(self, key: str) -> float | None:
        """Counted lookup; a hit refreshes the entry's recency."""
        with self._lock:
            val = self._data.get(key)
            if val is None:
                self._misses += 1
                return None
            self._data.move_to_end(key)
            self._hits += 1
            return val

    def put(self, key: str, value: float) -> None:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = float(value)
                return
            self._data[key] = float(value)
            if len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self._evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(self._hits, self._misses, self._evictions,
                              len(self._data), self.capacity)

    # --- persistence (warm restarts; docs/SERVING.md §warm cache) ----------
    def snapshot(self, path: str, *, meta: dict | None = None) -> int:
        """Persist all entries to one npz at `path` (atomic: tmp sibling +
        rename, like `repro.data.store`). Returns the entry count.

        Layout mirrors a corpus shard: ``entries`` is a canonical-JSON
        header (format version, keys in LRU order — oldest first — and a
        sha256 over the raw value bytes), ``values`` is one float64 block,
        JSON never touches the floats. `meta` (string-valued, e.g. the
        serving precision) is stamped into the header so `restore` can
        refuse snapshots from a differently-configured model.
        """
        with self._lock:
            keys = list(self._data)
            values = np.asarray([self._data[k] for k in keys], np.float64)
        header = {"format_version": SNAPSHOT_FORMAT_VERSION,
                  "kind": "prediction_cache", "keys": keys,
                  "meta": dict(meta or {}),
                  "values_sha256": hashlib.sha256(
                      values.tobytes()).hexdigest()}
        blob = json.dumps(header, sort_keys=True,
                          separators=(",", ":")).encode("utf-8")
        tmp = path + f".tmp-{os.getpid()}"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        try:
            with open(tmp, "wb") as f:
                np.savez(f, entries=np.frombuffer(blob, np.uint8),
                         values=values)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return len(keys)

    def restore(self, path: str, *, expect_meta: dict | None = None) -> int:
        """Load a `snapshot` file into this cache (entries inserted in
        stored LRU order, so recency survives the round trip; capacity
        still applies — oldest entries evict first if the snapshot is
        larger). Returns the number of entries loaded. Raises
        `SnapshotFormatError` on a corrupt/mismatched file, or when a
        key in `expect_meta` contradicts the snapshot's stamped meta
        (keys absent from the snapshot — every v1 file — are accepted:
        pre-meta snapshots predate the precision tag and are f32)."""
        try:
            with np.load(path) as z:
                header = json.loads(bytes(z["entries"]).decode("utf-8"))
                values = np.asarray(z["values"], np.float64)
        except (OSError, ValueError, KeyError) as e:
            raise SnapshotFormatError(f"{path}: unreadable snapshot "
                                      f"({e})") from e
        if header.get("format_version") not in _ACCEPTED_VERSIONS:
            raise SnapshotFormatError(
                f"{path}: format_version {header.get('format_version')!r} "
                f"not in {_ACCEPTED_VERSIONS}")
        meta = header.get("meta", {})
        for k, want in (expect_meta or {}).items():
            if k in meta and meta[k] != want:
                raise SnapshotFormatError(
                    f"{path}: snapshot meta {k}={meta[k]!r} does not match "
                    f"this service ({k}={want!r}) — a warm cache is only "
                    "sound for the model configuration that wrote it")
        digest = hashlib.sha256(values.tobytes()).hexdigest()
        if digest != header["values_sha256"]:
            raise SnapshotFormatError(f"{path}: values checksum mismatch")
        keys = header["keys"]
        if len(keys) != values.shape[0]:
            raise SnapshotFormatError(
                f"{path}: {len(keys)} keys but {values.shape[0]} values")
        with self._lock:
            for k, v in zip(keys, values):
                self.put(k, float(v))
        return len(keys)


class SnapshotFormatError(Exception):
    """Raised for malformed or checksum-mismatched cache snapshots."""
