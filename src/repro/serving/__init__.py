"""Cost-model prediction serving (docs/SERVING.md, docs/API.md).

The serving layer between clients (autotuners, fusion/tile evaluators,
remote search processes, future compiler hooks) and the GNN:

* `PredictionCache` — content-addressed, thread-safe LRU keyed by
  `KernelGraph.canonical_hash()`, with npz snapshot/restore for warm
  restarts;
* `RequestCoalescer` — accumulates cache-miss graphs and flushes them
  through the bucketed sparse batcher in one call (thread-safe);
* `CostModelService` — the in-process facade: `predict_many`, deferred
  `submit`, drop-in `tile_scorer`/`runtime_predictor`/`cost_fn`
  adapters, and a `stats()` surface;
* `CostModelServer` / `CostModelClient` — the persistent multi-tenant
  socket layer on top: length-prefixed-JSON protocol, bounded-queue
  admission with explicit `overloaded`/`deadline_exceeded` shedding,
  cross-client coalescing, warm-cache persistence, and structured fault
  injection (`FaultPolicy`) for the test suite.

Exports resolve lazily (PEP 562): importing `repro.serving` — or the
protocol/client side directly — does NOT pull in jax. `CostModelService`
(which imports the encoding/batching stack) triggers the real import on
first touch, so load-test client *processes* stay jax-free.
"""
import importlib

_EXPORTS = {
    # cache + coalescer (numpy-only)
    "CacheStats": "repro.serving.cache",
    "PredictionCache": "repro.serving.cache",
    "SnapshotFormatError": "repro.serving.cache",
    "RequestCoalescer": "repro.serving.coalescer",
    "Ticket": "repro.serving.coalescer",
    # socket server/client/protocol (numpy+stdlib only)
    "CostModelServer": "repro.serving.server",
    "FaultPolicy": "repro.serving.server",
    "FrameError": "repro.serving.server",
    "ServerStats": "repro.serving.server",
    "CostModelClient": "repro.serving.client",
    "ClientError": "repro.serving.client",
    "DeadlineExceeded": "repro.serving.client",
    "Overloaded": "repro.serving.client",
    "ProtocolError": "repro.serving.client",
    "ServerShutdown": "repro.serving.client",
    "WorkerFailure": "repro.serving.client",
    # in-process service facade (imports jax via core.features)
    "BucketStats": "repro.serving.service",
    "CostModelService": "repro.serving.service",
    "PendingRequest": "repro.serving.service",
    "ServiceStats": "repro.serving.service",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value      # cache: next access skips __getattr__
        return value
    try:                             # `repro.serving.replay`-style access
        return importlib.import_module(f"{__name__}.{name}")
    except ModuleNotFoundError as e:
        if e.name != f"{__name__}.{name}":
            raise                    # real dependency failure inside the
                                     # submodule (e.g. jax missing)
        raise AttributeError(
            f"module 'repro.serving' has no attribute {name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(__all__))
