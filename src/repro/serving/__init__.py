"""Cost-model prediction service (docs/SERVING.md, docs/API.md).

The serving layer between clients (autotuners, fusion/tile evaluators,
future compiler hooks) and the GNN:

* `PredictionCache` — content-addressed LRU keyed by
  `KernelGraph.canonical_hash()`;
* `RequestCoalescer` — accumulates cache-miss graphs and flushes them
  through the bucketed sparse batcher in one call;
* `CostModelService` — the facade: `predict_many`, deferred `submit`,
  drop-in `tile_scorer`/`runtime_predictor`/`cost_fn` adapters, and a
  `stats()` surface (hit rate, bucket occupancy, flush sizes, latency).
"""
from repro.serving.cache import CacheStats, PredictionCache
from repro.serving.coalescer import RequestCoalescer, Ticket
from repro.serving.service import (
    BucketStats,
    CostModelService,
    PendingRequest,
    ServiceStats,
)

__all__ = [
    "CacheStats", "PredictionCache", "RequestCoalescer", "Ticket",
    "BucketStats", "CostModelService", "PendingRequest", "ServiceStats",
]
