"""Request coalescer (docs/SERVING.md, stage 2).

Cache misses from interleaved requests accumulate here instead of hitting
the model one graph at a time. `add` returns a `Ticket` immediately;
identical graphs (same canonical hash) submitted while a flush is pending
share one ticket, so near-duplicate traffic — tile candidates of one
kernel, annealer revisits — is scored exactly once. When the pending node
count reaches `node_budget` (or on an explicit `flush()`), the whole
pending set is handed to the scoring backend in one call, which packs it
through the bucketed sparse batcher (`repro.data.batching`) so only a few
jit executables serve arbitrary traffic.

`add` and `flush` are thread-safe: one re-entrant lock guards the pending
set *and* the scoring call, so concurrent clients (the socket server's
scoring worker, `CostModelService.submit` callers on other threads) can
never double-flush a batch or lose a ticket — a flush atomically claims
the pending set, and every claimed ticket is resolved before the lock
drops.

>>> import numpy as np
>>> from repro.data.synthetic import random_kernel
>>> co = RequestCoalescer(
...     lambda gs: np.array([g.num_nodes for g in gs], np.float32),
...     node_budget=1 << 30)
>>> g = random_kernel(5, seed=0)
>>> t1 = co.add(g.canonical_hash(), g)
>>> t2 = co.add(g.canonical_hash(), g)     # coalesced: same ticket
>>> t1 is t2
True
>>> co.flush()
>>> t1.value
5.0
>>> (co.flushes, co.coalesced)
(1, 1)
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph

ScoreFn = Callable[[Sequence[KernelGraph]], np.ndarray]


class Ticket:
    """Placeholder for one unique pending graph; resolved at flush time."""
    __slots__ = ("value",)

    def __init__(self):
        self.value: float | None = None

    @property
    def ready(self) -> bool:
        return self.value is not None


class RequestCoalescer:
    """Accumulate unique cache-miss graphs; flush them in one batched call.

    `score_fn(graphs) -> np.ndarray` is the batching backend (see
    `CostModelService`); `on_scored(key, value)` — when given — is invoked
    for every resolved graph, which the service uses to fill the prediction
    cache during the flush so later submits already hit.
    """

    def __init__(self, score_fn: ScoreFn, *, node_budget: int = 2048,
                 on_scored: Callable[[str, float], None] | None = None):
        if node_budget < 1:
            raise ValueError(f"node_budget must be >= 1, got {node_budget}")
        self.score_fn = score_fn
        self.node_budget = int(node_budget)
        self.on_scored = on_scored
        # re-entrant: the auto-flush inside `add` re-enters `flush`
        self._lock = threading.RLock()
        self._pending: dict[str, tuple[KernelGraph, Ticket]] = {}
        self._pending_nodes = 0
        self.flushes = 0
        self.coalesced = 0            # duplicate adds absorbed by a ticket
        # bounded history (long-lived services flush millions of times)
        self.flush_sizes: deque[int] = deque(maxlen=4096)  # graphs per flush
        self.flush_nodes: deque[int] = deque(maxlen=4096)  # nodes per flush

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def pending_nodes(self) -> int:
        return self._pending_nodes

    def add(self, key: str, graph: KernelGraph) -> Ticket:
        """Register a miss; returns its (possibly shared) ticket. Flushes
        automatically once the pending set reaches `node_budget` nodes."""
        with self._lock:
            entry = self._pending.get(key)
            if entry is not None:
                self.coalesced += 1
                return entry[1]
            ticket = Ticket()
            self._pending[key] = (graph, ticket)
            self._pending_nodes += graph.num_nodes
            if self._pending_nodes >= self.node_budget:
                self.flush()
            return ticket

    def flush(self) -> None:
        """Score every pending graph in one backend call and resolve all
        tickets. No-op when nothing is pending. If the backend raises
        (a dying worker, an injected fault), the claimed tickets stay
        unresolved and the pending set stays empty — callers observe a
        clean failure, later adds start a fresh batch."""
        with self._lock:
            if not self._pending:
                return
            keys = list(self._pending)
            graphs = [self._pending[k][0] for k in keys]
            tickets = [self._pending[k][1] for k in keys]
            self._pending = {}
            self._pending_nodes = 0
            preds = np.asarray(self.score_fn(graphs), np.float32)
            if preds.shape != (len(graphs),):
                raise ValueError(f"score_fn returned shape {preds.shape}, "
                                 f"expected ({len(graphs)},)")
            self.flushes += 1
            self.flush_sizes.append(len(graphs))
            self.flush_nodes.append(sum(g.num_nodes for g in graphs))
            for key, ticket, p in zip(keys, tickets, preds):
                ticket.value = float(p)
                if self.on_scored is not None:
                    self.on_scored(key, float(p))
