"""Tile-search query-stream replay (docs/SERVING.md §worked-example).

The service's target workload is an autotuner hammering the model with
small, highly redundant kernel graphs. This module reconstructs that
traffic deterministically so the replay CLI
(`python -m repro.launch.serve_costmodel`) and the gating benchmark
(`benchmarks/bench_serving.py`) share one corpus: several search rounds
per kernel, each round scoring an overlapping random subset of the
kernel's tile candidates — exactly the revisit pattern of top-k
re-ranking and annealing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import features as F
from repro.core.features import FeatureNormalizer
from repro.core.graph import KernelGraph
from repro.data.fusion import apply_fusion, default_fusion
from repro.data.synthetic import generate_corpus
from repro.data.tile_dataset import enumerate_tiles


@dataclass
class TileReplay:
    """A deterministic query stream: `requests[i]` is one scoring call
    (a list of (kernel, tile) graphs, i.e. what a tile scorer submits)."""
    requests: list[list[KernelGraph]]
    normalizer: FeatureNormalizer
    num_kernels: int

    @property
    def num_queries(self) -> int:
        return sum(len(r) for r in self.requests)

    @property
    def num_unique(self) -> int:
        return len({g.canonical_hash() for r in self.requests for g in r})


def build_tile_replay(num_programs: int = 8, *, max_configs: int = 16,
                      rounds: int = 4, subset: float = 0.75,
                      seed: int = 0) -> TileReplay:
    """Build the replay stream.

    `rounds` search passes visit every kernel; each pass scores a random
    `subset` fraction of that kernel's tile candidates, so each unique
    (kernel, tile) graph is queried ~`rounds * subset` times — the cache
    hit rate of a replay approaches `1 - 1/(rounds * subset)`. Kernel
    order is shuffled per round to interleave traffic across kernels.
    """
    rng = np.random.default_rng(seed)
    kernels: list[KernelGraph] = []
    for prog in generate_corpus(num_programs, seed=seed):
        kernels.extend(apply_fusion(prog, default_fusion(prog)))
    tiles_by_kernel = []
    for k in kernels:
        tiles = enumerate_tiles(k, max_configs)
        if len(tiles) >= 2:
            k.structural_digest()      # memoize; all tile variants share it
            tiles_by_kernel.append((k, tiles))
    if not tiles_by_kernel:
        raise ValueError("corpus produced no tunable kernels")

    # normalizer statistics from the per-kernel tile extremes (the first /
    # last enumerated combos are the all-min / all-max tiles) — clipping
    # absorbs the interior
    fit_graphs = [k.with_tile(t)
                  for k, tiles in tiles_by_kernel
                  for t in (tiles[0], tiles[-1])]
    normalizer = F.fit_normalizer(fit_graphs)

    requests: list[list[KernelGraph]] = []
    for _ in range(rounds):
        for ki in rng.permutation(len(tiles_by_kernel)):
            k, tiles = tiles_by_kernel[int(ki)]
            n = max(int(round(subset * len(tiles))), 1)
            chosen = rng.choice(len(tiles), size=n, replace=False)
            requests.append([k.with_tile(tiles[int(t)]) for t in chosen])
    return TileReplay(requests, normalizer, len(tiles_by_kernel))


def run_replay(score_request, requests) -> tuple[list[np.ndarray], float]:
    """Feed every request through `score_request(graphs) -> scores`;
    returns (per-request predictions, elapsed seconds)."""
    import time
    preds = []
    t0 = time.perf_counter()
    for req in requests:
        preds.append(np.asarray(score_request(req)))
    return preds, time.perf_counter() - t0
