"""Fusion autotuner: simulated annealing with a hardware-minutes budget
(paper §7.3).

Two operating modes, mirroring Fig. 5:
  * 'HW m'            — anneal directly against hardware measurements for an
    m-minute hardware budget.
  * 'Cost model + HW' — anneal against the learned model (cheap, CPU), then
    re-rank the most promising configs on hardware within a (much smaller)
    hardware budget.

Hardware time is *simulated* wall-clock: each hardware evaluation of a
config charges its compile+run cost to the budget (`eval_seconds`), so the
budget comparison is apples-to-apples without real TPUs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator
from repro.data.fusion import (
    FusionDecision,
    apply_fusion,
    default_fusion,
    fusable_edges,
    random_fusion,
)

CostFn = Callable[[Sequence[KernelGraph]], float]


def model_cost_fn(params, model_cfg, normalizer, *, max_nodes: int = 64,
                  chunk: int = 128, node_budget: int | None = None,
                  predict_fn=None, service=None,
                  cache_capacity: int = 65536) -> CostFn:
    """Program cost under the learned model: Σ exp(predicted log-runtime).

    Scores through the prediction service: neighboring annealing steps
    share most of their kernels, so the content-addressed cache turns the
    per-step cost into scoring only the few kernels the last flip changed.

    Representation follows `model_cfg.adjacency`. The dense path must drop
    kernels above `max_nodes` (its padded slots truncate them anyway); the
    sparse path scores every kernel — packed candidate batches have no
    per-graph cap, which also removes a systematic bias of the dense
    annealer objective on large fusion groups.
    """
    if service is None and cache_capacity:
        from repro.serving import CostModelService
        service = CostModelService(params, model_cfg, normalizer,
                                   max_nodes=max_nodes, chunk=chunk,
                                   node_budget=node_budget,
                                   predict_fn=predict_fn,
                                   cache_capacity=cache_capacity)
    if service is not None:
        drop = max_nodes if service.adjacency == "dense" else None
        return service.cost_fn(drop_above=drop)

    from repro.core.evaluate import make_predict_fn, predict_kernels

    predict = predict_fn or make_predict_fn(model_cfg)

    def cost(kernels: Sequence[KernelGraph]) -> float:
        if model_cfg.adjacency == "dense":
            kernels = [k for k in kernels if k.num_nodes <= max_nodes]
        if not kernels:
            return 0.0
        s = predict_kernels(params, model_cfg, kernels, normalizer,
                            max_nodes=max_nodes, chunk=chunk,
                            predict_fn=predict, node_budget=node_budget)
        return float(np.sum(np.exp(s)))
    return cost


@dataclass
class FusionSearchResult:
    best_decision: FusionDecision
    best_runtime: float             # measured on hardware
    default_runtime: float
    hardware_evals: int
    model_evals: int
    hardware_seconds_used: float
    trace: list[float] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.default_runtime / max(self.best_runtime, 1e-30)


def _anneal(program: KernelGraph, start: FusionDecision, cost: CostFn,
            *, steps: int, rng: np.random.Generator,
            t0: float = 0.1, t1: float = 1e-3,
            max_group: int = 48) -> tuple[list[tuple[float, FusionDecision]],
                                          int]:
    """Simulated annealing over edge decisions; returns visited
    (cost, decision) pairs sorted best-first, and #cost evals."""
    n_edges = len(fusable_edges(program))
    cur = start
    cur_cost = cost(apply_fusion(program, cur, max_group))
    visited: dict[tuple, float] = {cur.fuse: cur_cost}
    evals = 1
    best = [(cur_cost, cur)]
    for i in range(steps):
        if n_edges == 0:
            break
        temp = t0 * (t1 / t0) ** (i / max(steps - 1, 1))
        flips = 1 + int(rng.random() < 0.3)
        cand = cur
        for _ in range(flips):
            cand = cand.flip(int(rng.integers(n_edges)))
        if cand.fuse in visited:
            cand_cost = visited[cand.fuse]
        else:
            cand_cost = cost(apply_fusion(program, cand, max_group))
            visited[cand.fuse] = cand_cost
            evals += 1
            best.append((cand_cost, cand))
        accept = cand_cost < cur_cost or \
            rng.random() < np.exp(-(cand_cost - cur_cost) /
                                  max(temp * cur_cost, 1e-30))
        if accept:
            cur, cur_cost = cand, cand_cost
    best.sort(key=lambda x: x[0])
    return best, evals


def simulated_annealing_fusion(
        program: KernelGraph, sim: TPUSimulator, *,
        model_cost: CostFn | None = None,
        hardware_budget_s: float = 60.0,
        model_steps: int = 300,
        eval_seconds: float = 2.0,
        seed: int = 0,
        start: str = "default",
        max_group: int = 48) -> FusionSearchResult:
    """Search fusion configs for one program.

    model_cost=None  => 'HW m' mode (anneal on hardware directly).
    model_cost given => 'Cost model + HW': anneal on the model, then spend
    the hardware budget re-ranking the model's best configs.
    """
    rng = np.random.default_rng(seed)
    start_dec = default_fusion(program) if start == "default" \
        else random_fusion(program, rng)
    hw_cost: CostFn = lambda kernels: sim.measure_program(kernels)

    default_runtime = hw_cost(apply_fusion(program, default_fusion(program),
                                           max_group))
    hw_evals = 0
    hw_seconds = 0.0
    model_evals = 0
    trace: list[float] = []

    if model_cost is None:
        # anneal directly on hardware until the budget runs out
        budget_steps = max(int(hardware_budget_s / eval_seconds), 1)
        visited, evals = _anneal(program, start_dec, hw_cost,
                                 steps=budget_steps, rng=rng,
                                 max_group=max_group)
        hw_evals = evals
        hw_seconds = evals * eval_seconds
        best_cost, best_dec = visited[0]
        trace = [c for c, _ in visited[:20]]
    else:
        # anneal on the model (free), validate top configs on hardware
        visited, model_evals = _anneal(program, start_dec, model_cost,
                                       steps=model_steps, rng=rng,
                                       max_group=max_group)
        top = visited[:max(int(hardware_budget_s / eval_seconds), 1)]
        best_cost, best_dec = float("inf"), start_dec
        for _, dec in top:
            rt = hw_cost(apply_fusion(program, dec, max_group))
            hw_evals += 1
            hw_seconds += eval_seconds
            trace.append(rt)
            if rt < best_cost:
                best_cost, best_dec = rt, dec
            if hw_seconds >= hardware_budget_s:
                break

    # the compiler default is always available as a fallback
    if default_runtime < best_cost:
        best_cost = default_runtime
        best_dec = default_fusion(program)
    return FusionSearchResult(best_dec, best_cost, default_runtime,
                              hw_evals, model_evals, hw_seconds, trace)
