"""Fusion autotuner: simulated annealing with a hardware-minutes budget
(paper §7.3) — a thin wrapper over the budgeted search engine
(`repro.search`, DESIGN.md §10).

Two operating modes, mirroring Fig. 5:
  * 'HW m'            — anneal directly against hardware measurements for an
    m-minute hardware budget.
  * 'Cost model + HW' — anneal against the learned model (cheap, CPU), then
    re-rank the most promising configs on hardware within a (much smaller)
    hardware budget.

Hardware time is *simulated* wall-clock: each hardware evaluation of a
config charges its compile+run cost to a `BudgetMeter` (`eval_seconds`
per eval) **as it happens**, inside the annealing loop — the search stops
when the next eval no longer fits, so `hardware_seconds_used` can never
overshoot `hardware_budget_s`.

`population > 1` proposes that many flips per temperature step and scores
them in ONE batched flush through the estimator (`CostEstimator
.program_costs` → one coalesced service call) instead of one-by-one —
the model-scoring-throughput win gated by benchmarks/bench_autotune.py.
`population=1` reproduces the classic sequential annealer bit-exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator
from repro.data.fusion import (
    FusionDecision,
    FusionMaterializer,
    default_fusion,
    fusable_edges,
    random_fusion,
)
from repro.search import BudgetMeter, CostEstimator, HardwareEstimator, \
    anneal

CostFn = Callable[[Sequence[KernelGraph]], float]


def model_cost_fn(params, model_cfg, normalizer, *, max_nodes: int = 64,
                  chunk: int = 128, node_budget: int | None = None,
                  predict_fn=None, service=None,
                  cache_capacity: int = 65536) -> CostFn:
    """Program cost under the learned model: Σ exp(predicted log-runtime).

    Built on `search.LearnedEstimator.from_params` — the one home of the
    service-construction kwargs. Scores through the prediction service:
    neighboring annealing steps share most of their kernels, so the
    content-addressed cache turns the per-step cost into scoring only the
    few kernels the last flip changed. (To also batch across a
    `population`, pass the estimator itself via
    `simulated_annealing_fusion(..., estimator=...)` instead.)

    Representation follows `model_cfg.adjacency`. The dense path must drop
    kernels above `max_nodes` (its padded slots truncate them anyway); the
    sparse path scores every kernel — packed candidate batches have no
    per-graph cap, which also removes a systematic bias of the dense
    annealer objective on large fusion groups.
    """
    from repro.search import LearnedEstimator
    est = LearnedEstimator.from_params(params, model_cfg, normalizer,
                                       max_nodes=max_nodes, chunk=chunk,
                                       node_budget=node_budget,
                                       predict_fn=predict_fn,
                                       service=service,
                                       cache_capacity=cache_capacity)
    return est.cost_fn()


@dataclass
class FusionSearchResult:
    best_decision: FusionDecision
    best_runtime: float             # measured on hardware
    default_runtime: float
    hardware_evals: int
    model_evals: int
    hardware_seconds_used: float
    trace: list[float] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.default_runtime / max(self.best_runtime, 1e-30)


def _propose_flips(n_edges: int):
    """The classic move: flip one edge, sometimes two (30%)."""
    def propose(cur: FusionDecision,
                rng: np.random.Generator) -> FusionDecision:
        flips = 1 + int(rng.random() < 0.3)
        cand = cur
        for _ in range(flips):
            cand = cand.flip(int(rng.integers(n_edges)))
        return cand
    return propose


def simulated_annealing_fusion(
        program: KernelGraph, sim: TPUSimulator, *,
        model_cost: CostFn | None = None,
        estimator: CostEstimator | None = None,
        hardware_budget_s: float = 60.0,
        model_steps: int = 300,
        eval_seconds: float = 2.0,
        seed: int = 0,
        start: str = "default",
        max_group: int = 48,
        population: int = 1,
        meter: BudgetMeter | None = None,
        rerank_top: int | None = None) -> FusionSearchResult:
    """Search fusion configs for one program.

    Neither model_cost nor estimator => 'HW m' mode (anneal on hardware
    directly, budget enforced per-eval inside the loop).
    model_cost (a `CostFn`) or estimator (a `CostEstimator`; enables
    population batching) => 'Cost model + HW': anneal on the model, then
    spend the hardware budget re-ranking the model's best configs.

    Pass a shared `meter` to budget several searches jointly (e.g. the
    cross-scenario driver in examples/autotune_zoo.py); by default a
    fresh meter with `hardware_budget_s` / `eval_seconds` is used.
    `rerank_top` caps how many model-ranked configs the hardware re-rank
    may verify (default: whatever the budget affords) — set it when a
    shared meter must keep budget for later searches. The
    compiler-default config measurement is the baseline, not tuning, and
    is not charged.
    """
    if model_cost is not None and estimator is not None:
        raise ValueError("pass model_cost or estimator, not both")
    rng = np.random.default_rng(seed)
    start_dec = default_fusion(program) if start == "default" \
        else random_fusion(program, rng)
    if meter is None:
        meter = BudgetMeter(budget_s=hardware_budget_s,
                            eval_seconds=eval_seconds)
    evals0, seconds0 = meter.evals, meter.spent_s
    hw = HardwareEstimator(sim, meter=meter)
    n_edges = len(fusable_edges(program))
    propose = _propose_flips(n_edges)
    # one memoized materializer per search: candidates share almost all
    # groups, so kernel construction + content hashing is paid once per
    # unique group, not once per candidate
    materialize = FusionMaterializer(program, max_group)

    default_runtime = sim.measure_program(
        materialize(default_fusion(program)))
    model_evals = 0
    trace: list[float] = []

    if model_cost is None and estimator is None:
        # anneal directly on hardware; the meter stops the loop. The step
        # cap mirrors the meter's actual eval capacity (a shared meter
        # may afford more than this call's hardware_budget_s default);
        # an unbounded meter falls back to the budget argument.
        budget_steps = max(meter.affordable(1 << 20), 1)
        if budget_steps >= 1 << 20:
            budget_steps = max(int(hardware_budget_s / eval_seconds), 1)
        res = anneal(
            start_dec, propose=propose,
            cost_many=lambda decs: [hw.measure_program(materialize(d))
                                    for d in decs],
            steps=budget_steps if n_edges else 0, rng=rng,
            key=lambda d: d.fuse, meter=meter)
        if res.visited:
            best_cost, best_dec = res.best
            trace = [c for c, _ in res.visited[:20]]
        else:                                  # budget afforded nothing
            best_cost, best_dec = float("inf"), start_dec
    else:
        # anneal on the model (free), validate top configs on hardware
        if estimator is not None:
            drop = getattr(estimator, "max_nodes", None) \
                if getattr(estimator, "adjacency", None) == "dense" else None

            def cost_many(decs: list[FusionDecision]) -> np.ndarray:
                groups = []
                for d in decs:
                    ks = materialize(d)
                    if drop is not None:
                        ks = [k for k in ks if k.num_nodes <= drop]
                    groups.append(ks)
                return estimator.program_costs(groups)   # ONE batched flush
        else:
            def cost_many(decs: list[FusionDecision]) -> list[float]:
                return [model_cost(materialize(d)) for d in decs]

        res = anneal(start_dec, propose=propose, cost_many=cost_many,
                     steps=model_steps if n_edges else 0, rng=rng,
                     population=population, key=lambda d: d.fuse)
        model_evals = res.evals
        best_cost, best_dec = float("inf"), start_dec
        top = res.visited if rerank_top is None else \
            res.visited[:max(rerank_top, 0)]
        for _, dec in top:
            if meter.affordable(1) < 1:
                break
            rt = hw.measure_program(materialize(dec))
            trace.append(rt)
            if rt < best_cost:
                best_cost, best_dec = rt, dec

    # the compiler default is always available as a fallback
    if default_runtime < best_cost:
        best_cost = default_runtime
        best_dec = default_fusion(program)
    return FusionSearchResult(best_dec, best_cost, default_runtime,
                              meter.evals - evals0, model_evals,
                              meter.spent_s - seconds0, trace)
