"""Autotuners driven by the learned performance model (paper §7).

* Tile-size autotuner: rank all valid tiles with a model, evaluate the top-k
  on hardware (§7.2); k=1 is direct compiler integration (§7.1).
* Fusion autotuner: simulated annealing over fusion configurations with a
  hardware-minutes budget; the learned model pre-screens candidates on CPU
  so scarce accelerator time is spent only on the most promising configs
  (§7.3).

Both are thin wrappers over the budgeted search engine in `repro.search`
(estimators, `BudgetMeter`, `topk_rerank`, population `anneal`) — pass
`estimator=` / `meter=` for batched scoring and shared hardware budgets
(DESIGN.md §10).
"""
from repro.autotuner.tile_autotuner import (
    TileTuneResult,
    autotune_program_tiles,
    model_scorer,
    tune_kernel_tiles,
)
from repro.autotuner.fusion_autotuner import (
    FusionSearchResult,
    model_cost_fn,
    simulated_annealing_fusion,
)

__all__ = [
    "TileTuneResult", "autotune_program_tiles", "model_scorer",
    "tune_kernel_tiles",
    "FusionSearchResult", "model_cost_fn", "simulated_annealing_fusion",
]
