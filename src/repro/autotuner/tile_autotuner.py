"""Tile-size autotuner (paper §7.1/§7.2) — a thin wrapper over the
budgeted search engine (`repro.search`, DESIGN.md §10).

Modes:
  * 'exhaustive' — measure every valid tile on hardware (the baseline
    autotuner; expensive). Each tile is measured exactly once and the
    measurements double as the regret oracle.
  * model top-k  — rank candidates with a cost model (learned, analytical
    or a cascade), measure only the top-k on hardware, keep the best.
    k=1 == direct compiler integration (no hardware in the loop).

Rankings come either from a legacy `scorer(kernel, tiles)` callable or —
preferably — a `repro.search.CostEstimator`: with an estimator,
`autotune_program_tiles` scores ALL kernels' candidates of a program in
one coalesced service flush, and an optional `BudgetMeter` caps the
hardware verification across the whole program.

The same interface tunes this framework's own Pallas kernels: block-shape
candidates from `repro.kernels.*.ops.block_candidates()` are scored the
same way (see examples/autotune_tilesize.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator
from repro.data.tile_dataset import enumerate_tiles
from repro.search import BudgetMeter, CostEstimator, topk_rerank

Scorer = Callable[[KernelGraph, Sequence[tuple[int, ...]]], np.ndarray]


def model_scorer(params, model_cfg, normalizer, *, max_nodes: int = 64,
                 chunk: int = 128, node_budget: int | None = None,
                 service=None, cache_capacity: int = 65536) -> Scorer:
    """Learned-model scorer for `tune_kernel_tiles`, scoring through the
    prediction service (`repro.serving.CostModelService`): tile candidates
    of one kernel are near-duplicate graphs, so across tuning passes the
    content-addressed cache absorbs most queries, and misses flush through
    the bucketed batcher in `model_cfg.adjacency` representation ('sparse'
    packs candidates into flat bucketed batches — markedly higher scoring
    throughput on big candidate sets — while 'dense' keeps the padded
    [B, N, N] layout). Pass `service` to share one cache across scorers."""
    from repro.core.evaluate import learned_tile_scorer
    return learned_tile_scorer(params, model_cfg, normalizer,
                               max_nodes=max_nodes, chunk=chunk,
                               node_budget=node_budget, service=service,
                               cache_capacity=cache_capacity)


@dataclass
class TileTuneResult:
    kernel_name: str
    chosen_tile: tuple[int, ...]
    chosen_runtime: float            # measured on hardware (NaN: model-only)
    best_runtime: float              # exhaustive-best (if known)
    hardware_evals: int
    candidates: int

    @property
    def regret(self) -> float:
        """Relative slowdown of the chosen tile vs the exhaustive best.

        >>> r = TileTuneResult("k", (8,), chosen_runtime=1.2,
        ...                    best_runtime=1.0, hardware_evals=3,
        ...                    candidates=10)
        >>> round(r.regret, 6)
        0.2
        """
        if self.best_runtime <= 0:
            return 0.0
        return self.chosen_runtime / self.best_runtime - 1.0


def _measure_all(kernel: KernelGraph, sim: TPUSimulator,
                 tiles: Sequence[tuple[int, ...]]) -> list[float]:
    """One hardware pass over every tile — the regret oracle. Measured
    once and reused (the old exhaustive mode measured everything twice)."""
    return [sim.measure(kernel.with_tile(t)) for t in tiles]


def _tune_group(kernel: KernelGraph, sim: TPUSimulator,
                tiles: list[tuple[int, ...]], scores: np.ndarray, *,
                top_k: int, exhaustive_truth: bool,
                meter: BudgetMeter | None) -> TileTuneResult:
    """Shared top-k verification for one kernel, with the oracle pass (if
    requested) reused for the top-k measurements (the simulator's
    measurements are deterministic per (kernel, tile))."""
    oracle = _measure_all(kernel, sim, tiles) if exhaustive_truth else None
    candidates = [kernel.with_tile(t) for t in tiles]
    by_id = {} if oracle is None else \
        {id(g): rt for g, rt in zip(candidates, oracle)}

    def measure(g: KernelGraph) -> float:
        rt = by_id.get(id(g))
        return sim.measure(g) if rt is None else rt

    choice, = topk_rerank([candidates], scores=[np.asarray(scores)],
                          measure=measure, top_k=top_k, meter=meter)
    true_best = min(oracle) if oracle is not None else choice.chosen_runtime
    return TileTuneResult(kernel.name, tiles[choice.chosen],
                          choice.chosen_runtime, true_best,
                          hardware_evals=choice.hardware_evals,
                          candidates=len(tiles))


def tune_kernel_tiles(kernel: KernelGraph, sim: TPUSimulator, *,
                      scorer: Scorer | None = None, top_k: int = 10,
                      max_configs: int = 128,
                      tiles: Sequence[tuple[int, ...]] | None = None,
                      exhaustive_truth: bool = True,
                      estimator: CostEstimator | None = None,
                      meter: BudgetMeter | None = None) -> TileTuneResult:
    """Tune one kernel. scorer=None and estimator=None => exhaustive
    hardware search. `meter` (model-ranked modes) caps hardware
    verification; the oracle pass (`exhaustive_truth`) is evaluation
    harness, not tuning, and is never charged."""
    if scorer is not None and estimator is not None:
        raise ValueError("pass scorer or estimator, not both")
    if tiles is None:
        tiles = enumerate_tiles(kernel, max_configs, sim.hw)
    tiles = list(tiles)
    if not tiles:
        raise ValueError(f"no valid tiles for kernel {kernel.name}")

    if scorer is None and estimator is None:     # exhaustive autotuner
        runtimes = _measure_all(kernel, sim, tiles)
        i = int(np.argmin(runtimes))
        return TileTuneResult(kernel.name, tiles[i], float(runtimes[i]),
                              min(runtimes) if exhaustive_truth
                              else float(runtimes[i]),
                              hardware_evals=len(tiles),
                              candidates=len(tiles))

    if estimator is not None:
        kernel.structural_digest()   # memoize once; tile variants share
        scores = estimator.estimate([kernel.with_tile(t) for t in tiles])
    else:
        scores = np.asarray(scorer(kernel, tiles))
    return _tune_group(kernel, sim, tiles, scores, top_k=top_k,
                       exhaustive_truth=exhaustive_truth, meter=meter)


@dataclass
class ProgramTuneResult:
    results: list[TileTuneResult] = field(default_factory=list)

    @property
    def total_runtime(self) -> float:
        """Sum of chosen runtimes. Deliberately NaN when any kernel went
        unverified (a budget-exhausted `meter` run) — check `unverified`
        / use `measured_runtime` before comparing against thresholds."""
        return sum(r.chosen_runtime for r in self.results)

    @property
    def unverified(self) -> int:
        """Kernels whose top-k verification got no hardware budget."""
        return sum(1 for r in self.results if r.hardware_evals == 0)

    @property
    def measured_runtime(self) -> float:
        """Total over the hardware-verified kernels only."""
        return sum(r.chosen_runtime for r in self.results
                   if r.hardware_evals > 0)

    @property
    def best_runtime(self) -> float:
        return sum(r.best_runtime for r in self.results)

    @property
    def hardware_evals(self) -> int:
        return sum(r.hardware_evals for r in self.results)

    def speedup_over(self, other_total: float) -> float:
        return other_total / max(self.total_runtime, 1e-30)


def autotune_program_tiles(kernels: Sequence[KernelGraph],
                           sim: TPUSimulator, *,
                           scorer: Scorer | None = None,
                           top_k: int = 10, max_configs: int = 128,
                           estimator: CostEstimator | None = None,
                           meter: BudgetMeter | None = None,
                           exhaustive_truth: bool = True
                           ) -> ProgramTuneResult:
    """Tune every kernel of a program.

    With an `estimator`, all kernels' tile candidates are scored in ONE
    batched call (one coalesced service flush for a `LearnedEstimator` /
    per-stage flushes for a cascade) before any hardware is touched; a
    shared `meter` then budgets the top-k verification across the whole
    program. The legacy `scorer` path ranks kernel-by-kernel."""
    if scorer is not None and estimator is not None:
        raise ValueError("pass scorer or estimator, not both")
    out = ProgramTuneResult()
    if estimator is None:
        for k in kernels:
            out.results.append(
                tune_kernel_tiles(k, sim, scorer=scorer, top_k=top_k,
                                  max_configs=max_configs, meter=meter,
                                  exhaustive_truth=exhaustive_truth))
        return out

    tiles_per_kernel: list[list[tuple[int, ...]]] = []
    groups: list[list[KernelGraph]] = []
    for k in kernels:
        tiles = list(enumerate_tiles(k, max_configs, sim.hw))
        if not tiles:
            raise ValueError(f"no valid tiles for kernel {k.name}")
        k.structural_digest()        # memoize once; tile variants share
        tiles_per_kernel.append(tiles)
        groups.append([k.with_tile(t) for t in tiles])
    scores = estimator.estimate_groups(groups)   # ONE coalesced flush
    for k, tiles, s in zip(kernels, tiles_per_kernel, scores):
        out.results.append(
            _tune_group(k, sim, tiles, s, top_k=top_k,
                        exhaustive_truth=exhaustive_truth, meter=meter))
    return out
