"""Tile-size autotuner (paper §7.1/§7.2).

Modes:
  * 'exhaustive' — measure every valid tile on hardware (the baseline
    autotuner; expensive).
  * model top-k  — rank candidates with a cost model (learned or
    analytical), measure only the top-k on hardware, keep the best.
    k=1 == direct compiler integration (no hardware in the loop).

The same interface tunes this framework's own Pallas kernels: block-shape
candidates from `repro.kernels.*.ops.block_candidates()` are scored the
same way (see examples/autotune_tilesize.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.graph import KernelGraph
from repro.core.simulator import TPUSimulator
from repro.data.tile_dataset import enumerate_tiles

Scorer = Callable[[KernelGraph, Sequence[tuple[int, ...]]], np.ndarray]


def model_scorer(params, model_cfg, normalizer, *, max_nodes: int = 64,
                 chunk: int = 128, node_budget: int | None = None,
                 service=None, cache_capacity: int = 65536) -> Scorer:
    """Learned-model scorer for `tune_kernel_tiles`, scoring through the
    prediction service (`repro.serving.CostModelService`): tile candidates
    of one kernel are near-duplicate graphs, so across tuning passes the
    content-addressed cache absorbs most queries, and misses flush through
    the bucketed batcher in `model_cfg.adjacency` representation ('sparse'
    packs candidates into flat bucketed batches — markedly higher scoring
    throughput on big candidate sets — while 'dense' keeps the padded
    [B, N, N] layout). Pass `service` to share one cache across scorers."""
    from repro.core.evaluate import learned_tile_scorer
    return learned_tile_scorer(params, model_cfg, normalizer,
                               max_nodes=max_nodes, chunk=chunk,
                               node_budget=node_budget, service=service,
                               cache_capacity=cache_capacity)


@dataclass
class TileTuneResult:
    kernel_name: str
    chosen_tile: tuple[int, ...]
    chosen_runtime: float            # measured on hardware
    best_runtime: float              # exhaustive-best (if known)
    hardware_evals: int
    candidates: int

    @property
    def regret(self) -> float:
        """Relative slowdown of the chosen tile vs the exhaustive best.

        >>> r = TileTuneResult("k", (8,), chosen_runtime=1.2,
        ...                    best_runtime=1.0, hardware_evals=3,
        ...                    candidates=10)
        >>> round(r.regret, 6)
        0.2
        """
        if self.best_runtime <= 0:
            return 0.0
        return self.chosen_runtime / self.best_runtime - 1.0


def tune_kernel_tiles(kernel: KernelGraph, sim: TPUSimulator, *,
                      scorer: Scorer | None = None, top_k: int = 10,
                      max_configs: int = 128,
                      tiles: Sequence[tuple[int, ...]] | None = None,
                      exhaustive_truth: bool = True) -> TileTuneResult:
    """Tune one kernel. scorer=None => exhaustive hardware search."""
    if tiles is None:
        tiles = enumerate_tiles(kernel, max_configs, sim.hw)
    tiles = list(tiles)
    if not tiles:
        raise ValueError(f"no valid tiles for kernel {kernel.name}")

    true_best = float("inf")
    if exhaustive_truth:
        true_best = min(sim.measure(kernel.with_tile(t)) for t in tiles)

    if scorer is None:                       # exhaustive autotuner
        runtimes = [sim.measure(kernel.with_tile(t)) for t in tiles]
        i = int(np.argmin(runtimes))
        return TileTuneResult(kernel.name, tiles[i], float(runtimes[i]),
                              true_best if exhaustive_truth
                              else float(runtimes[i]),
                              hardware_evals=len(tiles),
                              candidates=len(tiles))

    scores = np.asarray(scorer(kernel, tiles))
    order = np.argsort(scores)[:max(top_k, 1)]
    measured = [(int(i), sim.measure(kernel.with_tile(tiles[int(i)])))
                for i in order]
    bi, bt = min(measured, key=lambda x: x[1])
    return TileTuneResult(kernel.name, tiles[bi], float(bt),
                          true_best if exhaustive_truth else float(bt),
                          hardware_evals=len(measured),
                          candidates=len(tiles))


@dataclass
class ProgramTuneResult:
    results: list[TileTuneResult] = field(default_factory=list)

    @property
    def total_runtime(self) -> float:
        return sum(r.chosen_runtime for r in self.results)

    @property
    def best_runtime(self) -> float:
        return sum(r.best_runtime for r in self.results)

    @property
    def hardware_evals(self) -> int:
        return sum(r.hardware_evals for r in self.results)

    def speedup_over(self, other_total: float) -> float:
        return other_total / max(self.total_runtime, 1e-30)


def autotune_program_tiles(kernels: Sequence[KernelGraph],
                           sim: TPUSimulator, *, scorer: Scorer | None,
                           top_k: int = 10, max_configs: int = 128
                           ) -> ProgramTuneResult:
    out = ProgramTuneResult()
    for k in kernels:
        out.results.append(
            tune_kernel_tiles(k, sim, scorer=scorer, top_k=top_k,
                              max_configs=max_configs))
    return out
