"""Activation-sharding context.

Model code stays mesh-agnostic: it calls `constrain(x, name)` at key points
(post-embedding, block outputs, MoE dispatch buffers, microbatch reshape).
When a launcher wraps tracing in `activation_sharding(mapping)`, those calls
become `with_sharding_constraint`s; otherwise they are identity. The mapping
values are either PartitionSpecs or rank-indexed spec factories.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_CTX = threading.local()


@contextmanager
def activation_sharding(mapping: dict):
    """mapping: name -> PartitionSpec | callable(rank)->PartitionSpec.
    Special key 'dp': the data-parallel mesh axis (str or tuple) used for
    batch/microbatch constraints."""
    prev = getattr(_CTX, "map", None)
    _CTX.map = mapping
    try:
        yield
    finally:
        _CTX.map = prev


def _lookup(name: str):
    m = getattr(_CTX, "map", None)
    if not m:
        return None
    return m.get(name)


def dp_axes():
    """The data-parallel axis name(s), or None outside a context."""
    return _lookup("dp")


def _axis_size(axes) -> int:
    sizes = _lookup("axis_sizes") or {}
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def _divides(shape, spec) -> bool:
    for dim, axes in zip(shape, tuple(spec)):
        if axes is not None and dim % _axis_size(axes) != 0:
            return False
    return True


def constrain(x, name: str):
    spec = _lookup(name)
    if spec is None:
        return x
    if callable(spec):
        spec = spec(x.ndim)
    if not _divides(x.shape, spec):
        return x           # constraint would be invalid; let GSPMD decide
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch_tree(tree, leading: int = 1):
    """Constrain every array in a batch pytree: dims [0:leading] unsharded,
    dim `leading` over the dp axes, rest unsharded. Used for the microbatch
    reshape inside train_step (keeps GSPMD from resharding the scan input)."""
    dp = dp_axes()
    if dp is None:
        return tree

    def one(x):
        if x.ndim <= leading:
            return x
        spec = P(*([None] * leading + [dp] + [None] * (x.ndim - leading - 1)))
        if not _divides(x.shape, spec):
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    return jax.tree_util.tree_map(one, tree)


def shard_map_nocheck(f, mesh, in_specs, out_specs):
    """`shard_map` with replication/VMA checking off, spelled compatibly:
    the entry point moved from jax.experimental to jax, and the kwarg was
    renamed check_rep → check_vma, on independent version boundaries."""
    import inspect
    try:
        from jax import shard_map as _sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
    kw = ("check_vma" if "check_vma" in inspect.signature(_sm).parameters
          else "check_rep")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               **{kw: False})
