"""Parameter / optimizer / batch / cache partition rules.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * dp   = ("pod","data") or "data" — batch & FSDP axis
  * tp   = "model"                  — heads / d_ff / vocab / experts axis

Rules are *candidate lists*: the first spec whose sharded dims evenly divide
the leaf's shape wins (jit argument shardings must divide exactly — there is
no GSPMD padding for explicit input shardings). This is how e.g.:
  * yi-34b's 56 q-heads fall back to head-dim (128) sharding on 16-way TP,
  * recurrentgemma's MQA kv=1 falls back to replicated KV,
  * granite's 40 experts fall back from EP to TP over the expert FFN dim,
  * mamba2's vocab 50280 falls back to embedding-column sharding.
Each fallback is a real, coherent TP variant (extra collectives appear in
the dry-run HLO and are priced by §Roofline).
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_dp_axes(mesh: Mesh):
    axes = mesh.axis_names
    if "pod" in axes:
        return ("pod", "data")
    return "data"


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_divides(spec: P, shape, mesh: Mesh) -> bool:
    for dim, axes in zip(shape, tuple(spec)):
        if axes is None:
            continue
        if dim % axis_size(mesh, axes) != 0:
            return False
    return True


def choose_spec(shape, candidates, mesh: Mesh) -> P:
    for c in candidates:
        c = P(*(tuple(c) + (None,) * (len(shape) - len(tuple(c)))))
        if spec_divides(c, shape, mesh):
            return c
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _candidates(name: str, ndim: int, dp, fsdp: bool):
    """Candidate specs (most → least preferred) over non-scan dims."""
    f = dp if fsdp else None
    tp = "model"
    table = {
        # embeddings / head: vocab over tp, else d_model over tp
        ("embed", 2): [P(tp, f), P(None, tp)],
        ("lm_head", 2): [P(f, tp), P(tp, None)],
        # attention qkv [D, H, hd]: heads over tp, else head_dim over tp
        ("wq", 3): [P(f, tp, None), P(f, None, tp), P(f, None, None)],
        ("wk", 3): [P(f, tp, None), P(f, None, tp), P(f, None, None)],
        ("wv", 3): [P(f, tp, None), P(f, None, tp), P(f, None, None)],
        ("wo", 3): [P(tp, None, f), P(None, tp, f), P(None, None, f)],
        # MLA
        ("wdq", 2): [P(f, tp), P(f, None)],
        ("wuq", 3): [P(None, tp, None), P(tp, None, None)],
        ("wdkv", 2): [P(f, None)],
        ("wuk", 3): [P(None, tp, None), P(tp, None, None)],
        ("wuv", 3): [P(None, tp, None), P(tp, None, None)],
        # dense MLP [D, F]
        ("w_gate", 2): [P(f, tp), P(None, tp)],
        ("w_up", 2): [P(f, tp), P(None, tp)],
        ("w_down", 2): [P(tp, f), P(tp, None)],
        # MoE experts [E, D, F]: EP over tp, else TP over F
        ("router", 2): [P(f, None)],
        ("w_gate", 3): [P(tp, f, None), P(None, f, tp)],
        ("w_up", 3): [P(tp, f, None), P(None, f, tp)],
        ("w_down", 3): [P(tp, None, f), P(None, tp, f)],
        ("e_bias", 1): [P(None)],
        # SSD / RG-LRU
        ("w_in", 2): [P(f, tp), P(f, None)],
        ("w_x", 2): [P(f, tp), P(f, None)],
        ("w_out", 2): [P(tp, f), P(None, f)],
        ("w_rg", 2): [P(None, tp)],
        ("w_ig", 2): [P(None, tp)],
        ("conv_w", 2): [P(None, tp)],
        ("conv_b", 1): [P(tp)],
        ("lam", 1): [P(tp)],
    }
    return table.get((name, ndim), [])


def param_specs(cfg, params_like, mesh: Mesh):
    """PartitionSpec pytree matching the params tree."""
    dp = mesh_dp_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    specs = []
    for path, leaf in flat:
        p = _path_str(path)
        name = None
        for part in reversed(p.split("/")):
            if not part.isdigit():
                name = part
                break
        in_stack = "stacks" in p
        shape = tuple(leaf.shape)
        eff_shape = shape[1:] if in_stack else shape
        cands = _candidates(name, len(eff_shape), dp, cfg.fsdp)
        if name == "embed" and getattr(cfg, "embed_shard", "vocab") == \
                "dmodel":
            cands = [P(None, "model")]
        if name == "lm_head" and getattr(cfg, "embed_shard", "vocab") == \
                "dmodel":
            cands = [P(None, "model"), P(dp if cfg.fsdp else None, "model")]
        spec = choose_spec(eff_shape, cands, mesh)
        if in_stack:
            spec = P(None, *spec)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(p_specs, params_like, opt_like):
    """Optimizer-state specs derived from param specs by shape matching
    (AdamW m/v mirror params; Adafactor row/col factors drop a dim)."""
    flat_p = jax.tree_util.tree_leaves(params_like)
    flat_spec = jax.tree_util.tree_leaves(
        p_specs, is_leaf=lambda x: isinstance(x, P))
    shape_to_spec = {}
    for leaf, spec in zip(flat_p, flat_spec):
        sh = tuple(leaf.shape)
        t = tuple(spec)
        shape_to_spec.setdefault(sh, spec)
        if len(sh) >= 1:
            shape_to_spec.setdefault(sh[:-1], P(*t[:-1]))
        if len(sh) >= 2:
            shape_to_spec.setdefault(sh[:-2] + sh[-1:],
                                     P(*(t[:-2] + t[-1:])))

    def one(leaf):
        sh = tuple(leaf.shape)
        return shape_to_spec.get(sh, P(*([None] * len(sh))))

    return jax.tree_util.tree_map(one, opt_like)


def batch_specs(batch_like, mesh: Mesh):
    """Input batch: dim 0 over dp (when divisible)."""
    dp = mesh_dp_axes(mesh)

    def one(leaf):
        sh = tuple(leaf.shape)
        if not sh:
            return P()
        return choose_spec(sh, [P(dp)], mesh)

    return jax.tree_util.tree_map(one, batch_like)


def cache_specs(cfg, cache_like, mesh: Mesh, *, batch_size: int):
    """Decode caches. Layout per leaf: [repeats, B, ...].

    * B > 1: batch over dp; heads/latent/head-dim over tp (candidates).
    * B == 1 (long_500k): sequence parallelism — the cache length dim is
      sharded over dp instead (cfg.seq_shard_decode).
    """
    dp = mesh_dp_axes(mesh)
    tp = "model"
    seq_shard = batch_size == 1 and cfg.seq_shard_decode

    def cands_for(name: str, nd: int):
        if name in ("k", "v") and nd == 5:            # [R,B,C,KH,hd]
            if seq_shard:
                return [P(None, None, dp, tp, None),
                        P(None, None, dp, None, tp),
                        P(None, None, dp, None, None)]
            return [P(None, dp, None, tp, None),
                    P(None, dp, None, None, tp),
                    P(None, dp, tp, None, None),
                    P(None, dp, None, None, None)]
        if name in ("ckv", "krope") and nd == 4:      # [R,B,C,r]
            if seq_shard:
                return [P(None, None, dp, tp), P(None, None, dp, None)]
            return [P(None, dp, None, tp), P(None, dp, None, None)]
        if name == "k_pos" and nd == 3:               # [R,B,C]
            if seq_shard:
                return [P(None, None, dp)]
            return [P(None, dp, None)]
        if name == "state" and nd == 5:               # ssd [R,B,H,N,P]
            b = None if seq_shard else dp
            return [P(None, b, tp, None, None), P(None, b, None, None, None)]
        if name == "state" and nd == 3:               # rglru [R,B,W]
            b = None if seq_shard else dp
            return [P(None, b, tp), P(None, b, None)]
        if name == "conv" and nd == 4:                # [R,B,W-1,C]
            b = None if seq_shard else dp
            return [P(None, b, None, tp), P(None, b, None, None)]
        return []

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    specs = []
    for path, leaf in flat:
        name = _path_str(path).split("/")[-1]
        sh = tuple(leaf.shape)
        specs.append(choose_spec(sh, cands_for(name, len(sh)), mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def abstract_with_sharding(abstract, specs, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run params)."""
    def one(a, s):
        return jax.ShapeDtypeStruct(a.shape, a.dtype,
                                    sharding=NamedSharding(mesh, s))
    return jax.tree_util.tree_map(one, abstract, specs)
