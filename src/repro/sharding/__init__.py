from repro.sharding.partition import (
    batch_specs,
    cache_specs,
    opt_specs,
    param_specs,
)
from repro.sharding.context import activation_sharding, constrain, dp_axes, \
    shard_map_nocheck

__all__ = ["batch_specs", "cache_specs", "opt_specs", "param_specs",
           "activation_sharding", "constrain", "dp_axes",
           "shard_map_nocheck"]
