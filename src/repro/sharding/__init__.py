from repro.sharding.partition import (
    batch_specs,
    cache_specs,
    opt_specs,
    param_specs,
)
from repro.sharding.context import activation_sharding, constrain, \
    constrain_batch_tree, dp_axes, shard_map_nocheck
from repro.sharding.mesh import DATA_AXIS, MODEL_AXIS, make_train_mesh

__all__ = ["batch_specs", "cache_specs", "opt_specs", "param_specs",
           "activation_sharding", "constrain", "constrain_batch_tree",
           "dp_axes", "shard_map_nocheck",
           "DATA_AXIS", "MODEL_AXIS", "make_train_mesh"]
