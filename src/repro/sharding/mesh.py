"""Training meshes for the cost-model trainer (DESIGN.md §13).

`repro.launch.mesh` builds the *production LM* meshes (dp × fsdp × tp over
512 devices, checked by the dryrun probes). The cost-model trainer needs
something much smaller: a dp (× optional mp) mesh over however many local
devices the process actually has — 2 fake CPU devices under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` in CI, real
accelerators in production. This module is that factory, kept in
`repro.sharding` so the trainer never imports launch code.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

DATA_AXIS = "data"
MODEL_AXIS = "model"


def make_train_mesh(dp: int, mp: int = 1) -> Mesh:
    """A ``(dp, mp)`` mesh with axes ``("data", "model")`` over the first
    ``dp * mp`` local devices.

    The model axis exists even at ``mp == 1`` so a trainer compiled against
    the two-axis layout needs no special case; cost-model params are
    replicated over both axes today, and a future tensor-parallel GNN only
    has to partition over the already-present ``"model"`` axis.

    Raises ValueError when the host doesn't have enough devices — the
    actionable fix on CPU hosts is in the message.
    """
    if dp < 1 or mp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp} mp={mp}")
    need = dp * mp
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"mesh dp={dp} x mp={mp} needs {need} devices but only "
            f"{len(devices)} are visible; on CPU hosts set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    grid = np.asarray(devices[:need]).reshape(dp, mp)
    return Mesh(grid, (DATA_AXIS, MODEL_AXIS))
