"""Quickstart: train a small learned performance model and use it to rank
tile sizes for a kernel — the paper's core loop in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.evaluate import eval_tile_task
from repro.data.tile_dataset import build_tile_dataset, fit_tile_normalizer
from repro.core.model import CostModelConfig
from repro.core.simulator import TPUSimulator
from repro.data.sampler import TileBatchSampler
from repro.data.synthetic import generate_corpus
from repro.serving import CostModelService
from repro.training.optim import AdamWConfig
from repro.training.trainer import CostModelTrainer, TrainerConfig

MAX_NODES = 48

# 1. a corpus of tensor programs + the measurement oracle ("the hardware")
sim = TPUSimulator()
programs = generate_corpus(16, seed=0)
dataset = build_tile_dataset(programs, sim, max_configs_per_kernel=12)
print(f"corpus: {len(programs)} programs, {len(dataset.records)} kernels, "
      f"{dataset.num_samples} (kernel, tile) samples")

# 2. train the learned model with the pairwise rank loss (Eq. 1)
norm = fit_tile_normalizer(dataset.records)
model_cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                            hidden_dim=48, opcode_embed_dim=16,
                            max_nodes=MAX_NODES)
sampler = TileBatchSampler(dataset.records, norm, kernels_per_batch=3,
                           configs_per_kernel=8, max_nodes=MAX_NODES)
trainer = CostModelTrainer(
    model_cfg,
    TrainerConfig(task="tile", steps=300, ckpt_every=0, log_every=100,
                  optim=AdamWConfig(lr=2e-3, schedule="constant")),
    sampler)
res = trainer.run(resume=False)
print(f"trained 300 steps, final rank loss {res['loss']:.4f}")

# 3. serve the trained model (docs/SERVING.md) and rank tile sizes for one
#    kernel — predictions go through the cached, coalescing service
service = CostModelService(trainer.params, model_cfg, norm,
                           max_nodes=MAX_NODES, chunk=32)
scorer = service.tile_scorer()
rec = max(dataset.records, key=lambda r: len(r.tiles))
scores = scorer(rec.kernel, rec.tiles)
pred_best = rec.tiles[int(np.argmin(scores))]
true_best = rec.tiles[int(np.argmin(rec.runtimes))]
print(f"kernel {rec.kernel.name}: {len(rec.tiles)} candidate tiles")
print(f"  model's pick {pred_best} -> "
      f"{sim.measure(rec.kernel.with_tile(pred_best)):.3e}s")
print(f"  true best    {true_best} -> {rec.runtimes.min():.3e}s")

# 4. whole-test-set quality (Tile-Size APE, Eq. 2 + Kendall tau)
metrics = eval_tile_task(dataset, scorer)
print(f"mean tile APE {metrics['mean_ape']:.2f}%  "
      f"mean Kendall tau {metrics['mean_kendall']:.3f}")

# the service cached every (kernel, tile) query above; step 3's kernel hit
stats = service.stats()
print(f"service: {stats.graphs} queries, hit rate {stats.hit_rate:.1%}, "
      f"{stats.flushes} flushes, p50 {stats.latency_p50_ms:.1f}ms/call")
