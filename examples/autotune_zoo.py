"""Joint tile + fusion autotuning across the LM-architecture zoo under
one fixed hardware budget (the paper's §7 scenario at zoo scale;
DESIGN.md §10).

For each imported architecture graph (`repro.configs` via
`core.hlo_import`), one `BudgetMeter` spans the whole scenario:

  1. fusion search — population-batched simulated annealing against the
     learned model (one coalesced service flush per temperature step),
     then hardware re-ranking of the best configs within the budget;
  2. tile search  — the fused kernels' tile candidates scored by a
     `CascadeEstimator` (analytical prune → learned refine, half the
     learned-model queries), top-k verified on whatever budget remains.

The final chosen configuration is measured once at the end ("deploy and
observe") — that measurement is reporting, not tuning, and is not
charged against the budget.

  PYTHONPATH=src python examples/autotune_zoo.py
  PYTHONPATH=src python examples/autotune_zoo.py \\
      --archs yi-9b musicgen-large --budget-s 120 --population 16
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.autotuner import autotune_program_tiles, \
    simulated_annealing_fusion
from repro.core.evaluate import make_predict_fn
from repro.core.hlo_import import import_arch_program
from repro.core.model import CostModelConfig, cost_model_init
from repro.core.simulator import TPUSimulator
from repro.data.fusion import apply_fusion, default_fusion
from repro.data.synthetic import generate_corpus
from repro.data.tile_dataset import build_tile_dataset, fit_tile_normalizer
from repro.data.sampler import TileBatchSampler
from repro.search import AnalyticalEstimator, BudgetMeter, \
    CascadeEstimator, LearnedEstimator
from repro.training.optim import AdamWConfig
from repro.training.trainer import CostModelTrainer, TrainerConfig

MAX_NODES = 48

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--archs", nargs="+",
                    default=["musicgen-large", "yi-9b",
                             "granite-moe-3b-a800m"])
parser.add_argument("--budget-s", type=float, default=90.0,
                    help="hardware budget per architecture (simulated s)")
parser.add_argument("--eval-seconds", type=float, default=2.0)
parser.add_argument("--train-steps", type=int, default=250,
                    help="cost-model training steps (synthetic corpus)")
parser.add_argument("--population", type=int, default=8)
parser.add_argument("--model-steps", type=int, default=160,
                    help="annealing proposals (split across the population)")
args = parser.parse_args()

sim = TPUSimulator()

# --- a small learned model, trained on the synthetic corpus --------------
print(f"training cost model ({args.train_steps} steps on synthetic corpus)")
corpus = generate_corpus(12, seed=0)
tds = build_tile_dataset(corpus, sim, max_configs_per_kernel=12)
norm = fit_tile_normalizer(tds.records)
cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                      hidden_dim=48, opcode_embed_dim=16, dropout=0.0,
                      max_nodes=MAX_NODES, adjacency="sparse")
sampler = TileBatchSampler(tds.records, norm, kernels_per_batch=3,
                           configs_per_kernel=8, max_nodes=MAX_NODES)
trainer = CostModelTrainer(
    cfg, TrainerConfig(task="tile", steps=args.train_steps, ckpt_every=0,
                       log_every=100,
                       optim=AdamWConfig(lr=2e-3, schedule="constant")),
    sampler)
trainer.run(args.train_steps, resume=False)
params = trainer.params
predict_fn = make_predict_fn(cfg)

for arch in args.archs:
    prog = import_arch_program(arch)
    meter = BudgetMeter(budget_s=args.budget_s,
                        eval_seconds=args.eval_seconds)
    learned = LearnedEstimator.from_params(params, cfg, norm,
                                           max_nodes=MAX_NODES,
                                           node_budget=1024,
                                           predict_fn=predict_fn)

    # 1) fusion: population-batched anneal + hardware re-rank capped so
    # the shared budget keeps room for the tile phase
    r_fus = simulated_annealing_fusion(
        prog, sim, estimator=learned, meter=meter,
        population=args.population,
        model_steps=max(args.model_steps // args.population, 1),
        rerank_top=max(int(args.budget_s / args.eval_seconds) // 3, 1),
        seed=0)
    kernels = apply_fusion(prog, r_fus.best_decision)

    # 2) tiles: cascade scoring, top-k verified on the remaining budget —
    # most expensive kernels first (free analytical ordering, one
    # batched call), so the leftover hardware time goes where the
    # runtime is
    order = np.argsort(-AnalyticalEstimator().estimate(kernels))
    kernels = [kernels[int(i)] for i in order]
    refine = LearnedEstimator.from_params(params, cfg, norm,
                                          max_nodes=MAX_NODES,
                                          node_budget=1024,
                                          predict_fn=predict_fn)
    cascade = CascadeEstimator([AnalyticalEstimator(), refine], keep=0.5)
    r_tile = autotune_program_tiles(kernels, sim, scorer=None,
                                    estimator=cascade, top_k=4,
                                    max_configs=12, meter=meter,
                                    exhaustive_truth=False)

    # deploy-and-observe: a verified tile replaces the compiler default
    # only if its (already budget-charged) measurement beats it — the
    # default is always available as a fallback, like the fusion search
    tuned = improved = 0.0
    for k, r in zip(kernels, r_tile.results):
        base = sim.measure(k)
        best = min(base, r.chosen_runtime) if r.hardware_evals else base
        improved += base - best
        tuned += best
    verified = sum(1 for r in r_tile.results if r.hardware_evals)
    total_candidates = sum(r.candidates for r in r_tile.results)

    print(f"\n{prog.name}: {prog.num_nodes} nodes -> "
          f"{len(kernels)} fused kernels")
    print(f"  default fusion: {r_fus.default_runtime:.3e}s; "
          f"fusion search {r_fus.speedup:.3f}x "
          f"({r_fus.model_evals} model evals, "
          f"{r_fus.hardware_evals} hw evals)")
    print(f"  tile cascade: {verified}/{len(kernels)} kernels verified, "
          f"{refine.queries}/{total_candidates} learned queries "
          f"({cascade.stages[0].queries} analytical)")
    print(f"  tuned runtime: {tuned:.3e}s "
          f"({r_fus.default_runtime / max(tuned, 1e-30):.3f}x vs default); "
          f"budget {meter.spent_s:.0f}/{args.budget_s:.0f}s "
          f"({meter.evals} hw evals)")
    assert meter.spent_s <= args.budget_s + 1e-9
