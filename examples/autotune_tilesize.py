"""Tile-size autotuning (paper §7.2) — including this framework's own
Pallas flash-attention block shapes.

Part A reproduces the autotuner comparison on corpus kernels: exhaustive vs
learned-top-k vs analytical-top-k hardware usage.

Part B closes the loop on the framework itself: the flash-attention kernel's
(block_q, block_k) candidates are encoded as tile sizes of an attention
kernel graph and ranked by the same machinery.

  PYTHONPATH=src python examples/autotune_tilesize.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.autotuner import autotune_program_tiles, tune_kernel_tiles
from repro.core.analytical import AnalyticalModel
from repro.core import opset
from repro.core.evaluate import analytical_tile_scorer
from repro.core.graph import KernelGraph, Node
from repro.core.simulator import TPUSimulator
from repro.data.fusion import apply_fusion, default_fusion
from repro.data.synthetic import generate_program
from repro.kernels.flash_attention.ops import block_candidates

sim = TPUSimulator()

# --- Part A: corpus program ---------------------------------------------
prog = generate_program("attention", 0, seed=42)
kernels = apply_fusion(prog, default_fusion(prog))
scorer = analytical_tile_scorer(AnalyticalModel())
ex = autotune_program_tiles(kernels, sim, scorer=None, max_configs=24)
top10 = autotune_program_tiles(kernels, sim, scorer=scorer, top_k=10,
                               max_configs=24)
top1 = autotune_program_tiles(kernels, sim, scorer=scorer, top_k=1,
                              max_configs=24)
print("Part A — attention program,", len(kernels), "kernels")
print(f"  exhaustive: {ex.total_runtime:.3e}s "
      f"({ex.hardware_evals} hardware evals)")
print(f"  model top-10: {top10.total_runtime:.3e}s "
      f"({top10.hardware_evals} evals)")
print(f"  model top-1 (in-compiler): {top1.total_runtime:.3e}s "
      f"({top1.hardware_evals} evals)")

# --- Part B: the framework's own flash-attention kernel -------------------
# One (batch*heads) slice of flash attention as a kernel graph: the Pallas
# grid tiles the [S_q, S_k] score space with (block_q, block_k).
S, hd = 4096, 128
nodes = [
    Node(opset.PARAMETER, (S, hd), 2),                 # q
    Node(opset.PARAMETER, (S, hd), 2),                 # k
    Node(opset.PARAMETER, (S, hd), 2),                 # v
    Node(opset.DOT, (S, S), 2, (0, 1), contract_dim=hd),   # scores
    Node(opset.REDUCE_MAX, (S,), 2, (3,), reduced_dims=(S,)),
    Node(opset.BROADCAST, (S, S), 2, (4,)),
    Node(opset.SUB, (S, S), 2, (3, 5)),
    Node(opset.EXP, (S, S), 2, (6,)),
    Node(opset.DOT, (S, hd), 2, (7, 2), contract_dim=S,
         is_output=True),                              # p @ v
]
attn_kernel = KernelGraph(nodes, program="repro.kernels.flash_attention",
                          name="flash_attention[4096,128]")
tiles = [(bq, bk) for bq, bk in block_candidates(S, S)]
res = tune_kernel_tiles(attn_kernel, sim, scorer=scorer, top_k=5,
                        tiles=tiles)
print("\nPart B — Pallas flash-attention block shapes")
print(f"  candidates: {len(tiles)}; chosen (block_q, block_k) = "
      f"{res.chosen_tile}")
print(f"  chosen runtime {res.chosen_runtime:.3e}s, exhaustive best "
      f"{res.best_runtime:.3e}s, regret {100*res.regret:.2f}%")
