"""Fusion autotuning with a hardware budget (paper §7.3 / Fig. 5).

Compares simulated annealing on hardware alone vs. pre-screening with the
analytical model (stand-in for a trained learned model; see
examples/train_cost_model.py for the full learned pipeline).

  PYTHONPATH=src python examples/fusion_search.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.autotuner import simulated_annealing_fusion
from repro.core.analytical import AnalyticalModel
from repro.core.simulator import TPUSimulator
from repro.data.synthetic import generate_program

sim = TPUSimulator()
am = AnalyticalModel()
model_cost = lambda kernels: sum(am.predict(k) for k in kernels)  # noqa

for fam, idx in [("attention", 1), ("rnn", 2), ("norm", 0)]:
    prog = generate_program(fam, idx, seed=0)
    r_hw = simulated_annealing_fusion(prog, sim, model_cost=None,
                                      hardware_budget_s=60,
                                      eval_seconds=2.0, seed=0)
    r_cm = simulated_annealing_fusion(prog, sim, model_cost=model_cost,
                                      hardware_budget_s=6, model_steps=300,
                                      eval_seconds=2.0, seed=0)
    print(f"{prog.name}: default {r_hw.default_runtime:.3e}s")
    print(f"  HW-only  (60s budget): {r_hw.speedup:.3f}x speedup, "
          f"{r_hw.hardware_evals} hardware evals")
    print(f"  model+HW ( 6s budget): {r_cm.speedup:.3f}x speedup, "
          f"{r_cm.hardware_evals} hardware evals "
          f"({r_cm.model_evals} model evals on CPU)")
