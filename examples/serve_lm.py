"""Serve a small model with batched requests: prefill + iterative decode
through the production serving steps (same code paths the decode_32k /
long_500k dry-run cells lower at full scale).

  PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-3-4b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm, registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode-steps", type=int, default=32)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    capacity = args.prompt_len + args.decode_steps
    params = lm.init_params(jax.random.key(0), cfg)
    prefill = jax.jit(lm.prefill_step_fn(cfg, capacity=capacity))
    decode = jax.jit(lm.decode_step_fn(cfg))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{time.time()-t0:.2f}s (incl. compile)")

    t0 = time.time()
    generated = []
    for t in range(args.prompt_len, capacity):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, cache, nxt, jnp.asarray(t, jnp.int32))
    dt = time.time() - t0
    n = args.decode_steps * args.batch
    print(f"decoded {n} tokens in {dt:.2f}s -> {n/dt:.1f} tok/s (CPU, "
          f"interpret-free jnp path)")
    print("first request's continuation:",
          np.stack(generated, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
