"""End-to-end production driver: train the fusion-task cost model for a few
hundred steps with the full substrate — corpus incl. programs imported from
the assigned architectures, train/val/test splits, checkpointing + resume,
JSONL metrics, periodic eval — then hand the model to both autotuners.

  PYTHONPATH=src python examples/train_cost_model.py [--steps 600]
      [--adjacency dense|sparse] [--prefetch 2] [--dp N]
      [--num-hosts H --host-id h]

--adjacency selects the batched-graph representation end-to-end (sampler,
trainer, evaluation, autotuner): 'dense' pads each kernel to a [N, N]
adjacency slot; 'sparse' packs kernels into bucketed flat node/edge buffers
(segment-sum aggregation — same numerics, much higher throughput on
mixed-size corpora; see DESIGN.md §4 and benchmarks/bench_batching.py).

--prefetch encodes that many batches ahead on a background thread
(byte-identical batch stream; DESIGN.md §9, 0 = synchronous).

--store DIR makes the corpus a durable artifact (docs/DATA.md): the first
run fans generation + oracle measurement across worker processes into a
sharded on-disk store under DIR, and every later run streams the records
shard-by-shard from disk instead of rebuilding them (build once, reuse
forever — rebuilding an unchanged spec is a manifest-hash no-op).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.autotuner import model_cost_fn, simulated_annealing_fusion
from repro.core.evaluate import (
    eval_fusion_task,
    learned_runtime_predictor,
    make_predict_fn,
)
from repro.core.features import fit_normalizer
from repro.core.hlo_import import import_arch_program
from repro.core.model import CostModelConfig
from repro.core.simulator import TPUSimulator
from repro.data.corpus import filter_by_programs, split_programs
from repro.data.fusion_dataset import FusionDataset, build_fusion_dataset
from repro.data.sampler import BalancedSampler
from repro.data.synthetic import generate_corpus
from repro.training.optim import AdamWConfig
from repro.training.trainer import CostModelTrainer, TrainerConfig

MAX_NODES = 48


def _rebuild_program(name: str):
    """Regenerate one pre-fusion program graph by its corpus name —
    `arch_<zoo-name>` imports that architecture, `<family>_<idx>` re-runs
    the deterministic synthetic generator."""
    if name.startswith("arch_"):
        return import_arch_program(name[len("arch_"):])
    from repro.data.synthetic import generate_program
    family, idx = name.rsplit("_", 1)
    return generate_program(family, int(idx), seed=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--ckpt-dir", default="ckpts/fusion_model")
    ap.add_argument("--adjacency", choices=("dense", "sparse"),
                    default="dense")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="batches encoded ahead by a background thread "
                         "(0 = synchronous)")
    ap.add_argument("--store", default="",
                    help="corpus store root: built on the first run, "
                         "streamed from disk on every later run")
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel mesh size (0 = single-device path; "
                         ">=1 runs the mesh train step on dp local devices "
                         "— on CPU, export XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=<dp> first)")
    ap.add_argument("--num-hosts", type=int, default=1,
                    help="total training hosts (the sampler draws from "
                         "this host's disjoint record shard)")
    ap.add_argument("--host-id", type=int, default=0,
                    help="this host's index in [0, --num-hosts)")
    args = ap.parse_args()
    if args.num_hosts < 1 or not 0 <= args.host_id < args.num_hosts:
        ap.error(f"--host-id must be in [0, --num-hosts={args.num_hosts}), "
                 f"got {args.host_id}")

    # ---- data: synthetic families + imported architectures
    sim = TPUSimulator()
    archs = ("yi-9b", "mamba2-2.7b", "granite-moe-3b-a800m")
    if args.store:
        # build-once-reuse-forever: a no-op when the spec is unchanged.
        # Generation (incl. the jaxpr arch imports) happens in the builder
        # workers on the first run only — warm runs touch no generator.
        from repro.data.store import StreamingCorpus
        from repro.launch.build_corpus import build_corpus
        build_corpus(args.store, kinds=("fusion",), programs=24, seed=0,
                     import_archs=archs, workers=os.cpu_count() or 1,
                     fusion_opts={"configs_per_program": 10})
        corpus = StreamingCorpus.open(os.path.join(args.store, "fusion"))
        names = corpus.programs()
        split = split_programs(names, method="random")
        train_recs = corpus.select_programs(split["train"])
        test_recs = list(corpus.select_programs(split["test"]))
        num_samples = len(corpus)
    else:
        programs = generate_corpus(24, seed=0)
        for arch in archs:
            programs.append(import_arch_program(arch))
        ds = build_fusion_dataset(programs, sim, configs_per_program=10)
        names = [p.program for p in programs]
        split = split_programs(names, method="random")
        train_recs = filter_by_programs(ds.records, split["train"])
        test_recs = filter_by_programs(ds.records, split["test"])
        num_samples = ds.num_samples
    norm = fit_normalizer([r.kernel for r in train_recs])
    print(f"{len(names)} programs -> {num_samples} kernels "
          f"({len(train_recs)} train / {len(test_recs)} test)")

    # ---- model + trainer (checkpointed; rerun to resume)
    mc = CostModelConfig(gnn="graphsage", reduction="transformer",
                         hidden_dim=64, opcode_embed_dim=16,
                         max_nodes=MAX_NODES, adjacency=args.adjacency)
    sampler = BalancedSampler(train_recs, norm, batch_size=24,
                              max_nodes=MAX_NODES, adjacency=mc.adjacency,
                              host_id=args.host_id,
                              num_hosts=args.num_hosts)

    def eval_fn(params, step):
        pred = learned_runtime_predictor(params, mc, norm,
                                         max_nodes=MAX_NODES, chunk=32)
        res = eval_fusion_task(FusionDataset(test_recs), pred)
        return {"test_mape": res["mean_mape"],
                "test_kendall": res["mean_kendall"]}

    trainer = CostModelTrainer(
        mc,
        TrainerConfig(task="fusion", steps=args.steps, ckpt_every=200,
                      log_every=100, ckpt_dir=args.ckpt_dir,
                      metrics_path=os.path.join(args.ckpt_dir,
                                                "metrics.jsonl"),
                      prefetch=args.prefetch, dp=args.dp,
                      optim=AdamWConfig(lr=2e-3)),
        sampler)
    res = trainer.run(eval_fn=eval_fn, eval_every=200)
    print(f"training done at step {res['step']}: loss={res['loss']:.4f}")

    ev = eval_fn(trainer.params, res["step"])
    print(f"held-out programs: MAPE {ev['test_mape']:.1f}%  "
          f"Kendall {ev['test_kendall']:.3f}")

    # ---- hand the model to the fusion autotuner on a held-out program
    # (representation follows mc.adjacency: sparse scores every candidate
    # kernel; dense drops kernels above MAX_NODES)
    model_cost = model_cost_fn(trainer.params, mc, norm,
                               max_nodes=MAX_NODES, chunk=32,
                               predict_fn=make_predict_fn(mc))

    if args.store:
        # rebuild just the one target program (generation is deterministic
        # and cheap; only this name is re-imported/re-generated)
        target = _rebuild_program(split["test"][0])
    else:
        by_name = {p.program: p for p in programs}
        target = by_name[split["test"][0]]
    r = simulated_annealing_fusion(target, sim, model_cost=model_cost,
                                   hardware_budget_s=10, model_steps=200,
                                   seed=0)
    print(f"fusion autotuner on held-out {target.name}: "
          f"{r.speedup:.3f}x speedup over compiler default with only "
          f"{r.hardware_evals} hardware evals")


if __name__ == "__main__":
    main()
