"""Objective (Eq. 1) and metric (Eq. 2, Kendall, MAPE) tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.losses import log_mse_loss, mse_loss, pairwise_rank_loss
from repro.core.metrics import kendall_tau, mape, tile_size_ape


def test_rank_loss_perfect_order_with_margin():
    # predictions with margin >= 1 in the true order => hinge loss 0
    y_true = jnp.array([1.0, 2.0, 3.0])
    y_pred = jnp.array([0.0, 2.0, 4.0])
    l = pairwise_rank_loss(y_pred, y_true, phi="hinge")
    assert float(l) == pytest.approx(0.0, abs=1e-6)


def test_rank_loss_worst_order_positive():
    y_true = jnp.array([1.0, 2.0, 3.0])
    y_pred = jnp.array([3.0, 2.0, 1.0])
    l = pairwise_rank_loss(y_pred, y_true, phi="hinge")
    assert float(l) > 1.0


def test_rank_loss_group_masking():
    # cross-group pairs must not contribute: two groups with opposite order
    y_true = jnp.array([1.0, 2.0, 10.0, 20.0])
    y_pred = jnp.array([0.0, 5.0, 100.0, 200.0])   # correct within groups
    groups = jnp.array([0, 0, 1, 1])
    l = pairwise_rank_loss(y_pred, y_true, groups, phi="hinge")
    assert float(l) == pytest.approx(0.0, abs=1e-6)
    # without groups, cross pairs (e.g. 5 vs 100) are fine too here; flip
    # group 1 order to check masking really isolates:
    y_pred2 = jnp.array([0.0, 5.0, 200.0, 100.0])  # wrong within group 1
    l2 = pairwise_rank_loss(y_pred2, y_true, groups, phi="hinge")
    assert float(l2) > 0


@given(st.lists(st.floats(min_value=-5, max_value=5), min_size=2,
                max_size=12))
@settings(max_examples=50, deadline=None)
def test_rank_loss_nonnegative(preds):
    p = jnp.asarray(preds, jnp.float32)
    t = jnp.arange(len(preds), dtype=jnp.float32)
    for phi in ("hinge", "logistic"):
        l = pairwise_rank_loss(p, t, phi=phi)
        assert float(l) >= 0.0


def test_log_mse_matches_manual():
    preds = jnp.array([0.0, 1.0])
    targets = jnp.array([1.0, np.e])
    l = log_mse_loss(preds, targets)
    assert float(l) == pytest.approx(0.0, abs=1e-9)


def test_valid_mask_in_losses():
    preds = jnp.array([0.0, 100.0])
    targets = jnp.array([1.0, 1.0])
    v = jnp.array([1.0, 0.0])
    assert float(log_mse_loss(preds, targets, v)) == pytest.approx(0.0,
                                                                   abs=1e-9)
    assert float(mse_loss(preds, targets, v)) == pytest.approx(1.0, abs=1e-6)


def test_kendall_extremes_and_brute_force():
    assert kendall_tau([1, 2, 3, 4], [10, 20, 30, 40]) == 1.0
    assert kendall_tau([4, 3, 2, 1], [10, 20, 30, 40]) == -1.0
    rng = np.random.default_rng(0)
    for _ in range(5):
        a = rng.normal(size=7)
        b = rng.normal(size=7)
        # brute force
        conc = 0
        n = len(a)
        for i in range(n):
            for j in range(i + 1, n):
                conc += np.sign(a[i] - a[j]) * np.sign(b[i] - b[j])
        assert kendall_tau(a, b) == pytest.approx(conc / (n * (n - 1) / 2))


def test_tile_size_ape_eq2():
    # kernel 1: picks config with runtime 1.2 while best is 1.0
    # kernel 2: picks the true best (2.0)
    per_kernel = [
        {"true": [1.0, 1.2, 3.0], "pred": [5.0, 1.0, 9.0]},
        {"true": [2.0, 4.0], "pred": [0.1, 0.9]},
    ]
    # sum |chosen - best| = 0.2 ; sum best = 3.0 -> 6.666%
    assert tile_size_ape(per_kernel) == pytest.approx(100 * 0.2 / 3.0)


def test_mape():
    assert mape([1.1, 0.9], [1.0, 1.0]) == pytest.approx(10.0, rel=1e-6)
