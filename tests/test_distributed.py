"""Distribution tests that need >1 device: run in subprocesses with
--xla_force_host_platform_device_count (the main test process must keep
the real single-device view, per the assignment)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_smoke_archs_lower_on_mesh():
    """Every arch × {train, prefill, decode, long-decode} lowers+compiles on
    a 4×2 host mesh with the production partition rules."""
    out = _run("""
        import jax
        from repro.models import registry
        from repro.models.config import ShapeSpec
        from repro.launch.lowering import lower_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shapes = [ShapeSpec("t", 64, 8, "train"),
                  ShapeSpec("p", 64, 8, "prefill"),
                  ShapeSpec("d", 64, 8, "decode"),
                  ShapeSpec("l", 64, 1, "decode")]
        n = 0
        for arch in registry.list_archs():
            cfg = registry.get_smoke_config(arch)
            for shape in shapes:
                cell = lower_cell(arch, cfg, shape, mesh, "test")
                assert cell.cost_analysis.get("flops", 0) > 0, (arch, shape)
                n += 1
        print("CELLS", n)
    """)
    assert "CELLS 40" in out


@pytest.mark.slow
def test_multipod_mesh_smoke():
    """(pod, data, model) mesh lowers a train step; pod axis shards batch."""
    out = _run("""
        import jax
        from repro.models import registry
        from repro.models.config import ShapeSpec
        from repro.launch.lowering import lower_cell
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = registry.get_smoke_config("yi-9b")
        cell = lower_cell("yi-9b", cfg, ShapeSpec("t", 64, 8, "train"),
                          mesh, "multipod")
        coll = {k: v for k, v in cell.collective_bytes.items()
                if k != "_counts"}
        assert cell.cost_analysis["flops"] > 0
        assert sum(coll.values()) > 0   # gradient reduction crosses pods
        print("MULTIPOD OK", sorted(coll))
    """)
    assert "MULTIPOD OK" in out


@pytest.mark.slow
def test_data_parallel_training_equivalence():
    """Cost-model train step on a 4-way DP mesh matches single-device
    training bit-for-bit in loss trajectory."""
    out = _run("""
        import jax, numpy as np
        from repro.core.features import fit_normalizer
        from repro.core.model import CostModelConfig
        from repro.core.simulator import TPUSimulator
        from repro.data.sampler import TileBatchSampler
        from repro.data.synthetic import generate_corpus
        from repro.data.tile_dataset import build_tile_dataset
        from repro.training.trainer import CostModelTrainer, TrainerConfig
        from repro.training.optim import AdamWConfig
        from jax.sharding import Mesh

        progs = generate_corpus(4, seed=0)
        tds = build_tile_dataset(progs, TPUSimulator(),
                                 max_configs_per_kernel=4)
        from repro.data.tile_dataset import fit_tile_normalizer
        norm = fit_tile_normalizer(tds.records)
        sampler = TileBatchSampler(tds.records, norm, kernels_per_batch=2,
                                   configs_per_kernel=4, max_nodes=32)
        mc = CostModelConfig(hidden_dim=16, opcode_embed_dim=4, max_nodes=32,
                             reduction="per_node", gnn_layers=1,
                             node_final_layers=1)
        tc = TrainerConfig(task="tile", steps=5, ckpt_every=0, log_every=1,
                           optim=AdamWConfig(lr=1e-3))
        losses = {}
        for ndev in (1, 4):
            mesh = Mesh(np.array(jax.devices()[:ndev]), ("data",))
            tr = CostModelTrainer(mc, tc, sampler, mesh=mesh)
            res = tr.run(5, resume=False)
            losses[ndev] = res["loss"]
        assert abs(losses[1] - losses[4]) < 1e-5, losses
        print("DP EQUIV", losses)
    """)
    assert "DP EQUIV" in out


@pytest.mark.slow
def test_compressed_allreduce_multidevice():
    """int8 error-feedback all-reduce across 4 devices ≈ exact mean."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.sharding.context import shard_map_nocheck
        from repro.training.compression import compressed_allreduce

        mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
        g = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0

        def f(g_local):
            ef = {"g": jnp.zeros_like(g_local[0])}
            red, _ = compressed_allreduce({"g": g_local[0]}, ef, "data")
            return red["g"][None]

        red = shard_map_nocheck(f, mesh, in_specs=P("data"),
                                out_specs=P("data"))(g)
        expect = jnp.mean(g, axis=0)
        err = float(jnp.max(jnp.abs(red[0] - expect)))
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert err <= scale + 1e-6, (err, scale)
        print("COMPRESSED OK", err)
    """)
    assert "COMPRESSED OK" in out


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    """GPipe pipeline over 4 stages == sequential layer application."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.training.pipeline import pipeline_apply, \
            pipeline_stage_split

        mesh = jax.make_mesh((4,), ("stage",))
        L, D, M, mb = 8, 16, 6, 2
        key = jax.random.key(0)
        Ws = jax.random.normal(key, (L, D, D)) * 0.3

        def layer(w, x):
            return jnp.tanh(x @ w)

        def stage_fn(stage_params, x):
            def body(h, w):
                return layer(w, h), None
            h, _ = jax.lax.scan(body, x, stage_params)
            return h

        x = jax.random.normal(jax.random.key(1), (M, mb, D))
        stage_params = pipeline_stage_split(Ws, 4)
        y_pipe = pipeline_apply(stage_fn, stage_params, x, mesh=mesh,
                                axis="stage")
        y_seq = x
        for i in range(L):
            y_seq = layer(Ws[i], y_seq)
        err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
        assert err < 1e-5, err
        print("PIPELINE OK", err)
    """, devices=4)
    assert "PIPELINE OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_remesh():
    """Checkpoint written under a 2-device mesh restores onto 8 devices
    with different shardings (elastic scaling)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import restore_checkpoint, \
            save_checkpoint

        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        d = tempfile.mkdtemp()
        m2 = Mesh(np.array(jax.devices()[:2]), ("data",))
        state2 = jax.device_put(state["w"],
                                NamedSharding(m2, P("data", None)))
        save_checkpoint(d, 1, {"w": state2})
        m8 = Mesh(np.array(jax.devices()[:8]), ("data",))
        sh = {"w": NamedSharding(m8, P(None, "data"))}
        restored, step, _ = restore_checkpoint(d, state, shardings=sh)
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out
