"""Model-zoo tests.

Per assignment: every architecture gets a SMOKE test instantiating a
reduced config of the same family and running one forward/train step on CPU
asserting output shapes + no NaNs. Plus decode-vs-forward consistency (the
serving path must agree with the training path) and config-spec checks for
the FULL configs (exercised for real only by the dry-run).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import SHAPES, get_config, get_smoke_config, list_archs, \
    shape_applicable
from repro.models import lm
from repro.models.config import SMOKE_SHAPE, ShapeSpec
from repro.models.inputs import input_specs, make_batch

ALL_ARCHS = list_archs()


def test_registry_has_all_ten():
    assert len(ALL_ARCHS) == 10


# --------------------------------------------------------- full-config spec
FULL_SPEC = {
    "h2o-danube-3-4b": dict(L=24, d=3840, H=32, KH=8, dff=10240, V=32000),
    "yi-9b": dict(L=48, d=4096, H=32, KH=4, dff=11008, V=64000),
    "yi-34b": dict(L=60, d=7168, H=56, KH=8, dff=20480, V=64000),
    "qwen3-14b": dict(L=40, d=5120, H=40, KH=8, dff=17408, V=151936),
    "mamba2-2.7b": dict(L=64, d=2560, V=50280),
    "recurrentgemma-9b": dict(L=38, d=4096, H=16, KH=1, dff=12288, V=256000),
    "granite-moe-3b-a800m": dict(L=32, d=1536, H=24, KH=8, V=49155,
                                 experts=40, topk=8),
    "deepseek-v3-671b": dict(L=61, d=7168, H=128, V=129280, experts=256,
                             topk=8),
    "musicgen-large": dict(L=48, d=2048, H=32, KH=32, dff=8192, V=2048),
    "llava-next-34b": dict(L=60, d=7168, H=56, KH=8, dff=20480, V=64000),
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = FULL_SPEC[arch]
    assert cfg.num_layers == spec["L"], arch
    assert cfg.d_model == spec["d"]
    assert cfg.vocab_size == spec["V"]
    if "H" in spec:
        assert cfg.num_heads == spec["H"]
    if "KH" in spec:
        assert cfg.num_kv_heads == spec["KH"]
    if "dff" in spec:
        assert cfg.d_ff == spec["dff"]
    if "experts" in spec:
        assert cfg.moe.num_experts == spec["experts"]
        assert cfg.moe.top_k == spec["topk"]


def test_deepseek_param_count_near_671b():
    cfg = get_config("deepseek-v3-671b")
    n = lm.analytic_param_count(cfg)
    assert 6.4e11 < n < 7.0e11, n


def test_long_500k_applicability_rule():
    long = SHAPES["long_500k"]
    applicable = {a for a in ALL_ARCHS
                  if shape_applicable(get_config(a), long)[0]}
    assert applicable == {"h2o-danube-3-4b", "mamba2-2.7b",
                          "recurrentgemma-9b"}


# ------------------------------------------------------------- smoke steps
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, SMOKE_SHAPE)
    opt_init, _ = lm.make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(lm.train_step_fn(cfg))
    new_params, new_opt, stats = step(params, opt, batch)
    loss = float(stats["loss"])
    assert np.isfinite(loss) and loss > 0
    # shapes preserved
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(new_params)):
        assert a.shape == b.shape
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, SMOKE_SHAPE)
    x = lm._embed_inputs(params, cfg, batch)
    h = lm.forward_trunk(params, cfg, x)
    logits = lm.logits_fn(params, cfg, h)
    B = SMOKE_SHAPE.global_batch
    assert logits.shape == (B, SMOKE_SHAPE.seq_len, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


# ----------------------------------------------- decode == forward parity
DECODE_ARCHS = ["yi-9b", "h2o-danube-3-4b", "qwen3-14b", "mamba2-2.7b",
                "recurrentgemma-9b", "granite-moe-3b-a800m",
                "deepseek-v3-671b", "musicgen-large", "yi-34b",
                "llava-next-34b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits from [prefill(S-1 tokens) + decode(token S-1)] must equal the
    full forward's last-position logits."""
    cfg = get_smoke_config(arch)
    if cfg.embed_inputs or cfg.num_patch_tokens:
        pytest.skip("frontend-stub archs decode from tokens; parity is "
                    "covered by the text archs sharing the same backbone")
    S = 33  # S-1=32 divisible by smoke ssd chunk (16)
    params = lm.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)

    # full forward
    x = lm._embed_inputs(params, cfg, {"tokens": tokens})
    h = lm.forward_trunk(params, cfg, x)
    full_logits = lm.logits_fn(params, cfg, h)[:, -1, :]

    # prefill on S-1, decode token S-1
    prefill = lm.prefill_step_fn(cfg, capacity=S)
    _, cache = prefill(params, {"tokens": tokens[:, :S - 1]})
    decode = lm.decode_step_fn(cfg)
    logits, cache = decode(params, cache, tokens[:, S - 1:S],
                           jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0, :]),
                               np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)


def test_decode_steps_chain(tmp_path):
    """Multi-step decode stays finite and cache positions advance."""
    cfg = get_smoke_config("h2o-danube-3-4b")
    params = lm.init_params(jax.random.key(0), cfg)
    prefill = lm.prefill_step_fn(cfg, capacity=64)
    decode = jax.jit(lm.decode_step_fn(cfg))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    logits, cache = prefill(params, {"tokens": tokens})
    for t in range(16, 24):
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        logits, cache = decode(params, cache, nxt, jnp.asarray(t, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_microbatch_accumulation_invariance():
    """Same global batch, different microbatch splits -> same loss/grads."""
    cfg = get_smoke_config("yi-9b")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, ShapeSpec("t", 32, 4, "train"))
    opt_init, _ = lm.make_optimizer(cfg)
    losses = []
    for mb in (1, 2, 4):
        cfg_mb = dataclasses.replace(cfg, microbatch=mb)
        step = jax.jit(lm.train_step_fn(cfg_mb))
        _, _, stats = step(params, opt_init(params), batch)
        losses.append(float(stats["loss"]))
    assert losses[0] == pytest.approx(losses[1], rel=1e-4)
    assert losses[0] == pytest.approx(losses[2], rel=1e-4)


def test_unrolled_probe_paths_match_scanned():
    """scan_layers/scan_microbatch=False (roofline probes) must compute the
    same loss as the scanned paths."""
    cfg = get_smoke_config("recurrentgemma-9b")
    params = lm.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, ShapeSpec("t", 32, 4, "train"))
    opt_init, _ = lm.make_optimizer(cfg)
    step_scan = jax.jit(lm.train_step_fn(cfg))
    cfg_u = dataclasses.replace(cfg, scan_layers=False,
                                scan_microbatch=False)
    step_unroll = jax.jit(lm.train_step_fn(cfg_u))
    _, _, s1 = step_scan(params, opt_init(params), batch)
    _, _, s2 = step_unroll(params, opt_init(params), batch)
    assert float(s1["loss"]) == pytest.approx(float(s2["loss"]), rel=1e-5)


def test_input_specs_cover_all_cells():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape.name)
            for v in jax.tree_util.tree_leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)
