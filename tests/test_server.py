"""Concurrency + fault-injection suite for the cost-model socket server
(docs/SERVING.md §server).

Every test carries a deadline (`@pytest.mark.timeout` module-wide): a
deadlocked server must *fail* the suite, never hang it. Synchronization
is events/joins with timeouts — no sleeps. The blocking-service tests use
a jax-free stub (the server only needs the `submit/flush/stats/
snapshot_cache/restore_cache` protocol), so queue/deadline/shutdown
semantics are exercised without model latency noise; the parity tests run
against the real `CostModelService`.
"""
import os
import socket
import struct
import threading

import jax
import numpy as np
import pytest

from repro.core.evaluate import make_predict_fn, predict_kernels
from repro.core.model import CostModelConfig, cost_model_init
from repro.core import features as F
from repro.data.synthetic import random_kernel
from repro.serving import CostModelService, PredictionCache, RequestCoalescer
from repro.serving.client import (
    ClientError,
    CostModelClient,
    DeadlineExceeded,
    Overloaded,
    ProtocolError,
    WorkerFailure,
)
from repro.serving.server import CostModelServer, FaultPolicy, ServerStats

pytestmark = pytest.mark.timeout(180)

MAX_NODES = 32
JOIN_S = 30            # generous thread-join bound; tests fail, not hang


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def world():
    graphs = [random_kernel(n, seed=n) for n in (5, 7, 9, 12, 15, 18)]
    norm = F.fit_normalizer(graphs)
    cfg = CostModelConfig(gnn="graphsage", reduction="column_wise",
                          hidden_dim=16, opcode_embed_dim=8, dropout=0.0,
                          max_nodes=MAX_NODES, adjacency="sparse")
    params = cost_model_init(jax.random.key(0), cfg)
    predict_fn = make_predict_fn(cfg)
    return {"graphs": graphs, "norm": norm, "cfg": cfg, "params": params,
            "predict_fn": predict_fn}


def _service(world, **kw):
    return CostModelService(world["params"], world["cfg"], world["norm"],
                            predict_fn=world["predict_fn"], **kw)


class StubService:
    """jax-free stand-in implementing the server's service protocol.

    `gate` blocks every scoring call until set (saturation/shutdown
    tests); `started` is set when a scoring call begins. Scores are the
    graphs' node counts, so results stay checkable."""

    def __init__(self, *, blocking: bool = False):
        self.cache = PredictionCache(4096)
        self.gate = threading.Event()
        self.started = threading.Event()
        if not blocking:
            self.gate.set()
        self.coalescer = RequestCoalescer(self._score, node_budget=1 << 30,
                                          on_scored=self.cache.put)

    def _score(self, graphs):
        self.started.set()
        if not self.gate.wait(timeout=JOIN_S):
            raise TimeoutError("test forgot to open the gate")
        return np.array([g.num_nodes for g in graphs], np.float32)

    def submit(self, graphs):
        entries = []
        for g in graphs:
            key = g.canonical_hash()
            val = self.cache.get(key)
            entries.append(self.coalescer.add(key, g) if val is None else val)
        return _StubPending(self, entries)

    def flush(self):
        self.coalescer.flush()

    def stats(self):
        from repro.serving.service import ServiceStats
        return ServiceStats(requests=0, graphs=0, cache=self.cache.stats(),
                            coalesced=self.coalescer.coalesced,
                            flushes=self.coalescer.flushes,
                            flush_sizes=tuple(self.coalescer.flush_sizes))

    def snapshot_cache(self, path):
        return self.cache.snapshot(path)

    def restore_cache(self, path):
        return self.cache.restore(path)


class _StubPending:
    def __init__(self, service, entries):
        self._service, self._entries = service, entries

    def result(self):
        if any(hasattr(e, "ready") and not e.ready for e in self._entries):
            self._service.flush()
        return np.array([e.value if hasattr(e, "ready") else e
                         for e in self._entries], np.float32)


def _start(service, **kw) -> CostModelServer:
    return CostModelServer(service, **kw).start()


def _drain_threads(before):
    """Names of costmodel threads that outlived a stop()."""
    return [t.name for t in threading.enumerate()
            if t not in before and t.is_alive()
            and t.name.startswith("costmodel-server")]


# ---------------------------------------------------------------------------
# Concurrency: N clients x M requests, bit-identical to the direct path
# ---------------------------------------------------------------------------
def test_concurrent_clients_bit_identical(world):
    graphs = world["graphs"]
    # per-thread request streams: overlapping slices, like interleaved
    # tile-search clients
    streams = [[graphs[i % len(graphs)], graphs[(i + t) % len(graphs)]]
               for t in range(8) for i in range(4)]
    direct = {g.canonical_hash(): s for g, s in zip(
        graphs, predict_kernels(world["params"], world["cfg"], graphs,
                                world["norm"], max_nodes=MAX_NODES,
                                predict_fn=world["predict_fn"]))}
    server = _start(_service(world))
    host, port = server.address
    failures = []

    def client_thread(t):
        try:
            with CostModelClient(host, port) as c:
                for req in streams[t * 4:(t + 1) * 4]:
                    got = c.predict_many(req, deadline_ms=60_000)
                    want = np.array([direct[g.canonical_hash()]
                                     for g in req], np.float32)
                    if not np.array_equal(got, want):
                        failures.append((t, got, want))
        except Exception as e:                        # noqa: BLE001
            failures.append((t, repr(e)))

    threads = [threading.Thread(target=client_thread, args=(t,))
               for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=JOIN_S)
    assert not any(t.is_alive() for t in threads), "client threads hung"
    assert not failures, failures[:3]
    stats = server.stats
    assert stats.completed == 8 * 4
    assert stats.shed_overloaded == 0 and stats.shed_deadline == 0
    server.stop()


def test_cross_client_coalescing(world):
    """Identical graphs sent by different sockets while the worker is
    busy share one coalescer ticket (scored once)."""
    stub = StubService(blocking=True)
    server = _start(stub, coalesce_limit=8)
    host, port = server.address
    g = random_kernel(6, seed=0)
    warm = random_kernel(4, seed=1)
    results = []

    def one_client():
        with CostModelClient(host, port) as c:
            results.append(c.predict_many([g], deadline_ms=60_000))

    # occupy the worker so later requests pile up in the queue
    blocker = threading.Thread(target=lambda: CostModelClient(
        host, port).predict_many([warm], deadline_ms=60_000))
    blocker.start()
    assert stub.started.wait(timeout=JOIN_S)
    stub.gate.clear()                    # next scoring call will block too
    clients = [threading.Thread(target=one_client) for _ in range(4)]
    for t in clients:
        t.start()
    # all 4 duplicates must be queued before the worker drains them
    deadline = threading.Event()
    for _ in range(2000):
        if server._queue.qsize() >= 4:
            break
        deadline.wait(0.005)
    stub.gate.set()
    blocker.join(timeout=JOIN_S)
    for t in clients:
        t.join(timeout=JOIN_S)
    assert not any(t.is_alive() for t in clients)
    assert len(results) == 4
    assert all(float(r[0]) == g.num_nodes for r in results)
    # 4 identical graphs -> one scored entry; the rest were coalescer
    # shares or cache hits, never separate model scores
    scored = sum(stub.coalescer.flush_sizes)
    assert scored <= 2                   # warm graph + g exactly once
    server.stop()


# ---------------------------------------------------------------------------
# Fault injection: every mode ends in a clean typed error or retry success
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fault_server(world):
    server = _start(_service(world), allow_request_faults=True)
    yield server
    server.stop()


def test_fault_drop_is_clean_error(world, fault_server):
    host, port = fault_server.address
    with CostModelClient(host, port, retries=2, timeout_s=10) as c:
        with pytest.raises(ClientError):
            # the fault rides every resend, so retries exhaust cleanly
            c.inject_fault(world["graphs"][:2], "drop")
        # the connection was dropped, not the server: next call works
        out = c.predict_many(world["graphs"][:2], deadline_ms=60_000)
        assert out.shape == (2,)


def test_fault_delay_still_answers(world, fault_server):
    host, port = fault_server.address
    with CostModelClient(host, port) as c:
        want = c.predict_many(world["graphs"][:3], deadline_ms=60_000)
        got = c.inject_fault(world["graphs"][:3], "delay", delay_s=0.05)
        assert np.array_equal(got, want)


def test_fault_corrupt_frame_is_clean_error(world, fault_server):
    host, port = fault_server.address
    with CostModelClient(host, port, retries=1, timeout_s=10) as c:
        with pytest.raises(ProtocolError):
            c.inject_fault(world["graphs"][:2], "corrupt")
        assert c.predict_many(world["graphs"][:2],
                              deadline_ms=60_000).shape == (2,)


def test_fault_kill_flush_worker_recovers(world, fault_server):
    host, port = fault_server.address
    before = fault_server.stats.worker_failures
    with CostModelClient(host, port, retries=0, timeout_s=10) as c:
        with pytest.raises(WorkerFailure):
            c.inject_fault(world["graphs"][:2], "kill_flush")
        # the scoring pass died; the server did not
        out = c.predict_many(world["graphs"][:2], deadline_ms=60_000)
        assert out.shape == (2,)
    assert fault_server.stats.worker_failures > before


def test_server_side_fault_policy_retry_succeeds(world):
    """A transient server-side fault (one poisoned request) is survived by
    the client's retry: the resend gets a fresh sequence number."""
    server = _start(_service(world),
                    fault_policy=FaultPolicy("corrupt", requests=(1,)))
    host, port = server.address
    with CostModelClient(host, port, retries=2) as c:
        out = c.predict_many(world["graphs"][:2], deadline_ms=60_000)
        assert out.shape == (2,) and c.retried >= 1
    assert server.stats.faults_injected == 1
    server.stop()


def test_fault_policy_validates_mode():
    with pytest.raises(ValueError):
        FaultPolicy("segfault")


# ---------------------------------------------------------------------------
# Admission control: explicit shedding, never hangs, recovers
# ---------------------------------------------------------------------------
def test_overload_sheds_and_recovers():
    stub = StubService(blocking=True)
    server = _start(stub, max_queue=1, coalesce_limit=1)
    host, port = server.address
    results, errors = [], []

    def call(tag, **kw):
        try:
            with CostModelClient(host, port, retries=0, **kw) as c:
                results.append((tag, c.predict_many(
                    [random_kernel(5, seed=0)], deadline_ms=60_000)))
        except ClientError as e:
            errors.append((tag, e))

    # A occupies the worker (scoring blocked on the gate)...
    a = threading.Thread(target=call, args=("A",))
    a.start()
    assert stub.started.wait(timeout=JOIN_S)
    # ...B fills the queue (same graph: it will be a cache hit later)...
    b = threading.Thread(target=call, args=("B",))
    b.start()
    poll = threading.Event()
    for _ in range(2000):
        if server._queue.qsize() >= 1:
            break
        poll.wait(0.005)
    assert server._queue.qsize() >= 1
    # ...C must be shed immediately with an explicit `overloaded`
    with CostModelClient(host, port, retries=0) as c:
        with pytest.raises(Overloaded):
            c.predict_many([random_kernel(7, seed=1)], deadline_ms=60_000)
    assert server.stats.shed_overloaded == 1
    # release the gate: A and B complete, and the server has recovered
    stub.gate.set()
    a.join(timeout=JOIN_S)
    b.join(timeout=JOIN_S)
    assert not a.is_alive() and not b.is_alive()
    assert not errors and len(results) == 2
    with CostModelClient(host, port, retries=0) as c:
        assert c.predict_many([random_kernel(7, seed=1)],
                              deadline_ms=60_000).shape == (1,)
    # full accounting: every admitted request was answered
    s = server.stats
    assert s.requests == s.completed + s.shed_overloaded + s.shed_deadline
    server.stop()


def test_deadline_exceeded_while_queued():
    stub = StubService(blocking=True)
    server = _start(stub, max_queue=4, coalesce_limit=1)
    host, port = server.address
    outcome = {}

    def call_a():
        with CostModelClient(host, port) as c:
            outcome["A"] = c.predict_many([random_kernel(5, seed=0)],
                                          deadline_ms=60_000)

    def call_b():
        try:
            with CostModelClient(host, port, retries=0) as c:
                outcome["B"] = c.predict_many([random_kernel(9, seed=2)],
                                              deadline_ms=1.0)
        except DeadlineExceeded as e:
            outcome["B"] = e

    a = threading.Thread(target=call_a)
    a.start()
    assert stub.started.wait(timeout=JOIN_S)   # worker is busy scoring A
    b = threading.Thread(target=call_b)
    b.start()
    poll = threading.Event()
    for _ in range(2000):                       # B is parked in the queue
        if server._queue.qsize() >= 1:
            break
        poll.wait(0.005)
    poll.wait(0.01)                             # > B's 1ms deadline
    stub.gate.set()
    a.join(timeout=JOIN_S)
    b.join(timeout=JOIN_S)
    assert not a.is_alive() and not b.is_alive()
    assert isinstance(outcome["B"], DeadlineExceeded)
    assert outcome["A"].shape == (1,)
    assert server.stats.shed_deadline == 1
    server.stop()


# ---------------------------------------------------------------------------
# Warm cache: snapshot -> restart -> replay is hit-for-hit exact
# ---------------------------------------------------------------------------
def test_warm_snapshot_restart_replay_exact(world, tmp_path):
    snap = os.fspath(tmp_path / "warm-cache.npz")
    graphs = world["graphs"]
    cold_svc = _service(world)
    server = _start(cold_svc, snapshot_path=snap)
    host, port = server.address
    with CostModelClient(host, port) as c:
        want = c.predict_many(graphs, deadline_ms=60_000)
    server.stop()                               # writes the snapshot
    assert os.path.exists(snap)

    warm_svc = _service(world)
    server2 = _start(warm_svc, snapshot_path=snap)
    assert server2.stats.restored_entries == len(graphs)
    with CostModelClient(*server2.address) as c:
        got = c.predict_many(graphs, deadline_ms=60_000)
    s = warm_svc.stats()
    server2.stop()
    assert np.array_equal(got, want)            # hit-for-hit exact
    assert s.cache.misses == 0 and s.cache.hits == len(graphs)
    assert s.flushes == 0                       # the model was never touched


def test_snapshot_op_roundtrip(world, tmp_path):
    snap = os.fspath(tmp_path / "op-snapshot.npz")
    server = _start(_service(world))
    with CostModelClient(*server.address) as c:
        c.predict_many(world["graphs"][:4], deadline_ms=60_000)
        assert c.snapshot(snap) == 4
    server.stop()
    warm = PredictionCache(64)
    assert warm.restore(snap) == 4


# ---------------------------------------------------------------------------
# Shutdown: in-flight requests answered, no leaked threads or sockets
# ---------------------------------------------------------------------------
def test_shutdown_with_inflight_leaves_nothing_behind():
    before = set(threading.enumerate())
    stub = StubService(blocking=True)
    server = _start(stub, max_queue=8, coalesce_limit=1)
    host, port = server.address
    answered = []

    def call(tag):
        try:
            with CostModelClient(host, port, retries=0, timeout_s=20) as c:
                answered.append((tag, c.predict_many(
                    [random_kernel(5, seed=0)], deadline_ms=60_000)))
        except ClientError as e:
            answered.append((tag, e))

    a = threading.Thread(target=call, args=("inflight",))
    a.start()
    assert stub.started.wait(timeout=JOIN_S)
    b = threading.Thread(target=call, args=("queued",))
    b.start()
    poll = threading.Event()
    for _ in range(2000):
        if server._queue.qsize() >= 1:
            break
        poll.wait(0.005)
    stopper = threading.Thread(target=server.stop)
    stopper.start()
    stub.gate.set()                     # let the in-flight batch finish
    stopper.join(timeout=JOIN_S)
    a.join(timeout=JOIN_S)
    b.join(timeout=JOIN_S)
    assert not stopper.is_alive() and not a.is_alive() and not b.is_alive()
    # both requests were *answered* — scores or a typed error, no silence
    assert len(answered) == 2
    assert _drain_threads(before) == []
    # the listener socket is really gone: a fresh connect must fail
    with pytest.raises(OSError):
        socket.create_connection((host, port), timeout=2)


def test_stop_is_idempotent(world):
    server = _start(_service(world))
    server.stop()
    server.stop()                               # second stop: clean no-op


def test_client_shutdown_op():
    before = set(threading.enumerate())
    stub = StubService()
    server = _start(stub)
    c = CostModelClient(*server.address)
    c.shutdown()
    # the stop runs in the background; join the server's own threads
    for _ in range(2000):
        if not server.running and _drain_threads(before) == []:
            break
        threading.Event().wait(0.005)
    assert not server.running
    assert _drain_threads(before) == []


# ---------------------------------------------------------------------------
# Protocol hygiene
# ---------------------------------------------------------------------------
def test_garbage_frame_drops_connection_only():
    stub = StubService()
    server = _start(stub)
    host, port = server.address
    raw = socket.create_connection((host, port), timeout=5)
    raw.sendall(struct.pack(">I", 8) + b"notjson!")
    # server closes this connection (recv -> EOF)...
    raw.settimeout(5)
    assert raw.recv(1) == b""
    raw.close()
    # ...but keeps serving fresh ones
    with CostModelClient(host, port) as c:
        assert c.ping() > 0
    server.stop()


def test_oversize_frame_rejected():
    stub = StubService()
    server = _start(stub)
    host, port = server.address
    raw = socket.create_connection((host, port), timeout=5)
    raw.sendall(struct.pack(">I", (64 << 20) + 1))    # absurd length
    raw.settimeout(5)
    assert raw.recv(1) == b""
    raw.close()
    server.stop()


def test_unknown_op_is_bad_request():
    stub = StubService()
    server = _start(stub)
    with CostModelClient(*server.address, retries=0) as c:
        with pytest.raises(ClientError, match="bad_request"):
            c._call({"op": "frobnicate"})
    server.stop()


def test_undecodable_graphs_are_bad_request():
    stub = StubService()
    server = _start(stub)
    with CostModelClient(*server.address, retries=0) as c:
        with pytest.raises(ClientError, match="bad_request"):
            c._call({"op": "predict", "graphs": [{"bogus": 1}]})
    server.stop()


def test_stats_and_ping_ops(world):
    server = _start(_service(world))
    with CostModelClient(*server.address) as c:
        assert c.ping() > 0
        c.predict_many(world["graphs"][:3], deadline_ms=60_000)
        st = c.stats()
    assert st["server"]["completed"] == 1
    assert st["service"]["cache_size"] == 3
    assert st["service"]["flushes"] >= 1
    server.stop()


def test_server_stats_to_dict_roundtrip():
    s = ServerStats(connections=2, requests=5, completed=4,
                    shed_overloaded=1)
    d = s.to_dict()
    assert d["connections"] == 2 and d["shed_overloaded"] == 1
    assert set(d) == {"connections", "requests", "completed",
                      "shed_overloaded", "shed_deadline", "worker_failures",
                      "faults_injected", "restored_entries"}
