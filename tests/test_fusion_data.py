"""Fusion machinery + dataset construction tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import opset
from repro.core.simulator import TPUSimulator, tile_fits_vmem
from repro.data.corpus import filter_by_programs, kernel_hash, split_programs
from repro.data.fusion import (
    FusionDecision,
    apply_fusion,
    default_fusion,
    fusable_edges,
    no_fusion,
    random_fusion,
)
from repro.data.fusion_dataset import build_fusion_dataset
from repro.data.sampler import BalancedSampler, ShardPlanner, TileBatchSampler
from repro.data.synthetic import FAMILIES, generate_corpus, generate_program
from repro.data.tile_dataset import build_tile_dataset, enumerate_tiles


def test_generator_deterministic():
    a = generate_program("mlp", 3, seed=7)
    b = generate_program("mlp", 3, seed=7)
    assert kernel_hash(a) == kernel_hash(b)
    c = generate_program("mlp", 3, seed=8)
    assert kernel_hash(a) != kernel_hash(c)


def test_all_families_build_valid_programs():
    for fam in FAMILIES:
        g = generate_program(fam, 0, seed=1)
        assert g.num_nodes > 3
        assert any(n.is_output for n in g.nodes)
        # topological ordering enforced in the constructor


@pytest.mark.parametrize("fam", ["attention", "cnn", "mlp"])
def test_fusion_partition_covers_all_compute_nodes(fam):
    g = generate_program(fam, 1, seed=0)
    for dec in (no_fusion(g), default_fusion(g)):
        kernels = apply_fusion(g, dec)
        n_compute = sum(1 for n in g.nodes
                        if n.op not in (opset.PARAMETER, opset.CONSTANT))
        total = sum(sum(1 for n in k.nodes if n.op is not opset.PARAMETER)
                    for k in kernels)
        assert total == n_compute


def test_fusion_respects_contraction_rule():
    g = generate_program("attention", 2, seed=0)
    edges = fusable_edges(g)
    dec = FusionDecision(tuple(True for _ in edges))   # fuse everything
    for k in apply_fusion(g, dec):
        n_contract = sum(1 for n in k.nodes if n.op.fusion_root_only)
        assert n_contract <= 1


def test_default_fusion_reduces_kernel_count():
    g = generate_program("norm", 0, seed=0)
    n_no = len(apply_fusion(g, no_fusion(g)))
    n_def = len(apply_fusion(g, default_fusion(g)))
    assert n_def < n_no


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_random_fusion_valid_for_any_seed(seed):
    g = generate_program("rnn", 0, seed=1)
    rng = np.random.default_rng(seed)
    dec = random_fusion(g, rng)
    kernels = apply_fusion(g, dec)
    assert kernels
    for k in kernels:
        k._check_topo()


def test_enumerate_tiles_valid_and_bounded():
    g = generate_program("mlp", 0, seed=0)
    kernels = apply_fusion(g, default_fusion(g))
    sim = TPUSimulator()
    for k in kernels[:3]:
        tiles = enumerate_tiles(k, max_configs=32, hw=sim.hw)
        assert len(tiles) <= 32
        for t in tiles:
            assert tile_fits_vmem(k, t, sim.hw)
            assert len(t) == len(k.root.shape)


def test_splits_disjoint_and_complete():
    progs = [p.program for p in generate_corpus(30, seed=0)]
    for method in ("random", "manual"):
        sp = split_programs(progs, method=method)
        all_names = sp["train"] + sp["val"] + sp["test"]
        assert sorted(all_names) == sorted(set(progs))
        assert not (set(sp["train"]) & set(sp["test"]))
        assert sp["test"], method
    manual = split_programs(progs, method="manual")
    for name in manual["test"]:
        assert name.startswith(("convdraw", "embedding"))


def test_datasets_and_samplers():
    progs = generate_corpus(8, seed=0)
    sim = TPUSimulator()
    tds = build_tile_dataset(progs, sim, max_configs_per_kernel=8)
    fds = build_fusion_dataset(progs, sim, configs_per_program=4)
    assert tds.num_samples > 50
    assert fds.num_samples > 30
    # dedup: all hashes unique
    hs = [kernel_hash(r.kernel) for r in fds.records]
    assert len(hs) == len(set(hs))

    from repro.data.tile_dataset import fit_tile_normalizer
    norm = fit_tile_normalizer(tds.records)
    ts = TileBatchSampler(tds.records, norm, kernels_per_batch=2,
                          configs_per_kernel=4, max_nodes=48)
    b1, b2 = ts.batch(5), ts.batch(5)
    np.testing.assert_array_equal(b1.targets, b2.targets)      # determinism
    assert set(np.asarray(b1.group_ids)) == {0, 1}
    bs = BalancedSampler(fds.records, norm, batch_size=8, max_nodes=48)
    fb = bs.batch(0)
    assert fb.targets.shape == (8,)
    assert (fb.targets > 0).all()

    # records filter
    sub = filter_by_programs(tds.records, [tds.records[0].program])
    assert all(r.program == tds.records[0].program for r in sub)


def test_shard_planner_straggler_takeover():
    pl = ShardPlanner(4)
    healthy = pl.plan(0)
    assert healthy == {0: [0], 1: [1], 2: [2], 3: [3]}
    degraded = pl.plan(0, frozenset({1, 2}))
    covered = sorted(s for shards in degraded.values() for s in shards)
    assert covered == [0, 1, 2, 3]           # all shards still consumed
    assert set(degraded) == {0, 3}           # only healthy hosts work
    # deterministic
    assert degraded == pl.plan(0, frozenset({1, 2}))
