"""Corpus store tests: serialization round trip, dedup, checksums,
manifest determinism, streaming parity with in-memory sampling
(DESIGN.md §11, docs/DATA.md)."""
import json
import os

import numpy as np
import pytest

from repro.core import opset
from repro.core.graph import KernelGraph, Node
from repro.core.simulator import TPUSimulator
from repro.data.fusion_dataset import build_fusion_records
from repro.data.prefetch import Prefetcher
from repro.data.sampler import BalancedSampler, TileBatchSampler
from repro.data.store import (
    CorpusFormatError,
    StreamingCorpus,
    load_manifest,
    record_key,
    write_corpus,
)
from repro.data.synthetic import generate_program, random_kernel
from repro.data.tile_dataset import build_tile_records, fit_tile_normalizer
from repro.launch.build_corpus import build_corpus


@pytest.fixture(scope="module")
def sim():
    return TPUSimulator()


@pytest.fixture(scope="module")
def tile_records(sim):
    kernels = [random_kernel(n, seed=i)
               for i, n in enumerate((10, 14, 18, 12, 16, 20))]
    return build_tile_records(kernels, sim, max_configs_per_kernel=8)


@pytest.fixture(scope="module")
def fusion_records(sim):
    recs = []
    for i, fam in enumerate(("mlp", "norm")):
        recs.extend(build_fusion_records(generate_program(fam, i, 0), sim,
                                         configs_per_program=4))
    return recs


# --------------------------------------------------------------- graph serde
def test_graph_dict_round_trip_preserves_hashes():
    g = generate_program("attention", 0, seed=3)
    g2 = KernelGraph.from_dict(g.to_dict())
    assert g2.program == g.program and g2.name == g.name
    assert g2.canonical_hash() == g.canonical_hash()
    assert (g2.canonical_hash(order_sensitive=True)
            == g.canonical_hash(order_sensitive=True))
    assert [n.to_dict() for n in g2.nodes] == [n.to_dict() for n in g.nodes]


def test_graph_dict_round_trip_with_tile():
    g = random_kernel(9, seed=1).with_tile((8, 8))
    g2 = KernelGraph.from_dict(g.to_dict())
    assert g2.tile_size == (8, 8)
    assert g2.canonical_hash() == g.canonical_hash()


def test_node_from_dict_rejects_unknown_op():
    d = Node(opset.ADD, (4,), inputs=()).to_dict()
    d["op"] = "not-an-op"
    with pytest.raises(KeyError):
        Node.from_dict(d)


# ------------------------------------------------------------ store roundtrip
def test_tile_round_trip_exact(tile_records, tmp_path):
    m = write_corpus(str(tmp_path / "t"), "tile", tile_records,
                     shard_records=2)
    c = StreamingCorpus.open(str(tmp_path / "t"), verify=True)
    assert len(c) == len(tile_records)
    assert c.kind == "tile" and c.num_samples == m["stats"]["samples"]
    for a, b in zip(tile_records, c):
        assert a.tiles == b.tiles and a.program == b.program
        assert a.runtimes.dtype == b.runtimes.dtype == np.float64
        np.testing.assert_array_equal(a.runtimes, b.runtimes)  # bit-exact
        assert record_key(a) == record_key(b)


def test_fusion_round_trip_exact(fusion_records, tmp_path):
    write_corpus(str(tmp_path / "f"), "fusion", fusion_records)
    c = StreamingCorpus.open(str(tmp_path / "f"))
    assert [r.runtime for r in c] == [r.runtime for r in fusion_records]
    assert c.record_programs == [r.program for r in fusion_records]


def test_random_access_and_shard_lru(tile_records, tmp_path):
    write_corpus(str(tmp_path / "t"), "tile", tile_records, shard_records=1)
    c = StreamingCorpus.open(str(tmp_path / "t"), max_cached_shards=1)
    # thrash: every access evicts the only cached shard
    for i in (3, 0, 5, 2, 3, -1):
        want = tile_records[i]
        got = c[i]
        assert got.tiles == want.tiles
        np.testing.assert_array_equal(got.runtimes, want.runtimes)
    with pytest.raises(IndexError):
        c[len(tile_records)]


def test_iter_shards_streams_in_order(tile_records, tmp_path):
    write_corpus(str(tmp_path / "t"), "tile", tile_records, shard_records=2)
    seen = [r for shard in
            StreamingCorpus.open(str(tmp_path / "t")).iter_shards()
            for r in shard]
    assert [record_key(r) for r in seen] == \
        [record_key(r) for r in tile_records]


# --------------------------------------------------------------------- dedup
def test_dedup_drops_exact_duplicates(fusion_records, tmp_path):
    doubled = fusion_records + fusion_records[:3]
    m = write_corpus(str(tmp_path / "f"), "fusion", doubled)
    assert m["stats"]["records"] == len(fusion_records)
    assert m["stats"]["duplicates_dropped"] == 3


def test_dedup_off_preserves_duplicates(fusion_records, tmp_path):
    doubled = fusion_records + fusion_records[:3]
    m = write_corpus(str(tmp_path / "f"), "fusion", doubled, dedup=False)
    assert m["stats"]["records"] == len(doubled)
    assert m["stats"]["duplicates_dropped"] == 0


def test_tile_key_covers_tile_sweep(tile_records):
    r = tile_records[0]
    import dataclasses
    trimmed = dataclasses.replace(r, tiles=r.tiles[:-1],
                                  runtimes=r.runtimes[:-1])
    assert record_key(r) != record_key(trimmed)
    assert record_key(r) == record_key(dataclasses.replace(r, program="x"))


# ----------------------------------------------------- integrity + manifests
def test_checksum_mismatch_detected(fusion_records, tmp_path):
    d = str(tmp_path / "f")
    m = write_corpus(d, "fusion", fusion_records, shard_records=4)
    shard = os.path.join(d, m["shards"][0]["file"])
    blob = bytearray(open(shard, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(shard, "wb") as f:
        f.write(blob)
    with pytest.raises(CorpusFormatError, match="checksum"):
        StreamingCorpus.open(d, verify=True)
    with pytest.raises(CorpusFormatError, match="checksum"):
        StreamingCorpus.open(d)[0]          # lazy load checks too


def test_manifest_tamper_detected(fusion_records, tmp_path):
    d = str(tmp_path / "f")
    write_corpus(d, "fusion", fusion_records)
    mpath = os.path.join(d, "manifest.json")
    m = json.load(open(mpath))
    m["stats"]["records"] = 9999
    json.dump(m, open(mpath, "w"))
    with pytest.raises(CorpusFormatError, match="manifest hash"):
        StreamingCorpus.open(d, verify=True)


def test_open_missing_raises(tmp_path):
    with pytest.raises(CorpusFormatError):
        StreamingCorpus.open(str(tmp_path / "nope"))
    assert load_manifest(str(tmp_path / "nope")) is None


def test_writer_refuses_non_store_dir(fusion_records, tmp_path):
    d = tmp_path / "precious"
    d.mkdir()
    (d / "notes.txt").write_text("do not delete")
    with pytest.raises(CorpusFormatError, match="refusing"):
        write_corpus(str(d), "fusion", fusion_records)
    assert (d / "notes.txt").exists()


def test_write_is_deterministic(fusion_records, tmp_path):
    m1 = write_corpus(str(tmp_path / "a"), "fusion", fusion_records)
    m2 = write_corpus(str(tmp_path / "b"), "fusion", fusion_records)
    assert m1["manifest_hash"] == m2["manifest_hash"]
    for s1, s2 in zip(m1["shards"], m2["shards"]):
        assert s1["sha256"] == s2["sha256"]


# ------------------------------------------------------------- builder CLI
def test_build_corpus_noop_and_determinism(tmp_path):
    kw = dict(kinds=("fusion",), programs=4, seed=0, workers=1,
              fusion_opts={"configs_per_program": 3}, quiet=True)
    m1 = build_corpus(str(tmp_path / "c"), **kw)
    m2 = build_corpus(str(tmp_path / "c"), **kw)            # no-op
    assert m1["fusion"]["manifest_hash"] == m2["fusion"]["manifest_hash"]
    m3 = build_corpus(str(tmp_path / "c2"), **dict(kw, force=True))
    assert m3["fusion"]["manifest_hash"] == m1["fusion"]["manifest_hash"]
    m4 = build_corpus(str(tmp_path / "c3"), **dict(kw, seed=1))
    assert m4["fusion"]["manifest_hash"] != m1["fusion"]["manifest_hash"]


@pytest.mark.slow
def test_build_corpus_workers_match_serial(tmp_path):
    kw = dict(kinds=("tile", "fusion"), programs=6, seed=0,
              tile_opts={"max_configs_per_kernel": 8},
              fusion_opts={"configs_per_program": 3}, quiet=True)
    m1 = build_corpus(str(tmp_path / "w1"), workers=1, **kw)
    m2 = build_corpus(str(tmp_path / "w2"), workers=2, **kw)
    for kind in ("tile", "fusion"):
        assert m1[kind]["manifest_hash"] == m2[kind]["manifest_hash"]


# -------------------------------------------------------- streaming parity
def test_tile_sampler_stream_parity(tile_records, tmp_path):
    d = str(tmp_path / "t")
    write_corpus(d, "tile", tile_records, shard_records=2)
    corpus = StreamingCorpus.open(d, max_cached_shards=2)
    norm = fit_tile_normalizer(tile_records)
    mk = lambda recs: TileBatchSampler(  # noqa: E731
        recs, norm, kernels_per_batch=3, configs_per_kernel=4,
        max_nodes=24, seed=0)
    s_mem, s_store = mk(tile_records), mk(corpus)
    for step in range(4):
        a, b = s_mem.batch(step), s_store.batch(step)
        np.testing.assert_array_equal(a.targets, b.targets)
        np.testing.assert_array_equal(a.group_ids, b.group_ids)
        np.testing.assert_array_equal(a.valid, b.valid)
        import jax
        for x, y in zip(jax.tree_util.tree_leaves(a.graphs),
                        jax.tree_util.tree_leaves(b.graphs)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fusion_sampler_prefetch_parity(fusion_records, tmp_path):
    d = str(tmp_path / "f")
    write_corpus(d, "fusion", fusion_records, shard_records=4)
    corpus = StreamingCorpus.open(d, max_cached_shards=1)
    from repro.core.features import fit_normalizer
    norm = fit_normalizer([r.kernel for r in fusion_records])
    s_mem = BalancedSampler(fusion_records, norm, batch_size=8,
                            max_nodes=24, seed=0)
    with Prefetcher(BalancedSampler(corpus, norm, batch_size=8,
                                    max_nodes=24, seed=0), depth=2) as pre:
        for step in range(4):
            a, b = s_mem.batch(step), pre.batch(step)
            np.testing.assert_array_equal(a.targets, b.targets)
            import jax
            for x, y in zip(jax.tree_util.tree_leaves(a.graphs),
                            jax.tree_util.tree_leaves(b.graphs)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_select_programs_view(fusion_records, tmp_path):
    d = str(tmp_path / "f")
    write_corpus(d, "fusion", fusion_records)
    corpus = StreamingCorpus.open(d)
    programs = corpus.programs()
    assert len(programs) == 2
    sub = corpus.select_programs([programs[0]])
    assert 0 < len(sub) < len(corpus)
    assert set(sub.record_programs) == {programs[0]}
    assert sub[0].program == programs[0]
    # a sampler over the view draws only from the selected program
    from repro.core.features import fit_normalizer
    norm = fit_normalizer([sub[0].kernel])
    s = BalancedSampler(sub, norm, batch_size=4, max_nodes=24, seed=0)
    assert s.batch(0).targets.shape == (4,)

# ------------------------------------------------- worker shard properties
# `StreamingCorpus.shard(idx, num)` (DESIGN.md §13): deterministic,
# disjoint, manifest-only round-robin views whose position interleave is
# the unsharded stream. Property tests can't take pytest fixtures (the
# hypothesis @given wrapper owns the signature), so they share one
# module-memoized on-disk corpus.
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.sampler import shard_records  # noqa: E402
from repro.data.store import CorpusSubset  # noqa: E402

_SHARD_CORPUS: dict = {}


def _shard_corpus() -> StreamingCorpus:
    if "c" not in _SHARD_CORPUS:
        import tempfile
        sim = TPUSimulator()
        kernels = [random_kernel(n, seed=i)
                   for i, n in enumerate((10, 14, 18, 12, 16, 20, 11))]
        recs = build_tile_records(kernels, sim, max_configs_per_kernel=4)
        d = tempfile.mkdtemp(prefix="shard_corpus_")
        write_corpus(d, "tile", recs, shard_records=3)
        _SHARD_CORPUS["c"] = StreamingCorpus.open(d, max_cached_shards=2)
    return _SHARD_CORPUS["c"]


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=9))
def test_shards_disjoint_exhaustive_interleave(num):
    corpus = _shard_corpus()
    shards = [corpus.shard(i, num) for i in range(num)]
    assert sum(len(s) for s in shards) == len(corpus)
    keys = [record_key(r) for s in shards for r in s]
    assert len(set(keys)) == len(keys)                      # disjoint
    for k in range(len(corpus)):                            # exhaustive +
        got = shards[k % num][k // num]                     # ordered union
        want = corpus[k]
        assert record_key(got) == record_key(want)
        np.testing.assert_array_equal(got.runtimes, want.runtimes)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=5))
def test_shard_deterministic_and_manifest_only(num, idx):
    corpus = _shard_corpus()
    idx = idx % num
    a, b = corpus.shard(idx, num), corpus.shard(idx, num)
    # same records on every call, computed from the manifest alone
    assert a._indices == b._indices == list(range(idx, len(corpus), num))
    assert [r["key"] for r in
            (corpus.manifest["index"][i] for i in a._indices)] == \
        [record_key(r) for r in a]


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=2, max_value=4),
       st.integers(min_value=2, max_value=3))
def test_shard_composes_with_subshard(num, sub):
    """shard().shard() flattens to one round-robin over num*sub workers."""
    corpus = _shard_corpus()
    nested = corpus.shard(1 % num, num).shard(1 % sub, sub)
    want = list(range(len(corpus)))[1 % num::num][1 % sub::sub]
    assert nested._indices == want


def test_shard_identity_view_shares_parent_lru():
    corpus = _shard_corpus()
    view = corpus.shard(0, 1)
    assert isinstance(view, CorpusSubset)
    assert view._corpus is corpus                 # same LRU, no copy
    assert len(view) == len(corpus)
    assert [record_key(r) for r in view] == \
        [record_key(r) for r in corpus]
    corpus._cache.clear()
    _ = view[0]                                   # decode through the view…
    assert len(corpus._cache) == 1                # …lands in the parent LRU


def test_shard_validation_errors():
    corpus = _shard_corpus()
    with pytest.raises(ValueError):
        corpus.shard(0, 0)
    with pytest.raises(ValueError):
        corpus.shard(2, 2)
    with pytest.raises(ValueError):
        corpus.shard(-1, 2)
    with pytest.raises(ValueError):
        corpus.shard(0, 2).shard(3, 3)


def test_shard_records_prefers_manifest_view():
    corpus = _shard_corpus()
    view = shard_records(corpus, 1, 3)
    assert isinstance(view, CorpusSubset)         # no decode, no list copy
    assert view._corpus is corpus
    # plain lists fall back to strided slicing with identical membership
    recs = list(corpus)
    assert [record_key(r) for r in shard_records(recs, 1, 3)] == \
        [record_key(r) for r in view]
    assert shard_records(recs, 0, 1) is recs      # num=1: untouched


@pytest.mark.slow
def test_shard_deterministic_under_build_workers(tmp_path):
    """The shard views of a corpus built with --workers N are identical to
    the serial build's — partitioning the build cannot move records
    between worker shards."""
    kw = dict(kinds=("tile",), programs=6, seed=0,
              tile_opts={"max_configs_per_kernel": 6}, quiet=True)
    build_corpus(str(tmp_path / "w1"), workers=1, **kw)
    build_corpus(str(tmp_path / "w2"), workers=2, **kw)
    c1 = StreamingCorpus.open(str(tmp_path / "w1" / "tile"))
    c2 = StreamingCorpus.open(str(tmp_path / "w2" / "tile"))
    for w in (2, 3):
        for i in range(w):
            assert [record_key(r) for r in c1.shard(i, w)] == \
                [record_key(r) for r in c2.shard(i, w)]
